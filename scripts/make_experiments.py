"""Compose EXPERIMENTS.md from the dry-run / roofline sweep artifacts.

    PYTHONPATH=src python scripts/make_experiments.py

Inputs (produced by repro.launch.dryrun / repro.launch.roofline):
    dryrun_results_opt.jsonl   80-cell compile/memory table (optimized code)
    roofline_baseline.jsonl    32-cell baseline roofline terms
    roofline_opt_full.jsonl    32-cell optimized roofline terms
"""
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def load(name):
    path = os.path.join(ROOT, name)
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path) if l.strip()]


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(recs):
    out = ["| arch | shape | mesh | status | compile s | peak GiB/dev | "
           "HLO flops/dev |", "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r['compile_s']} | "
                f"{fmt_bytes(r['memory']['peak_per_device_bytes'])} | "
                f"{r['cost']['flops_per_device']:.2e} |")
        elif r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skip | — | — | — |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"**FAIL** | — | — | — |")
    return "\n".join(out)


def roofline_table(recs, title):
    out = [f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | useful | roofline % |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']*100:.1f} |")
    return "\n".join(out)


def main():
    dr = load("dryrun_results_opt.jsonl") or load("dryrun_results.jsonl")
    base = load("roofline_baseline.jsonl")
    opt = load("roofline_opt_full.jsonl")

    n_ok = sum(r["status"] == "ok" for r in dr)
    n_skip = sum(r["status"] == "skip" for r in dr)
    n_fail = len(dr) - n_ok - n_skip

    doc = f"""# EXPERIMENTS — DeLIA-JAX

All numbers in this file are produced by checked-in tooling:
`repro.launch.dryrun` (compile/memory), `repro.launch.roofline` (roofline
terms), `benchmarks/run.py` (paper reproduction + subsystem benches).
Hardware model: TPU v5e-class — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
50 GB/s/link ICI (single-link conservative).  Runtime here is CPU-only:
everything below is derived from `.lower().compile()` artifacts
(`cost_analysis`, `memory_analysis`, HLO text), never from wall-clock.

## S Paper-validation (the reproduction floor)

The paper's quantitative claim: integrating DeLIA into the FWI 4D code with
**global saves every iteration + termination-signal detection** costs a
median relative overhead of **~1.4%** (eq. 2: (M_with - M_without)/M_with;
their medians 13441.83 s vs ~13266.9 s), with ~2x runtime-stddev inflation.

We rebuilt the whole stack (Sec. DESIGN.md): the BSP coordinator, the
checkpoint/heartbeat/signal layers, and the FWI application itself, then ran
the paper's experiment shape (R runs with / without the library,
checkpoint every iteration, medians + eq. 2) — `benchmarks/bench_overhead_fwi.py`
(this run's numbers in `bench_output.txt`):

- sync-every-iteration: **5.4%** median overhead (paper: 1.4% — our
  iterations are ~0.2 s vs their ~672 s, so the latency-dominated save
  costs proportionally more; the eq.-3 bound scales with C/T exactly as the
  paper derives).  **Async** saves (beyond-paper) land at **1.7%** —
  inside the paper's band — and int8-codec saves at ~2.7%.
- the stddev inflation with sync saves reproduces almost exactly:
  **1.9x** (0.0433 vs 0.0224 s) vs the paper's ~2x (21.33 vs 10.77 s,
  Fig. 2) — same mechanism (FS write jitter on the critical path).
- eq. (2)/(3) and the Young/Daly eq. (1) implementation are property-tested
  against the paper's own numbers (`tests/test_policy.py`:
  `test_overhead_metric_eq2` checks 174.9448/13441.8312 ~ 1.3%).

Beyond-paper rows in the same bench: **async** double-buffered saves drive
the overhead to ~0% (only the device->host snapshot remains on the BSP
critical path), and **int8-block-codec** checkpoints cut checkpoint bytes
~3.9x, which by eq. (1) shortens the optimal period by ~2x
(`benchmarks/bench_checkpoint.py` prints the Young/Daly table).

End-to-end fault-tolerance invariants (pytest, `tests/`):
- crash at any step -> restore -> **bit-exact** continuation vs a
  failure-free run (global + local state), sync and async
  (`test_recovery.py`, `test_system.py`).
- SIGTERM/SIGUSR1 -> final checkpoint at the superstep boundary -> resume
  (`test_heartbeat_signals.py`, `examples/preemption.py`).
- UDP heartbeat fail-stop detection + rejoin; straggler watchdog
  (`test_heartbeat_signals.py`, `test_recovery.py`).
- elastic restore onto a smaller surviving mesh, bit-equal trajectory
  (`test_elastic_mesh.py`).

## S Dry-run (assignment: every arch x shape x mesh must compile)

{n_ok} ok / {n_skip} documented skips / **{n_fail} failures** out of
{len(dr)} (arch x shape x mesh) attempts.  Skips are the assignment-mandated
ones (encoder decode cells; long_500k on full-attention archs) — see
DESIGN.md S5.  `peak GiB/dev` = `memory_analysis` arguments + temporaries
(CPU-backend buffer assignment as proxy; see caveats below).

{dryrun_table(dr)}

Memory-fit notes:
- Train cells use per-arch gradient accumulation (mb=4..16, clamped so the
  per-microbatch batch stays DP-shardable) and, on the heavy archs,
  sequence-parallel residuals (`seq_shard`).
- `peak GiB/dev` comes from XLA:**CPU** buffer assignment, which neither
  overlaps FSDP gathers with compute nor reuses remat buffers the way the
  TPU latency-hiding scheduler does — treat it as a pessimistic proxy.
  Notably, the S Perf sharding work (gather-before-norm, cotangent pins)
  *raised* this proxy for several train cells by a few GiB while cutting
  wire/HBM traffic 2-4x; we kept the traffic wins and record the proxy
  honestly.  Cells over 16 GiB on the proxy: the remediation stack is
  (i) more microbatches, (ii) bf16 Adam moments (-4 B/param),
  (iii) the multi-pod mesh (every such cell shrinks ~2x at 2x16x16 —
  table rows above), (iv) int8 KV cache for the decode cells (the
  `ckpt_codec` kernel).
- decode cells donate the KV cache (in/out aliased); serve params are bf16
  and replicate across DP when a TP shard is < 4 GiB (zero per-layer weight
  gathers at inference).

## S Roofline

### Methodology (probe-corrected; see `repro/launch/roofline.py`)

1. XLA `cost_analysis` counts a `lax.scan` body once, so the scanned
   production model under-reports by the trip count (measured 10x on a
   10-step scan).  Every cell is therefore re-lowered as two UNROLLED
   probes — L=0 (embed+head+loss) and L=len(pattern) — and reconstructed:
   `total = L0 + (L/P) x (LP - L0)`.  Remat recompute appears unrolled in
   the probes and is counted (visible in `useful`).
2. `cost_analysis` is **per-device** post-SPMD: terms divide by per-chip
   peaks directly, and padding waste (e.g. 12->16 padded q-heads) is
   honestly included.
3. FLOPs and collectives come from exact-FLOPs einsum-attention probes
   (attention is collective-free).  The memory term of the optimized table
   uses blocked-attention probes — the flash/VMEM-resident production path —
   while the baseline table charges naive einsum-attention bytes.
4. Collective wire bytes parsed from HLO with op-specific factors
   (all-reduce 2(G-1)/G, all-gather (G-1)/G, reduce-scatter (G-1),
   all-to-all (G-1)/G x result bytes, permute 1x); XLA:CPU wraps bf16
   collectives in f32 converts — those are counted at bf16 size (their TPU
   wire size).  Term = wire bytes / 50 GB/s (single-link, conservative: a
   2D-torus ring would have >=2 usable links, so this term is an upper
   bound).
5. Train cells: probes are the grads function at the per-microbatch batch;
   a step = mb x probe + closed-form AdamW/clip term (25 flops, 36 bytes
   per local param; no collectives).
6. `useful` = MODEL_FLOPS / HLO_FLOPs with MODEL_FLOPS = 6*N_active*D
   (train) or 2*N_active*D (serve).  <1 means remat recompute, attention
   quadratic terms (dominant at 32k+), vocab/head padding, and capacity-
   factor MoE slack.  `roofline %` = ideal step time (MODEL_FLOPS at peak)
   / max(term).
7. CPU-proxy caveats: "bytes accessed" reflects XLA:CPU fusion, which is
   far weaker than TPU fusion — the memory term is a structural UPPER
   bound (it still ranks implementations correctly: removing S^2 score
   materialization or fp32 weight gathers shows up 1:1).  Decode cells'
   roofline % is intentionally tiny: one token against a 32k cache is
   bandwidth-bound by construction; the meaningful decode metric is the
   memory term itself (~cache bytes / HBM BW = optimal).

### Baseline table (paper-faithful framework defaults, einsum attention,
before the S Perf optimizations; single-pod 16x16)

{roofline_table(base, "baseline")}

### Optimized table (after S Perf: bf16-pinned weight gathers, shard_map
embedding, gather-before-norm SP, cotangent-aligned TP pins, flash-memory
accounting; single-pod 16x16)

{roofline_table(opt, "optimized")}

## S Perf — hypothesis -> change -> measure -> validate log

Cells hillclimbed (per assignment: worst roofline, most collective-bound,
most representative): **qwen1.5-110b x train_4k** (most collective-bound:
171 s collective term at baseline), **hubert-xlarge x prefill_32k** (worst
non-decode roofline fraction: 0.8%), **granite-3-8b x train_4k** (the
representative DeLIA-protected dense-LM training job).  Iterations below
ran on all three; numbers are (compute / memory / collective seconds,
roofline %).

**it0 — baseline.**
granite 1.57/20.28/16.90 (5.0%) · qwen110 38.27/135.42/171.06 (8.1%,
collective-dominated) · hubert 0.21/4.73/1.53 (0.8%).

**it1 — H: casting weights/attention to bf16 at use-sites halves wire
bytes.**  Change: cast params once outside scan; shard_map masked-lookup
+psum embedding (kills an 839 MB fp32 table all-gather per step); bf16
attention operands with fp32 accumulation.  Measured: nearly no change
(granite coll 16.90 -> 16.78).  **Refuted** — HLO inspection showed GSPMD
propagates the consumers' replicated sharding BACKWARD through elementwise
casts and still moved fp32: the gathers hoist above the casts.  Lesson:
dtype at the op is not dtype on the wire; placement is a sharding-propagation
fight.

**it2 — H: hard bf16 edges (back-to-back sharding constraints) force the
reshard onto bf16 tensors.**  Change: SP gathers moved BEFORE the norm (the
norm's fp32 internals were getting resharded at 2x bytes); weight casts
pinned to the parameter sharding.  Measured: granite 1.62/18.48/14.50
(5.5%); qwen110 compute **38.3 -> 18.2 s** (GSPMD had been replicating
whole attention computations — "involuntary full rematerialization" — which
the clean edges removed; useful 0.36 -> 0.76), coll 171 -> 153.
**Confirmed** (large side-benefit on compute).

**it3 — metric correction, not a code change: XLA:CPU lowers bf16
collectives as convert->f32-collective->convert.**  parse_collectives now
counts convert-wrapped f32 collectives at bf16 size (their TPU size).
granite coll 14.5 -> 8.5; qwen110 153 -> 81.4.  Recorded separately so the
code-change deltas above/below stay honest.

**it4 — H: the memory term is dominated by einsum-attention S^2 traffic
that the flash kernel (VMEM-resident tiles) never moves.**  Change: memory
term measured from blocked-attention probes (the deployable path; the
Pallas kernel implements exactly this blocking — `kernels/flash_attention`).
Measured: hubert memory **4.73 -> 0.68 s** (6.9x; roofline 0.9 -> 5.1%,
now collective-dominated); granite 18.5 -> 10.2 (10.0%); qwen110
132.5 -> 99.3 (14.0%).  **Confirmed.**

**it5 — H: the remaining qwen110 collective bulk is full-weight
all-gathers in the remat-backward (GSPMD loses TP alignment of cotangents
and gathers w_in/w_out over BOTH mesh axes, ~1.5 GiB each).**  Change: pin
the MLP hidden (B,S,F) to P(dp,None,model) — the constraint transposes onto
the cotangent, keeping the backward dx = dh @ w_out^T contraction aligned.
Measured: qwen110 memory 99.3 -> 43.9, coll 81.4 -> 34.3, roofline
**14.0 -> 31.6%**; granite -> 11.9%.  **Confirmed** (the single biggest
win; one line per matmul family).

**it6 — same hypothesis applied to attention output o.**  qwen110 coll
34.3 -> 24.6 (roofline 33.3%); granite coll 6.6 -> 4.9 (12.2%).
**Confirmed.**

**it7 — H: hubert's 0.76 s collective term is FSDP weight gathers at
inference.**  Change: serving weights replicate across DP when a TP shard
is < 4 GiB (`SERVE_FSDP_THRESHOLD_BYTES`).  Measured: 763 -> 761 ms.
**Refuted**: the term is the per-layer Megatron TP output all-reduces
(2 x (B,S,D) per layer), inherent to running a 1 B encoder TP=16 wide.
Finding recorded: the right deployment for this arch is fewer chips per
replica (elastic serve supports it); kept the weight-replication change
anyway (it is strictly better and removes gather latency).

**Stopping rule**: it8 candidates (remat policy tuning, loss-block
chunking, decode cache layouts) each napkin-mathed < 5% on the dominant
terms of the three cells; with it6/it7 below 5% too, iteration stops per
the assignment's 3-consecutive-<5% rule (it4->it5->it6 were the last >=5%
steps on their respective cells).

### Summary: paper-faithful baseline vs beyond-paper optimized

| cell | baseline roofline | optimized roofline | dominant at stop |
|---|---|---|---|
| qwen1.5-110b x train_4k | 8.1% | **33.3%** | memory (CPU-fusion-inflated; analytic TPU-fusion estimate in S notes) |
| granite-3-8b x train_4k | 5.0% | **12.2%** | memory |
| hubert-xlarge x prefill_32k | 0.8% | **5.2%** | collective (TP-width mismatch; see it7) |

(Values re-confirmed from the final full-table sweep; the framework-wide
best cells after optimization: qwen1.5-110b x prefill_32k 38.0%,
gemma2-27b x prefill_32k 34.4%, gemma-7b x prefill_32k 29.7%.)

The optimized sharding/dtype rules apply framework-wide (every cell in the
optimized table benefits, not just the three hillclimbed cells).

### Notes on the remaining gap

- The dominant memory terms are CPU-fusion upper bounds: e.g. the
  qwen110 per-layer fp32 elementwise chains (norms, softmax, residuals)
  count ~6 reads+writes on TPU-fusable ops.  A TPU-fusion-style analytic
  estimate (weights-stream + 4 bytes/elt activation traffic) puts the
  memory term at or below the compute term for the train cells — i.e. the
  TPU-expected operating point is compute-bound at roughly
  `useful x 100%` of roofline (~77% for qwen110, ~63% for granite),
  with the collective term overlapped behind the MXU via XLA latency
  hiding (our terms assume zero overlap).
- Decode cells: the memory term equals cache-bytes/HBM-BW within 2x —
  decode is already at its bandwidth roofline; the lever there is cache
  compression (int8 KV via `kernels/ckpt_codec`) — future work.

## S Multi-pod

Every runnable cell also compiles on the 2x16x16 (512-chip) mesh (table
above), proving the "pod" axis shards: batch DP spans (pod, data), FSDP
stays within a pod, and the collective schedule introduces no cross-pod
all-to-alls for the default layout.  The roofline table is single-pod per
the assignment.
"""
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(doc)
    print("wrote EXPERIMENTS.md",
          f"({n_ok} ok / {n_skip} skip / {n_fail} fail dry-run cells; "
          f"{len(base)} baseline, {len(opt)} optimized roofline rows)")


if __name__ == "__main__":
    sys.exit(main())
