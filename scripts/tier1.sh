#!/usr/bin/env bash
# Tier-1 verify: the command ROADMAP.md pins, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."
# the SDC suite is part of tier 1 (tests/test_sdc.py end-to-end + unit,
# ABFT kernel-vs-oracle sweeps in tests/test_kernels.py); the full-tests
# run below collects it — fail loudly if it ever goes missing
test -f tests/test_sdc.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
