#!/usr/bin/env bash
# Tier-1 verify: the command ROADMAP.md pins, from any cwd.
#
#   scripts/tier1.sh            full tier-1 (what CI gates on)
#   scripts/tier1.sh --fast     skip tests marked `slow` (the multi-device
#                               E2E subprocesses) — ~4x faster inner loop
set -euo pipefail
cd "$(dirname "$0")/.."
# the SDC suite is part of tier 1 (tests/test_sdc.py end-to-end + unit,
# ABFT kernel-vs-oracle sweeps in tests/test_kernels.py); the full-tests
# run below collects it — fail loudly if it ever goes missing
test -f tests/test_sdc.py
# the elastic failover suite likewise (tests/test_elastic_loop.py)
test -f tests/test_elastic_loop.py
# and the serving-engine suite (tests/test_serve.py; its multi-replica E2E
# cases carry the `slow` marker, so --fast skips them)
test -f tests/test_serve.py
# and the delta-checkpoint suite (tests/test_delta.py chain/GC/bit-exact
# coverage + block_hash kernel sweeps in tests/test_kernels.py)
test -f tests/test_delta.py
# and the chaos scenario suite (tests/test_chaos.py: schema/driver/sim
# units + the compound-trace E2Es, which carry the `slow` marker)
test -f tests/test_chaos.py
# and the telemetry suite (tests/test_obs.py: bus/metrics/timeline units
# + the record-and-replay round trip)
test -f tests/test_obs.py
# and the elastic 3D mesh suite (tests/test_elastic_3d.py: grid/MoE
# degradation/sim units + the (2,2,2) host-kill E2E, marked `slow`)
test -f tests/test_elastic_3d.py
# and the telemetry-plane suite (tests/test_telemetry.py: wire/merge/
# detector/policy units + the straggle-then-kill E2Es, marked `slow`)
test -f tests/test_telemetry.py
# and the paged-KV-cache suite (tests/test_paged.py: allocator/refcount
# units, paged-vs-slot bit-identity, prefix sharing + the 100-stream
# flash-crowd failover E2E, marked `slow`; paged kernel sweeps live in
# tests/test_kernels.py)
test -f tests/test_paged.py
ARGS=()
for a in "$@"; do
  if [ "$a" = "--fast" ]; then
    ARGS+=(-m "not slow")
  else
    ARGS+=("$a")
  fi
done
# ${ARGS[@]+...}: expanding an empty array under `set -u` is an error on
# bash < 4.4 (stock macOS) — guard the no-argument invocation
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  exec python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}
