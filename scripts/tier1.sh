#!/usr/bin/env bash
# Tier-1 verify: the command ROADMAP.md pins, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
