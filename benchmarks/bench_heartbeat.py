"""Heartbeat failure-detection latency vs heartbeat period (paper SII:
deteccao por batimentos via UDP).

The monitor now measures its own last-beat -> declaration latency
(``HeartbeatMonitor.detection_latency``, exposed on the obs registry) —
the benchmark reads that instead of re-deriving the number from callback
wall-clocks, so what it reports is exactly what the telemetry layer feeds
the Young/Daly D term."""
from __future__ import annotations

import time
from typing import List

from repro.core import HeartbeatEmitter, HeartbeatMonitor
from repro.obs import Observability


def main(trials: int = 3) -> List[str]:
    rows = []
    print("# heartbeat detection latency (UDP loopback)")
    for period in (0.02, 0.05, 0.1):
        obs = Observability()
        lat = []
        for _ in range(trials):
            detected = {}
            mon = HeartbeatMonitor(
                num_hosts=2, period=period, timeout_factor=4.0,
                on_failure=lambda h: detected.setdefault(h, time.time()),
                obs=obs,
            ).start()
            ems = [HeartbeatEmitter(i, mon.addr, period).start()
                   for i in range(2)]
            time.sleep(8 * period)          # establish liveness
            t_fail = time.time()
            ems[1].pause()                  # fail-stop host 1
            while 1 not in detected and time.time() - t_fail < 5:
                time.sleep(period / 4)
            # the monitor's own measurement: last accepted beat ->
            # declaration (slightly tighter than pause -> callback, which
            # also pays the callback dispatch)
            lat.append(mon.detection_latency.get(
                1, detected.get(1, time.time()) - t_fail))
            for e in ems:
                e.stop()
            mon.stop()
        mean = sum(lat) / len(lat)
        hist = obs.registry.histogram("heartbeat.detection_latency_ms",
                                      host=1)
        print(f"period={period*1e3:.0f}ms: detect latency "
              f"mean={mean*1e3:.0f}ms p50={hist.p50:.0f}ms (timeout=4x, "
              f"{hist.count} samples on the registry)")
        rows.append(f"heartbeat_p{int(period*1e3)}ms,{mean*1e6:.0f},"
                    f"timeout_factor=4")
    return rows


if __name__ == "__main__":
    main()
