"""Serving-engine benchmark: throughput, latency percentiles, failover.

Three numbers matter (docs/serving.md):
  - continuous-batching throughput: decode tok/s and prefill tok/s through
    the engine (vs the request-at-a-time floor the slot pool replaces);
  - request latency: p50/p99 time-to-first-token and total latency over a
    request sweep (CPU timings are shape, not TPU performance — same
    caveat as bench_kernels);
  - failover recovery time: with 2 replicas and one killed mid-decode via
    ``FaultInjector.schedule_replica_kill``, the gap between the kill and
    the first retried request's first token on the survivor.

Emits machine-readable ``BENCH_serve.json``.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Dict, List

import jax


def write_json(results: Dict[str, float],
               path: str = "BENCH_serve.json") -> str:
    path = os.environ.get("BENCH_SERVE_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def main() -> List[str]:
    from repro.core import FaultInjector
    from repro.models import get_config, init_params
    from repro.serve import ServeEngine, pctl

    rows: List[str] = []
    results: Dict[str, float] = {}
    cfg = get_config("granite-3-8b", tiny=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt_len, gen, n_req = 16, 16, 8
    prompts = [[int(t) for t in jax.random.randint(
        jax.random.PRNGKey(100 + i), (prompt_len,), 0, cfg.vocab_size)]
        for i in range(n_req)]

    # ---- throughput + latency: 1 replica, continuous batching ----
    eng = ServeEngine(cfg, params, num_replicas=1, slots_per_replica=4,
                      max_len=prompt_len + gen, fault_tolerant=False)
    # warm the compiles outside the timed window
    warm = eng.submit(prompts[0], 2)
    eng.run()
    assert warm in eng.results()
    for p in prompts:
        eng.submit(p, gen)
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    lat = eng.request_latencies()[1:]            # drop the warmup request
    eng.shutdown()
    dec_tokens = sum(len(v) for v in res.values()) - 2  # minus warmup
    tok_s = dec_tokens / wall
    ttft = [t for _, t, _ in lat]
    total = [t for _, _, t in lat]
    p50, p99 = statistics.median(total), pctl(total, 0.99)
    print(f"continuous batching ({cfg.name} tiny, {n_req} req x "
          f"{prompt_len}+{gen} tok, 4 slots): {tok_s:.0f} tok/s decode, "
          f"prefill {n_req * prompt_len / wall:.0f} tok/s amortized")
    print(f"latency: ttft p50={statistics.median(ttft) * 1e3:.0f}ms  "
          f"total p50={p50 * 1e3:.0f}ms p99={p99 * 1e3:.0f}ms")
    rows.append(f"serve_decode_tok_s,{tok_s:.1f},")
    rows.append(f"serve_latency_p50_ms,{p50 * 1e3:.1f},"
                f"p99_ms={p99 * 1e3:.1f}")
    results["decode_tok_s"] = tok_s
    results["prefill_tok_s"] = n_req * prompt_len / wall
    results["latency_p50_ms"] = p50 * 1e3
    results["latency_p99_ms"] = p99 * 1e3
    results["ttft_p50_ms"] = statistics.median(ttft) * 1e3

    # ---- failover: kill 1 of 2 replicas mid-decode ----
    inj = FaultInjector()
    inj.schedule_replica_kill(4, replica_id=1)
    eng = ServeEngine(cfg, params, num_replicas=2, slots_per_replica=2,
                      max_len=prompt_len + gen, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      fault_injector=inj)
    for p in prompts:
        eng.submit(p, gen)
    res = eng.run()
    fail_t = next(e["t"] for e in eng.events
                  if e["event"] == "replica_failed")
    retried = set(eng.scheduler.retried_rids)
    assert retried and not eng.scheduler.failed_rids
    # recovery = kill -> first retried request streaming again
    first_retry_tok = min(eng.scheduler.requests[r].t_first_token
                          for r in retried)
    recovery_s = first_retry_tok - fail_t
    eng.shutdown()
    print(f"failover: killed 1/2 replicas, {len(retried)} requests "
          f"re-executed, 0 dropped; recovery to first retried token "
          f"{recovery_s * 1e3:.0f}ms")
    rows.append(f"serve_failover_recovery_ms,{recovery_s * 1e3:.1f},"
                f"retried={len(retried)}")
    results["failover_recovery_ms"] = recovery_s * 1e3
    results["failover_retried"] = float(len(retried))
    results["failover_dropped"] = 0.0

    path = write_json(results)
    print(f"(machine-readable: {path})")
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
