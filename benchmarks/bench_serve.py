"""Serving-engine benchmark: throughput, latency percentiles, failover,
and the paged-vs-legacy concurrency sweep.

Four numbers matter (docs/serving.md):
  - continuous-batching throughput: decode tok/s and prefill tok/s through
    the engine (vs the request-at-a-time floor the slot pool replaces);
  - request latency: p50/p99 time-to-first-token and total latency over a
    request sweep (CPU timings are shape, not TPU performance — same
    caveat as bench_kernels);
  - failover recovery time: with 2 replicas and one killed mid-decode via
    ``FaultInjector.schedule_replica_kill``, the gap between the kill and
    the first retried request's first token on the survivor;
  - the concurrency sweep (8/32/128 streams): paged vs legacy slot pool
    AT EQUAL MEMORY — a ``max_len``-sized slot pool caps concurrency at
    its slot count, while the same bytes repaged as 16-token blocks carry
    100+ short streams, with prefix sharing on top.  Per mode and stream
    count: aggregate decode tok/s, TTFT p50/p99, prefix-hit rate, and the
    peak concurrent in-flight streams actually sustained.

Emits machine-readable ``BENCH_serve.json``.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Dict, List

import jax


def write_json(results: Dict[str, float],
               path: str = "BENCH_serve.json") -> str:
    path = os.environ.get("BENCH_SERVE_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def main() -> List[str]:
    from repro.core import FaultInjector
    from repro.models import get_config, init_params
    from repro.serve import ServeEngine, pctl

    rows: List[str] = []
    results: Dict[str, float] = {}
    cfg = get_config("granite-3-8b", tiny=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt_len, gen, n_req = 16, 16, 8
    prompts = [[int(t) for t in jax.random.randint(
        jax.random.PRNGKey(100 + i), (prompt_len,), 0, cfg.vocab_size)]
        for i in range(n_req)]

    # ---- throughput + latency: 1 replica, continuous batching ----
    eng = ServeEngine(cfg, params, num_replicas=1, slots_per_replica=4,
                      max_len=prompt_len + gen, fault_tolerant=False)
    # warm the compiles outside the timed window
    warm = eng.submit(prompts[0], 2)
    eng.run()
    assert warm in eng.results()
    for p in prompts:
        eng.submit(p, gen)
    t0 = time.perf_counter()
    res = eng.run()
    wall = time.perf_counter() - t0
    lat = eng.request_latencies()[1:]            # drop the warmup request
    eng.shutdown()
    dec_tokens = sum(len(v) for v in res.values()) - 2  # minus warmup
    tok_s = dec_tokens / wall
    ttft = [t for _, t, _ in lat]
    total = [t for _, _, t in lat]
    p50, p99 = statistics.median(total), pctl(total, 0.99)
    print(f"continuous batching ({cfg.name} tiny, {n_req} req x "
          f"{prompt_len}+{gen} tok, 4 slots): {tok_s:.0f} tok/s decode, "
          f"prefill {n_req * prompt_len / wall:.0f} tok/s amortized")
    print(f"latency: ttft p50={statistics.median(ttft) * 1e3:.0f}ms  "
          f"total p50={p50 * 1e3:.0f}ms p99={p99 * 1e3:.0f}ms")
    rows.append(f"serve_decode_tok_s,{tok_s:.1f},")
    rows.append(f"serve_latency_p50_ms,{p50 * 1e3:.1f},"
                f"p99_ms={p99 * 1e3:.1f}")
    results["decode_tok_s"] = tok_s
    results["prefill_tok_s"] = n_req * prompt_len / wall
    results["latency_p50_ms"] = p50 * 1e3
    results["latency_p99_ms"] = p99 * 1e3
    results["ttft_p50_ms"] = statistics.median(ttft) * 1e3

    # ---- failover: kill 1 of 2 replicas mid-decode ----
    inj = FaultInjector()
    inj.schedule_replica_kill(4, replica_id=1)
    eng = ServeEngine(cfg, params, num_replicas=2, slots_per_replica=2,
                      max_len=prompt_len + gen, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      fault_injector=inj)
    for p in prompts:
        eng.submit(p, gen)
    res = eng.run()
    fail_t = next(e["t"] for e in eng.events
                  if e["event"] == "replica_failed")
    retried = set(eng.scheduler.retried_rids)
    assert retried and not eng.scheduler.failed_rids
    # recovery = kill -> first retried request streaming again
    first_retry_tok = min(eng.scheduler.requests[r].t_first_token
                          for r in retried)
    recovery_s = first_retry_tok - fail_t
    eng.shutdown()
    print(f"failover: killed 1/2 replicas, {len(retried)} requests "
          f"re-executed, 0 dropped; recovery to first retried token "
          f"{recovery_s * 1e3:.0f}ms")
    rows.append(f"serve_failover_recovery_ms,{recovery_s * 1e3:.1f},"
                f"retried={len(retried)}")
    results["failover_recovery_ms"] = recovery_s * 1e3
    results["failover_retried"] = float(len(retried))
    results["failover_dropped"] = 0.0

    # ---- concurrency sweep: paged vs legacy at equal memory ----
    # the budget where slots cap out: 16 slots x 256-token rows.  The
    # paged pool gets the SAME bytes (16 * 256 / 16 + 1 pages); short
    # 8+8-token streams hold 2 pages worst-case instead of a whole row,
    # and 4 prompt templates shared across streams exercise the prefix
    # cache (exact repeats skip prefill entirely).
    sweep_max_len, sweep_slots = 256, 16
    sweep_plen, sweep_gen = 8, 8
    templates = [[int(t) for t in jax.random.randint(
        jax.random.PRNGKey(500 + i), (sweep_plen,), 0, cfg.vocab_size)]
        for i in range(4)]

    def sweep_run(paged: bool, n_streams: int) -> Dict[str, float]:
        eng = ServeEngine(cfg, params, num_replicas=1,
                          slots_per_replica=sweep_slots,
                          max_len=sweep_max_len, fault_tolerant=False,
                          sentinel=False, max_pending=max(256, n_streams),
                          max_prefill_per_step=32, paged=paged,
                          max_active=(128 if paged else None))
        warm = eng.submit(templates[0], 2)       # compile outside timing
        eng.run()
        eng.drain_finished()
        assert warm is not None
        rids = [eng.submit(list(templates[i % len(templates)]), sweep_gen)
                for i in range(n_streams)]
        peak = 0
        t0 = time.perf_counter()
        while not eng.scheduler.all_done():
            eng.step()
            peak = max(peak, len(eng.scheduler.in_flight()))
        wall = time.perf_counter() - t0
        res = eng.results()
        assert len(res) == n_streams and not eng.scheduler.failed_rids
        lat = [t for r, t, _ in eng.request_latencies() if r in set(rids)]
        hits = misses = 0
        if paged:
            pool = eng.router.replicas[0].pool
            hits, misses = pool.prefix_hits, pool.prefix_misses
            ok, detail = pool.audit()
            assert ok, detail
        eng.shutdown()
        return {"tok_s": n_streams * sweep_gen / wall,
                "ttft_p50_ms": statistics.median(lat) * 1e3,
                "ttft_p99_ms": pctl(lat, 0.99) * 1e3,
                "peak_concurrency": float(peak),
                "prefix_hit_rate": (hits / (hits + misses)
                                    if hits + misses else 0.0)}

    sweep: Dict[str, Dict[str, float]] = {}
    for mode, paged in (("legacy", False), ("paged", True)):
        for n in (8, 32, 128):
            r = sweep_run(paged, n)
            sweep[f"{mode}_{n}"] = r
            print(f"sweep {mode:6s} {n:3d} streams "
                  f"({sweep_slots} slots x {sweep_max_len} tok budget): "
                  f"{r['tok_s']:.0f} tok/s, peak "
                  f"{r['peak_concurrency']:.0f} concurrent, ttft "
                  f"p50={r['ttft_p50_ms']:.0f}ms "
                  f"p99={r['ttft_p99_ms']:.0f}ms, prefix hits "
                  f"{r['prefix_hit_rate']:.0%}")
            for k, v in r.items():
                results[f"sweep_{mode}_{n}_{k}"] = v
    # the acceptance claims, pinned where the numbers are produced: the
    # paged pool sustains 100+ concurrent streams at the memory budget
    # where the slot pool caps out at 16, and matches or beats the slot
    # pool's throughput at the slot pool's own best concurrency
    legacy_best = max(sweep[f"legacy_{n}"]["tok_s"] for n in (8, 32, 128))
    assert sweep["paged_128"]["peak_concurrency"] >= 100, sweep
    assert max(sweep[f"legacy_{n}"]["peak_concurrency"]
               for n in (8, 32, 128)) <= sweep_slots
    assert sweep["paged_128"]["tok_s"] >= legacy_best, sweep
    rows.append(f"serve_sweep_paged_128_tok_s,"
                f"{sweep['paged_128']['tok_s']:.1f},"
                f"legacy_best={legacy_best:.1f}")
    rows.append(f"serve_sweep_paged_128_peak,"
                f"{sweep['paged_128']['peak_concurrency']:.0f},"
                f"legacy_cap={sweep_slots}")

    path = write_json(results)
    print(f"(machine-readable: {path})")
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
