"""SDC-guard overhead: what does each detection tier cost per step?

Three numbers matter (docs/sdc.md):
  - tier 1 ABFT: algorithmic overhead of the checksum-extended matmul vs a
    plain matmul (jitted jnp pipelines — CPU interpret-mode kernel timings
    are not TPU performance, same caveat as bench_kernels).
  - tier 2 scrub: per-step cost of the rotating checksum pass, as a
    fraction of the measured train-step time, at several scrub fractions —
    the amortization curve (target: <5% at the default fraction).
  - tier 3 sentinel: host-side metric check (should be ~free).

Emits machine-readable ``BENCH_sdc.json`` (name -> us_per_call).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

STEPS = 6


def _time(fn, *args, reps=10):
    """Best-of timing: CPU XLA matmul runs are noisy under thread churn."""
    fn(*args)  # compile/warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def write_json(results: Dict[str, float], path: str = "BENCH_sdc.json") -> str:
    path = os.environ.get("BENCH_SDC_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def main() -> List[str]:
    rows: List[str] = []
    results: Dict[str, float] = {}
    k = jax.random.PRNGKey(0)

    # ---- tier 1: ABFT matmul vs plain matmul (algorithmic overhead) ----
    from repro.kernels.abft_matmul.ops import verify_and_correct
    from repro.kernels.abft_matmul.ref import abft_matmul_ref

    n = 512
    a = jax.random.normal(k, (n, n))
    b = jax.random.normal(jax.random.fold_in(k, 1), (n, n))
    plain = jax.jit(lambda x, y: jnp.dot(x, y,
                                         preferred_element_type=jnp.float32))
    abft = jax.jit(lambda x, y: verify_and_correct(abft_matmul_ref(x, y))[0])
    t_plain = _time(plain, a, b)
    t_abft = _time(abft, a, b)
    # paper eq.-(2) overhead convention, (M_with - M_without) / M_with —
    # same as CheckpointPolicy.fault_free_overhead and the scrub % below
    ov = (t_abft - t_plain) / t_abft
    print(f"abft_matmul {n}x{n}: plain={t_plain:.0f}us "
          f"abft={t_abft:.0f}us overhead={ov * 100:.1f}%")
    rows.append(f"sdc_abft_matmul_{n},{t_abft:.0f},plain_us={t_plain:.0f}")
    results[f"abft_matmul_{n}"] = t_abft
    results[f"plain_matmul_{n}"] = t_plain

    # ---- tier 2: scrub cost vs train-step time (amortization curve) ----
    from repro.data import make_pipeline
    from repro.models import get_config
    from repro.sdc import StateScrubber
    from repro.train import init_state, make_train_step

    cfg = get_config("granite-3-8b", tiny=True)
    step_fn = jax.jit(make_train_step(cfg, total_steps=STEPS + 1))
    state = init_state(cfg, jax.random.PRNGKey(0))
    data = make_pipeline(cfg, 16, 4)
    state, _ = step_fn(state, data.next_batch())   # compile outside timing
    t0 = time.perf_counter()
    for _ in range(STEPS):
        state, m = step_fn(state, data.next_batch())
        jax.block_until_ready(m["loss"])
    step_us = (time.perf_counter() - t0) / STEPS * 1e6
    print(f"train step ({cfg.name} tiny): {step_us:.0f}us")
    rows.append(f"sdc_train_step,{step_us:.0f},")
    results["train_step"] = step_us

    for fraction in (0.25, 1.0):
        scr = StateScrubber(fraction=fraction)
        # warm one full rotation: each distinct leaf subset jits its own
        # batched reduction, cached from the second rotation on
        for s in range(int(1 / fraction) + 1):
            scr.record(state, s)
        t0 = time.perf_counter()
        for s in range(STEPS):
            scr.verify(state)
            scr.record(state, s)
        scrub_us = (time.perf_counter() - t0) / STEPS * 1e6
        pct = scrub_us / (step_us + scrub_us) * 100
        print(f"scrub f={fraction}: {scrub_us:.0f}us/step "
              f"({pct:.2f}% of the guarded step)")
        rows.append(f"sdc_scrub_f{fraction},{scrub_us:.0f},pct={pct:.2f}")
        results[f"scrub_f{fraction}"] = scrub_us

    # ---- tier 2b: scrub throughput on a big state ----
    # the tiny-model % above is dispatch-bound; at scale the reduction
    # dominates, and overhead = fraction * state_bytes / (tput * step_s)
    big = {f"w{i}": jax.random.normal(jax.random.fold_in(k, i), (1 << 20,))
           for i in range(16)}                      # 64 MB, 16 leaves
    jax.block_until_ready(big)
    scr = StateScrubber(fraction=1.0)
    scr.record(big, 0)
    t0 = time.perf_counter()
    for s in range(STEPS):
        scr.record(big, s)
    full_us = (time.perf_counter() - t0) / STEPS * 1e6
    gbps = (64 / 1024) / (full_us / 1e6)
    print(f"scrub 64MB full pass: {full_us:.0f}us ({gbps:.1f} GB/s)")
    rows.append(f"sdc_scrub_64MB,{full_us:.0f},GBps={gbps:.1f}")
    results["scrub_64MB_full"] = full_us

    # ---- tier 3: sentinel (host-side, per step) ----
    from repro.sdc import LossSentinel

    sent = LossSentinel()
    t0 = time.perf_counter()
    reps = 10_000
    for i in range(reps):
        sent.observe(i, 2.0, grad_norm=1.0, nonfinite=0.0)
    sent_us = (time.perf_counter() - t0) / reps * 1e6
    print(f"sentinel observe: {sent_us:.3f}us")
    rows.append(f"sdc_sentinel,{sent_us:.3f},")
    results["sentinel_observe"] = sent_us

    path = write_json(results)
    print(f"(machine-readable: {path})")
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
