"""Training-loop throughput +- the dependability layer on a tiny LM (CPU).

The LM twin of the FWI overhead experiment: tokens/s with no protection,
sync every-N checkpoints, and async checkpoints."""
from __future__ import annotations

import statistics
import tempfile
import time
from typing import List

import jax

from repro.core import Dependability, DependabilityConfig, run_bsp
from repro.data import make_pipeline
from repro.models import get_config
from repro.train import init_state, make_train_step


def main(steps: int = 30) -> List[str]:
    cfg = get_config("granite-3-8b", tiny=True)
    seq, gb = 128, 8
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    rows = []
    results = {}
    for name, dep_cfg in [
        ("none", None),
        ("sync_n5", dict(policy_mode="every_n", every_n=5, async_save=False)),
        ("async_n5", dict(policy_mode="every_n", every_n=5, async_save=True)),
    ]:
        state = init_state(cfg, jax.random.PRNGKey(0))
        data = make_pipeline(cfg, seq, gb)
        # warmup
        state, _ = step_fn(state, data.peek_batch())
        t0 = time.perf_counter()
        if dep_cfg is None:
            for _ in range(steps):
                state, m = step_fn(state, data.next_batch())
            jax.block_until_ready(m["loss"])
        else:
            with tempfile.TemporaryDirectory() as d:
                dep = Dependability(DependabilityConfig(
                    checkpoint_dir=d, signal_detection=False,
                    **dep_cfg)).start()
                dep.register_local_state(data)
                state, _, _ = run_bsp(dep, step_fn, state, data,
                                      steps + 1, final_save=False)
                dep.stop()
        wall = time.perf_counter() - t0
        tps = steps * seq * gb / wall
        results[name] = wall
        print(f"throughput[{name}]: {tps:,.0f} tok/s wall={wall:.2f}s")
        rows.append(f"train_throughput_{name},{wall/steps*1e6:.0f},"
                    f"tokens_per_s={tps:.0f}")
    for name in ("sync_n5", "async_n5"):
        ov = (results[name] - results["none"]) / results[name]
        print(f"overhead[{name}] = {ov*100:.2f}%  (paper FWI: ~1.4%)")
    return rows


if __name__ == "__main__":
    main()
