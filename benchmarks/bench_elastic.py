"""Elastic 3D reshard latency: dp-only vs tp-repartition vs expert-drop.

Times ``reshard_state`` — restore the latest checkpoint onto a DIFFERENT
mesh — for each of the three degradation paths the 3D refactor added
(docs/elastic.md "3D meshes"), against the pre-refactor 2D baseline:

  - ``baseline_2d``: (2, 2) "data"/"model" survivor mesh, the path every
    PR up to the 3D refactor shipped;
  - ``dp_only``:  (2,2,2) -> (1,2,2) — batch axis shrinks, tp/ep intact
    (the 3D equivalent of the baseline; MUST NOT be slower);
  - ``tp_repartition``: (2,2,2) -> (2,1,2) — every "model"-sharded leaf
    is re-partitioned (concat across the old tp group);
  - ``expert_drop``: (2,2,2) -> (2,2,1) — the expert axis folds away
    (params keep full shapes; the router masks the dead experts).

Two state sizes show the scaling.  Needs 8 host devices, so the
measurement runs in a child process with XLA_FLAGS set (the parent —
``benchmarks/run.py`` — keeps the default single device).  Emits
machine-readable ``BENCH_elastic.json`` (override: BENCH_ELASTIC_JSON).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
REPEATS = 5


def write_json(results: Dict[str, float],
               path: str = "BENCH_elastic.json") -> str:
    path = os.environ.get("BENCH_ELASTIC_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def _worker() -> None:
    import dataclasses
    import tempfile
    import time

    import jax
    import numpy as np

    from repro.core import (CheckpointManager, MeshSpec, reshard_state,
                            survivor_mesh, survivor_mesh3d)
    from repro.models import get_config
    from repro.train import init_state

    key = jax.random.PRNGKey(0)
    tiny = get_config("mixtral-8x7b", tiny=True)
    bigger = dataclasses.replace(tiny, name="mixtral-8x7b-tiny-x4",
                                 d_model=128, d_ff=256, num_layers=4)
    results: Dict[str, float] = {}

    for label, cfg in (("tiny", tiny), ("x4", bigger)):
        state = init_state(cfg, key)
        size_mb = sum(np.prod(l.shape) * l.dtype.itemsize
                      for l in jax.tree.leaves(state)) / 2 ** 20
        like = jax.eval_shape(lambda c=cfg: init_state(c, key))
        with tempfile.TemporaryDirectory() as d:
            manager = CheckpointManager(d)
            manager.save(0, state, blocking=True)

            devices = jax.devices()
            targets = {
                "baseline_2d": (survivor_mesh(devices[:4], model_axis=2),
                                False),
                "dp_only": (survivor_mesh3d(
                    devices[:4], MeshSpec.from_config(
                        cfg, data=1, model=2, expert=2)), None),
                "tp_repartition": (survivor_mesh3d(
                    devices[:4], MeshSpec.from_config(
                        cfg, data=2, model=1, expert=2)), None),
                "expert_drop": (survivor_mesh3d(
                    devices[:4], MeshSpec.from_config(
                        cfg, data=2, model=2, expert=1)), None),
            }
            for path_name, (mesh, moe_ep) in targets.items():
                best = float("inf")
                for _ in range(REPEATS):
                    t0 = time.perf_counter()
                    out, _local, _step = reshard_state(manager, cfg, mesh,
                                                       like, moe_ep=moe_ep)
                    jax.block_until_ready(out)
                    best = min(best, time.perf_counter() - t0)
                results[f"{label}.{path_name}_ms"] = round(best * 1e3, 3)
            manager.close()
        results[f"{label}.state_mb"] = round(size_mb, 3)

        base = results[f"{label}.baseline_2d_ms"]
        dp = results[f"{label}.dp_only_ms"]
        results[f"{label}.dp_vs_baseline"] = round(dp / base, 3)
        print(f"{label:5s} state {size_mb:6.2f} MB: "
              f"2d={base:.1f}ms dp={dp:.1f}ms "
              f"tp={results[f'{label}.tp_repartition_ms']:.1f}ms "
              f"ep={results[f'{label}.expert_drop_ms']:.1f}ms "
              f"(dp/2d={results[f'{label}.dp_vs_baseline']:.2f}x)")
    path = write_json(results)
    print(f"(machine-readable results: {path})")


def main() -> List[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    p = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--worker"], env=env, capture_output=True, text=True)
    sys.stdout.write(p.stdout)
    if p.returncode != 0:
        raise RuntimeError(f"bench_elastic worker failed:\n{p.stderr}")

    path = os.environ.get("BENCH_ELASTIC_JSON", "BENCH_elastic.json")
    with open(path) as f:
        results = json.load(f)
    rows = [f"elastic_reshard_{k.replace('.', '_')},{v * 1e3:.1f},"
            for k, v in sorted(results.items()) if k.endswith("_ms")]
    # acceptance: the dp-only path must not regress vs the 2D baseline
    # (x2 tolerance absorbs timer noise on ~ms restores)
    for label in ("tiny", "x4"):
        ratio = results[f"{label}.dp_vs_baseline"]
        if ratio > 2.0:
            raise AssertionError(
                f"dp-only reshard regressed vs the 2D baseline on {label}: "
                f"{ratio:.2f}x")
    return rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        main()
