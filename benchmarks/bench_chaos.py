"""Chaos-engine benchmark: replay the scenario library at cluster scale.

Every trace in ``scenarios/*.json`` runs through the control-plane
simulator (``repro.chaos.sim``) at 1000 virtual hosts — the scale the
acceptance bar names — and the compound trace additionally sweeps fleet
sizes.  Reported per trace (docs/chaos.md):

  - wall time and us/host-tick (the simulator must stay cheap enough to
    sweep: 1000 hosts x a full trace well under a minute);
  - failure-detection latency p50/p99 (kill -> monitor declares dead) —
    the recovery-latency distribution of the control plane itself;
  - stale-datagram rejections (every one delivered must be rejected);
  - invariant pass rates (no-dead-growth, monotonic-drain, conservation,
    Young/Daly cadence vs the closed form).

Emits machine-readable ``BENCH_chaos.json``.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List

SCENARIO_DIR = os.path.join(os.path.dirname(__file__), "..", "scenarios")
NUM_HOSTS = 1000


def write_json(results: Dict[str, float],
               path: str = "BENCH_chaos.json") -> str:
    path = os.environ.get("BENCH_CHAOS_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def main() -> List[str]:
    from repro.chaos import ControlPlaneSim, Scenario

    rows: List[str] = []
    results: Dict[str, float] = {}
    paths = sorted(glob.glob(os.path.join(SCENARIO_DIR, "*.json")))
    if not paths:
        raise FileNotFoundError(f"no scenario traces in {SCENARIO_DIR}")

    total_wall = 0.0
    for path in paths:
        sc = Scenario.from_json(path)
        sim = ControlPlaneSim(NUM_HOSTS, base_rate=20, slots_per_host=4)
        t0 = time.perf_counter()
        rep = sim.run(sc)
        wall = time.perf_counter() - t0
        total_wall += wall
        d = rep.to_dict()
        host_ticks = NUM_HOSTS * rep.ticks
        us_tick = wall / host_ticks * 1e6
        print(f"{sc.name:16s} {NUM_HOSTS} hosts x {rep.ticks} ticks: "
              f"{wall * 1e3:6.1f} ms ({us_tick:.2f} us/host-tick)  "
              f"detected={d['detected']} "
              f"latency p50={d['detection_latency_p50']:.2f}s "
              f"p99={d['detection_latency_p99']:.2f}s  "
              f"stale {d['stale_rejected']}/{d['stale_delivered']} rejected"
              f"  invariants {d['invariant_pass_rate']:.0%}")
        rows.append(f"chaos_sim_{sc.name},{us_tick:.3f},"
                    f"detected={d['detected']}")
        for k in ("detected", "detection_latency_p50",
                  "detection_latency_p99", "grow_events", "stale_delivered",
                  "stale_rejected", "drained", "completed",
                  "invariant_pass_rate"):
            results[f"{sc.name}.{k}"] = float(d[k])
        results[f"{sc.name}.us_per_host_tick"] = round(us_tick, 3)
        if d["invariant_pass_rate"] < 1.0:
            raise AssertionError(
                f"{sc.name}: invariants failed: {d['invariants']}")

    # fleet-size sweep on the compound trace: detection latency must stay
    # flat (timeout-bound) while the Young/Daly interval shrinks ~1/sqrt(n)
    compound = Scenario.from_json(os.path.join(SCENARIO_DIR,
                                               "compound.json"))
    for n in (100, 1000, 4000):
        sim = ControlPlaneSim(n)
        t0 = time.perf_counter()
        rep = sim.run(compound)
        wall = time.perf_counter() - t0
        interval = rep.cadence[-1]["interval"]
        print(f"compound @ {n:5d} hosts: {wall * 1e3:6.1f} ms, "
              f"young/daly interval={interval} steps, "
              f"cadence_ok={rep.cadence_ok}")
        rows.append(f"chaos_sweep_{n},{wall / max(n * rep.ticks, 1) * 1e6:.3f},"
                    f"yd_interval={interval}")
        results[f"sweep.{n}.yd_interval"] = float(interval)
        results[f"sweep.{n}.wall_ms"] = round(wall * 1e3, 2)
    print(f"library total: {len(paths)} traces x {NUM_HOSTS} hosts in "
          f"{total_wall:.2f}s")
    results["library.total_seconds"] = round(total_wall, 3)
    path = write_json(results)
    print(f"(machine-readable results: {path})")
    return rows


if __name__ == "__main__":
    main()
