# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows after each section's human-readable report, and persists the
# checkpoint suite's rows to BENCH_checkpoint.json (name -> us_per_call)
# so the perf trajectory is tracked across PRs.
#
# ``--only NAME`` runs a single suite by its short name (e.g.
# ``python benchmarks/run.py --only chaos``).
from __future__ import annotations

import argparse
import os
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_chaos, bench_checkpoint, bench_elastic,
                            bench_heartbeat, bench_kernels, bench_obs,
                            bench_overhead_fwi, bench_sdc, bench_serve,
                            bench_telemetry, bench_throughput)
    suites = [
        ("overhead_fwi", "overhead_fwi (paper Fig.1-2, eq.2-3)",
         bench_overhead_fwi.main),
        ("checkpoint", "checkpoint cost + Young/Daly (eq.1)",
         bench_checkpoint.main),
        ("heartbeat", "heartbeat detection", bench_heartbeat.main),
        ("kernels", "kernels vs oracles", bench_kernels.main),
        ("sdc", "SDC guard overhead (docs/sdc.md)", bench_sdc.main),
        ("throughput", "train-loop throughput", bench_throughput.main),
        ("serve", "serving engine (docs/serving.md)", bench_serve.main),
        ("chaos", "chaos scenario replay (docs/chaos.md)",
         bench_chaos.main),
        ("elastic", "3D mesh reshard latency (docs/elastic.md)",
         bench_elastic.main),
        ("obs", "telemetry overhead (docs/observability.md)",
         bench_obs.main),
        ("telemetry", "telemetry plane (docs/observability.md)",
         bench_telemetry.main),
    ]
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=[s[0] for s in suites],
                    help="run a single suite by short name")
    args = ap.parse_args()
    if args.only:
        suites = [s for s in suites if s[0] == args.only]
    all_rows = []
    failed = 0
    for _, name, fn in suites:
        print(f"\n=== {name} ===", flush=True)
        try:
            rows = fn()
            all_rows.extend(rows or [])
        except Exception:
            failed += 1
            traceback.print_exc()
    print("\n=== CSV (name,us_per_call,derived) ===")
    for r in all_rows:
        print(r)
    for env, default in (("BENCH_CHECKPOINT_JSON", "BENCH_checkpoint.json"),
                         ("BENCH_SDC_JSON", "BENCH_sdc.json"),
                         ("BENCH_SERVE_JSON", "BENCH_serve.json"),
                         ("BENCH_CHAOS_JSON", "BENCH_chaos.json"),
                         ("BENCH_ELASTIC_JSON", "BENCH_elastic.json"),
                         ("BENCH_OBS_JSON", "BENCH_obs.json"),
                         ("BENCH_TELEMETRY_JSON", "BENCH_telemetry.json")):
        json_path = os.environ.get(env, default)
        if os.path.exists(json_path):  # written by the owning bench module
            print(f"(machine-readable results: {json_path})")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
