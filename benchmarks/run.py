# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows after each section's human-readable report, and persists the
# checkpoint suite's rows to BENCH_checkpoint.json (name -> us_per_call)
# so the perf trajectory is tracked across PRs.
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    sections = []
    from benchmarks import (bench_checkpoint, bench_heartbeat, bench_kernels,
                            bench_overhead_fwi, bench_sdc, bench_serve,
                            bench_throughput)
    suites = [
        ("overhead_fwi (paper Fig.1-2, eq.2-3)", bench_overhead_fwi.main),
        ("checkpoint cost + Young/Daly (eq.1)", bench_checkpoint.main),
        ("heartbeat detection", bench_heartbeat.main),
        ("kernels vs oracles", bench_kernels.main),
        ("SDC guard overhead (docs/sdc.md)", bench_sdc.main),
        ("train-loop throughput", bench_throughput.main),
        ("serving engine (docs/serving.md)", bench_serve.main),
    ]
    all_rows = []
    failed = 0
    for name, fn in suites:
        print(f"\n=== {name} ===", flush=True)
        try:
            rows = fn()
            all_rows.extend(rows or [])
        except Exception:
            failed += 1
            traceback.print_exc()
    print("\n=== CSV (name,us_per_call,derived) ===")
    for r in all_rows:
        print(r)
    for env, default in (("BENCH_CHECKPOINT_JSON", "BENCH_checkpoint.json"),
                         ("BENCH_SDC_JSON", "BENCH_sdc.json"),
                         ("BENCH_SERVE_JSON", "BENCH_serve.json")):
        json_path = os.environ.get(env, default)
        if os.path.exists(json_path):  # written by the owning bench module
            print(f"(machine-readable results: {json_path})")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
