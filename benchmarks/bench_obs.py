"""Telemetry-layer overhead (docs/observability.md).

Two numbers gate the obs design:

1. **bus cost**: ns per ``EventBus.emit`` (with and without a JSONL
   sink) and per histogram observation — the primitive everything else
   pays.
2. **instrumented train step**: the same ``run_bsp`` loop with and
   without an attached ``Observability``.  Per superstep the
   instrumentation adds one bus emit + one histogram observe (~ us)
   against a ~ms train step, so the delta must stay **under 2%** —
   enforced here and recorded in ``BENCH_obs.json`` (acceptance
   criterion, ISSUE 7; the same bar the paper holds its wrappers to,
   1.4% median overhead).
"""
from __future__ import annotations

import json
import os
import statistics
import tempfile
import time
from typing import Dict, List

OVERHEAD_BUDGET = 0.02      # instrumented-vs-bare train-step ceiling
#: a paired median below this is not "free instrumentation", it is a
#: broken measurement (the instrumented arm cannot beat bare by more
#: than noise) — fail the bench rather than report a nonsense number
OVERHEAD_FLOOR = -0.005


def write_json(results: Dict[str, float],
               path: str = "BENCH_obs.json") -> str:
    path = os.environ.get("BENCH_OBS_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def bench_bus(n: int = 200_000) -> Dict[str, float]:
    from repro.obs import EventBus, MetricsRegistry

    bus = EventBus()
    t0 = time.perf_counter()
    for i in range(n):
        bus.emit("bench", "tick", step=i)
    ns_emit = (time.perf_counter() - t0) / n * 1e9

    with tempfile.TemporaryDirectory() as d:
        sunk = EventBus()
        sunk.attach_jsonl(os.path.join(d, "events.jsonl"))
        t0 = time.perf_counter()
        for i in range(n // 4):
            sunk.emit("bench", "tick", step=i)
        ns_emit_jsonl = (time.perf_counter() - t0) / (n // 4) * 1e9
        sunk.close()

    hist = MetricsRegistry().histogram("bench.obs_ms")
    t0 = time.perf_counter()
    for i in range(n):
        hist.observe(float(i))
    ns_observe = (time.perf_counter() - t0) / n * 1e9
    return {"bus_emit_ns": ns_emit, "bus_emit_jsonl_ns": ns_emit_jsonl,
            "histogram_observe_ns": ns_observe}


def bench_train_overhead(steps: int = 40) -> Dict[str, float]:
    import jax

    from repro.core import Dependability, DependabilityConfig, run_bsp
    from repro.data import make_pipeline
    from repro.models import get_config
    from repro.obs import Observability
    from repro.train import init_state, make_train_step

    cfg = get_config("granite-3-8b", tiny=True)
    seq, gb = 128, 8
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))

    def run(obs) -> float:
        """Median superstep seconds for one run_bsp pass (checkpointing
        pushed past the horizon — only the loop + instrumentation are
        under test)."""
        state = init_state(cfg, jax.random.PRNGKey(0))
        data = make_pipeline(cfg, seq, gb)
        state, _ = step_fn(state, data.peek_batch())      # warm the jit
        with tempfile.TemporaryDirectory() as d:
            dep = Dependability(DependabilityConfig(
                checkpoint_dir=d, policy_mode="every_n",
                every_n=10 ** 9, signal_detection=False)).start()
            if obs is not None:
                dep.attach_obs(obs)
            _, _, hist = run_bsp(dep, step_fn, state, data, steps,
                                 final_save=False)
            dep.stop()
        # skip the first few records: scheduler noise settles
        return statistics.median(r["seconds"] for r in hist[3:])

    # PAIRED, INTERLEAVED measurement: each trial runs both arms back to
    # back (order alternating so neither arm systematically inherits a
    # warmer cache / throttled clock), and the reported fraction is the
    # median of the per-pair ratios.  Sequential min-of-arms measured the
    # machine's drift, not the instrumentation — BENCH_obs.json once
    # reported overhead_frac=-0.06, i.e. the instrumented run "won" by
    # 6% because it ran later on a warmed-up machine.
    pairs: List[float] = []
    bares: List[float] = []
    instrs: List[float] = []
    for trial in range(5):
        if trial % 2 == 0:
            b = run(None)
            i = run(Observability())
        else:
            i = run(Observability())
            b = run(None)
        bares.append(b)
        instrs.append(i)
        pairs.append((i - b) / b)
    bare_s = statistics.median(bares)
    instr_s = statistics.median(instrs)
    overhead = statistics.median(pairs)
    return {"bare_step_us": bare_s * 1e6,
            "instrumented_step_us": instr_s * 1e6,
            "overhead_frac": overhead}


def main() -> List[str]:
    rows: List[str] = []
    results: Dict[str, float] = {}

    bus = bench_bus()
    results.update(bus)
    print(f"bus emit: {bus['bus_emit_ns']:.0f} ns/event "
          f"({bus['bus_emit_jsonl_ns']:.0f} ns with JSONL sink); "
          f"histogram observe: {bus['histogram_observe_ns']:.0f} ns")
    rows.append(f"obs_bus_emit,{bus['bus_emit_ns'] / 1e3:.3f},ns_per_event="
                f"{bus['bus_emit_ns']:.0f}")
    rows.append(f"obs_bus_emit_jsonl,{bus['bus_emit_jsonl_ns'] / 1e3:.3f},"
                f"ns_per_event={bus['bus_emit_jsonl_ns']:.0f}")

    tr = bench_train_overhead()
    results.update(tr)
    ok = OVERHEAD_FLOOR <= tr["overhead_frac"] < OVERHEAD_BUDGET
    print(f"train step: bare={tr['bare_step_us']:.0f}us "
          f"instrumented={tr['instrumented_step_us']:.0f}us "
          f"-> overhead={tr['overhead_frac'] * 100:.2f}% "
          f"(valid range [{OVERHEAD_FLOOR * 100:.1f}%, "
          f"{OVERHEAD_BUDGET * 100:.0f}%): "
          f"{'OK' if ok else 'OUT OF RANGE'})")
    rows.append(f"obs_train_step_instrumented,{tr['instrumented_step_us']:.0f},"
                f"overhead_frac={tr['overhead_frac']:.4f}")
    results["overhead_budget"] = OVERHEAD_BUDGET
    results["overhead_floor"] = OVERHEAD_FLOOR
    results["within_budget"] = float(ok)

    path = write_json(results)
    print(f"(machine-readable results: {path})")
    if not ok:
        if tr["overhead_frac"] < OVERHEAD_FLOOR:
            raise RuntimeError(
                f"instrumented train step measured "
                f"{tr['overhead_frac'] * 100:.2f}% FASTER than bare — "
                f"below the {OVERHEAD_FLOOR * 100:.1f}% noise floor, the "
                "paired measurement itself is broken")
        raise RuntimeError(
            f"instrumented train step {tr['overhead_frac'] * 100:.2f}% over "
            f"bare exceeds the {OVERHEAD_BUDGET * 100:.0f}% telemetry "
            "budget")
    return rows


if __name__ == "__main__":
    main()
