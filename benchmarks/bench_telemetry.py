"""Telemetry-plane benchmarks (docs/observability.md, "Telemetry plane").

Three numbers gate the plane's design, persisted to BENCH_telemetry.json:

1. **collector merge throughput** (events/s): the (inc,seq) + offset +
   gap-accounting merge protocol driven directly through
   ``Collector.ingest`` — the ceiling on how much cluster telemetry one
   collector absorbs.
2. **precursor detection latency** (p50/p99 ms, + samples-to-detect):
   from the first anomalous step sample entering the collector to the
   ``on_precursor`` callback firing — the head start the proactive
   hooks get over the heartbeat timeout.
3. **proactive vs reactive recovery** on a scripted straggle-then-kill
   trace: the same fail-stop recovered (a) reactively from the policy's
   last cadence checkpoint vs (b) proactively, the drift detector's
   precursor forcing a checkpoint just before the kill.  Proactive
   recovery time must be STRICTLY lower (the acceptance criterion) and
   the shared invariant suite (no-lost-steps, trajectory-match,
   detect-before-act) must hold in both modes.
"""
from __future__ import annotations

import json
import os
import statistics
import tempfile
import time
from typing import Dict, List

#: straggle-then-kill script (steps): the host visibly degrades over
#: [STRAGGLE_AT, KILL_AT) and fail-stops at KILL_AT
STEPS = 24
CADENCE = 10                 # reactive checkpoint cadence (every_n)
STRAGGLE_AT = 14
KILL_AT = 19
STRAGGLE_FACTOR = 5.0


def write_json(results: Dict[str, float],
               path: str = "BENCH_telemetry.json") -> str:
    path = os.environ.get("BENCH_TELEMETRY_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def bench_merge_throughput(hosts: int = 4, datagrams_per_host: int = 250,
                           events_per_datagram: int = 100
                           ) -> Dict[str, float]:
    """Drive the full merge protocol through Collector.ingest directly
    (no UDP, no threads): events/s through ordering, offset mapping, gap
    accounting, and the merged append."""
    from repro.obs import Collector

    payloads = []
    for h in range(hosts):
        for s in range(datagrams_per_host):
            t = s * 0.05
            payloads.append((
                {"host": h, "inc": 1000.0 + h, "seq": s,
                 "t_send": t,
                 "events": [{"seq": s * events_per_datagram + i,
                             "t_mono": t + i * 1e-4, "t_wall": 0.0,
                             "subsystem": "bench", "kind": "tick",
                             "step": i}
                            for i in range(events_per_datagram)]},
                t + 0.002))
    col = Collector()
    t0 = time.perf_counter()
    for payload, t_recv in payloads:
        col.ingest(payload, t_recv=t_recv)
    dt = time.perf_counter() - t0
    col.stop()
    n = hosts * datagrams_per_host * events_per_datagram
    return {"merge_events_per_s": n / dt,
            "merge_datagram_us": dt / len(payloads) * 1e6}


def bench_detection_latency(trials: int = 50) -> Dict[str, float]:
    """Wall-clock latency from the first anomalous step sample entering
    the collector to the precursor callback, plus how many anomalous
    samples the drift detector needed."""
    from repro.obs import AnomalyEngine, Collector, StepTimeDriftDetector

    lat_ms: List[float] = []
    samples_needed: List[int] = []
    for trial in range(trials):
        fired = []
        eng = AnomalyEngine(
            detectors=[StepTimeDriftDetector()],
            on_precursor=lambda h, k, r: fired.append(
                time.perf_counter()))
        col = Collector(anomaly=eng)

        def dgram(seq: int, seconds: float):
            t = seq * 0.05
            return ({"host": 1, "inc": 1.0, "seq": seq, "t_send": t,
                     "events": [{"seq": seq, "t_mono": t, "t_wall": 0.0,
                                 "subsystem": "train", "kind": "step",
                                 "step": seq, "seconds": seconds}]},
                    t + 0.001)
        for s in range(10):                       # healthy baseline
            p, tr = dgram(s, 0.010)
            col.ingest(p, t_recv=tr)
        t_anom = time.perf_counter()
        n = 0
        for s in range(10, 30):                   # sustained 4x drift
            n += 1
            p, tr = dgram(s, 0.040)
            col.ingest(p, t_recv=tr)
            if fired:
                break
        col.stop()
        assert fired, "drift detector never fired on a 4x straggle"
        lat_ms.append((fired[0] - t_anom) * 1e3)
        samples_needed.append(n)
    lat_ms.sort()
    return {"detect_latency_p50_ms": statistics.median(lat_ms),
            "detect_latency_p99_ms":
                lat_ms[min(len(lat_ms) - 1, int(0.99 * len(lat_ms)))],
            "detect_samples": statistics.median(samples_needed)}


def _run_trace(proactive_mode: bool, *, cfg, step_fn, reference=False):
    """One straggle-then-kill pass; returns (history, rollback_steps,
    median_step_s, events)."""
    import jax

    from repro.core import (Dependability, DependabilityConfig,
                            FaultInjector, run_with_recovery)
    from repro.data import make_pipeline
    from repro.obs import (AnomalyEngine, Observability,
                           make_proactive_hook)
    from repro.train import init_state

    state = init_state(cfg, jax.random.PRNGKey(0))
    data = make_pipeline(cfg, 64, 4)
    jax.block_until_ready(step_fn(state, data.peek_batch()))  # warm the jit
    with tempfile.TemporaryDirectory() as d:
        dep = Dependability(DependabilityConfig(
            checkpoint_dir=d, policy_mode="every_n", every_n=CADENCE,
            signal_detection=False)).start()
        obs = Observability()
        dep.attach_obs(obs)
        dep.register_local_state(data)

        injector = None
        hook = None
        if not reference:
            # the scripted trace: visible degradation, then the kill;
            # the straggle extra scales off a MEASURED warm step (block —
            # async dispatch returns in us, the device work is the cost)
            injector = FaultInjector()
            t0 = time.perf_counter()
            jax.block_until_ready(step_fn(state, data.peek_batch()))
            base = time.perf_counter() - t0
            for s in range(STRAGGLE_AT, KILL_AT):
                injector.schedule_straggle(
                    s, (STRAGGLE_FACTOR - 1.0) * base)
            injector.schedule_failstop(KILL_AT)
        if proactive_mode:
            anomaly = AnomalyEngine()
            anomaly.attach(obs.bus)
            hook = make_proactive_hook(anomaly.risk_scores,
                                       threshold=0.5)

        state, info = run_with_recovery(
            dep, step_fn, state, data, STEPS, fault_injector=injector,
            like=state, proactive=hook)
        assert info["status"] == "done", info["status"]

        # run_with_recovery's history drops the pass the failure killed;
        # the bus kept every superstep record (train/step events)
        history = [dict(e.data) for e in obs.events("train", "step")]
        step_s = statistics.median(
            h["seconds"] for h in history if not h.get("straggler"))
        rollback = 0.0
        snap = obs.registry.histogram("train.rollback_depth").snapshot()
        if snap["count"]:
            rollback = snap["max"]
        events = obs.events()
        dep.stop()
        obs.close()
    return history, rollback, step_s, events


def bench_recovery_delta() -> Dict[str, float]:
    import jax

    from repro.chaos import (check_detect_before_act, check_no_lost_steps,
                             check_trajectory_match, verify)
    from repro.models import get_config
    from repro.train import make_train_step

    cfg = get_config("granite-3-8b", tiny=True)
    step_fn = jax.jit(make_train_step(cfg, total_steps=STEPS))

    ref_hist, _, _, _ = _run_trace(False, cfg=cfg, step_fn=step_fn,
                                   reference=True)
    re_hist, re_roll, re_step_s, re_events = _run_trace(
        False, cfg=cfg, step_fn=step_fn)
    pro_hist, pro_roll, pro_step_s, pro_events = _run_trace(
        True, cfg=cfg, step_fn=step_fn)

    # recovery time = rolled-back work replayed after the restore
    recovery_reactive_s = re_roll * re_step_s
    recovery_proactive_s = pro_roll * pro_step_s

    ref_losses = [h["loss"] for h in _dedup(ref_hist)]
    # the invariant suite holds in BOTH modes; detect->act only exists
    # in proactive mode (reactive runs no detectors)
    results = [
        check_no_lost_steps(_dedup(re_hist), STEPS),
        check_no_lost_steps(_dedup(pro_hist), STEPS),
        check_trajectory_match([h["loss"] for h in _dedup(re_hist)],
                               ref_losses, tol=0.0),
        check_trajectory_match([h["loss"] for h in _dedup(pro_hist)],
                               ref_losses, tol=0.0),
        check_detect_before_act(pro_events),
    ]
    verify(results)
    assert recovery_proactive_s < recovery_reactive_s, (
        f"proactive recovery ({recovery_proactive_s:.3f}s, rollback "
        f"{pro_roll:.0f} steps) not faster than reactive "
        f"({recovery_reactive_s:.3f}s, rollback {re_roll:.0f} steps)")
    proactive_saves = len(
        [e for e in pro_events
         if (e.subsystem, e.kind) == ("checkpoint", "proactive")])
    precursors = len(
        [e for e in pro_events if e.subsystem == "precursor"])
    return {"recovery_reactive_s": recovery_reactive_s,
            "recovery_proactive_s": recovery_proactive_s,
            "rollback_steps_reactive": re_roll,
            "rollback_steps_proactive": pro_roll,
            "proactive_saves": float(proactive_saves),
            "precursor_events": float(precursors)}


def _dedup(history: List[Dict]) -> List[Dict]:
    """check_no_lost_steps wants one {step, ...} record per superstep;
    recovery replays steps, so keep the LAST record of each step (the
    one whose loss the final trajectory contains)."""
    recs = {}
    for h in history:
        if "loss" in h:
            recs[h["step"]] = h
    return [recs[k] for k in sorted(recs)]


def main() -> List[str]:
    rows: List[str] = []
    results: Dict[str, float] = {}

    merge = bench_merge_throughput()
    results.update(merge)
    print(f"collector merge: {merge['merge_events_per_s']:,.0f} events/s "
          f"({merge['merge_datagram_us']:.1f} us/datagram)")
    rows.append(f"telemetry_merge,{merge['merge_datagram_us']:.3f},"
                f"events_per_s={merge['merge_events_per_s']:.0f}")

    det = bench_detection_latency()
    results.update(det)
    print(f"precursor detection: p50={det['detect_latency_p50_ms']:.3f}ms "
          f"p99={det['detect_latency_p99_ms']:.3f}ms "
          f"({det['detect_samples']:.0f} anomalous samples to fire)")
    rows.append(f"telemetry_detect,"
                f"{det['detect_latency_p50_ms'] * 1e3:.1f},"
                f"p99_ms={det['detect_latency_p99_ms']:.3f}")

    rec = bench_recovery_delta()
    results.update(rec)
    speedup = rec["recovery_reactive_s"] / max(rec["recovery_proactive_s"],
                                               1e-9)
    print(f"straggle-then-kill recovery: reactive="
          f"{rec['recovery_reactive_s']:.3f}s (rollback "
          f"{rec['rollback_steps_reactive']:.0f} steps) proactive="
          f"{rec['recovery_proactive_s']:.3f}s (rollback "
          f"{rec['rollback_steps_proactive']:.0f} steps) -> "
          f"{speedup:.1f}x faster; {rec['precursor_events']:.0f} "
          f"precursors, {rec['proactive_saves']:.0f} forced saves; "
          "invariants green both modes")
    rows.append(f"telemetry_recovery_proactive,"
                f"{rec['recovery_proactive_s'] * 1e6:.0f},"
                f"reactive_s={rec['recovery_reactive_s']:.3f}")

    path = write_json(results)
    print(f"(machine-readable results: {path})")
    return rows


if __name__ == "__main__":
    main()
