"""Checkpoint-cost benchmark: C (Young/Daly's cost term) vs state size.

Measures the legacy pipeline (single writer, per-file fsync, host-side numpy
int8 encode) against the fast path (on-device int8 encode before device_get,
pooled shard writers, batched fsync), plus the eq.-(1) optimal-period table.

Critical-path is measured in STEADY STATE: back-to-back async saves, where
each ``save()`` first drains the previous write (double-buffering) — exactly
the cost a BSP loop pays when the checkpoint period approaches the write
time.  The first save (cold jit/pool) is excluded.

Emits machine-readable ``BENCH_checkpoint.json`` (name -> us_per_call) so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import CheckpointManager
from repro.core.policy import SystemModel, young_daly_period

LEAVES = 8          # multi-leaf state: exercises shard-level parallelism
SAVES = 3           # timed steady-state saves (after one warmup)


def _state(mb: int):
    n = mb * 1024 * 1024 // 4 // LEAVES
    k = jax.random.PRNGKey(0)
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(k, i), (n,),
                                         jnp.float32)
              for i in range(LEAVES)}
    return {"params": params, "step": jnp.asarray(3, jnp.int32)}


def _churn(state, i):
    """Low-churn update between saves: ONE leaf of LEAVES moves (the
    optimizer-moment pattern — params/embeddings/frozen layers static)."""
    params = dict(state["params"])
    params["w0"] = params["w0"] + (1.0 + i)
    return {"params": params, "step": state["step"] + 1}


LOWCHURN_SAVES = 7      # enough samples for a stable median


def _measure_lowchurn(state, *, delta: bool, **mgr_kwargs):
    """Steady-state async saves with one leaf churning between saves.
    Returns (steady_critical_s, steady_bytes_per_save).  The critical path
    is the MEDIAN over the post-warmup saves: each save() drains the
    previous fsync, whose latency is the one noisy term on an otherwise
    deterministic path (a single slow flush would skew a mean)."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, **mgr_kwargs,
                                **(dict(delta=True, full_every=1_000_000)
                                   if delta else {}))
        mgr.save(0, state, blocking=False)          # warmup / delta base
        mgr.wait()
        on_path, stats = [], []
        for i in range(LOWCHURN_SAVES):
            state = _churn(state, i)
            jax.block_until_ready(state["params"]["w0"])
            t = time.perf_counter()
            stats.append(mgr.save(i + 1, state, blocking=False))
            on_path.append(time.perf_counter() - t)
        mgr.wait()
        nbytes = stats[-1].bytes_written            # steady-state save
        mgr.close()
    # settle: flush this config's dirty pages so the NEXT config's fsyncs
    # don't inherit ~200MB of queued writeback and measure the wrong thing
    os.sync()
    time.sleep(0.3)
    steady = sorted(on_path[1:])                    # save 1 compiles jits
    return steady[len(steady) // 2], nbytes


def _measure(state, *, async_mode: bool, **mgr_kwargs):
    """Returns (steady_critical_s, total_per_save_s, bytes_written)."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, **mgr_kwargs)
        stats = mgr.save(0, state, blocking=not async_mode)  # warmup (jit,
        mgr.wait()                                           # pool, page $)
        nbytes = stats.bytes_written
        on_path = []
        t0 = time.perf_counter()
        for i in range(SAVES):
            t = time.perf_counter()
            mgr.save(i + 1, state, blocking=not async_mode)
            on_path.append(time.perf_counter() - t)
        mgr.wait()
        total = (time.perf_counter() - t0) / SAVES
        mgr.close()
    # steady-state: from the 2nd save on, save() includes draining the
    # previous async write — the real per-checkpoint cost C
    crit = (sum(on_path[1:]) / len(on_path[1:])
            if async_mode and len(on_path) > 1 else sum(on_path) / len(on_path))
    return crit, total, nbytes


def write_json(results: Dict[str, float],
               path: str = "BENCH_checkpoint.json") -> str:
    path = os.environ.get("BENCH_CHECKPOINT_JSON", path)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return path


def main() -> List[str]:
    rows: List[str] = []
    results: Dict[str, float] = {}
    # (label, async, manager kwargs) — "legacy" rows emulate the old
    # pipeline: one writer thread, per-file fsync, host-side numpy encode
    legacy = dict(io_threads=1, fsync="per_file")
    fast = dict(io_threads=0, fsync="batch")
    configs = [
        ("raw_sync", False, dict(codec=None, **legacy)),
        ("raw_async", True, dict(codec=None, **legacy)),
        ("int8_async", True, dict(codec="int8", **legacy)),
        ("raw_async_pario", True, dict(codec=None, **fast)),
        ("int8dev_async_pario", True, dict(device_codec=True, **fast)),
    ]
    print(f"# checkpoint cost C vs size ({LEAVES} leaves, steady-state "
          f"critical path over {SAVES} back-to-back saves)")
    by_size: Dict[int, Dict[str, float]] = {}
    for mb in (8, 32, 128):
        state = _state(mb)
        jax.block_until_ready(state["params"])
        by_size[mb] = {}
        for label, async_mode, kwargs in configs:
            crit, total, nbytes = _measure(state, async_mode=async_mode,
                                           **kwargs)
            name = f"ckpt_{mb}MB_{label}"
            print(f"{name}: critical-path={crit*1e3:.1f}ms "
                  f"total={total*1e3:.1f}ms bytes={nbytes}")
            rows.append(f"{name},{crit*1e6:.0f},total_ms={total*1e3:.2f}")
            results[name] = round(crit * 1e6)
            by_size[mb][label] = crit
        old, new = by_size[mb]["int8_async"], by_size[mb]["int8dev_async_pario"]
        print(f"  -> fast path vs int8_async at {mb}MB: "
              f"{old*1e3:.1f}ms -> {new*1e3:.1f}ms ({old/max(new,1e-9):.1f}x)")

    print("# delta mode: steady-state cost under low churn (1 of "
          f"{LEAVES} leaves updates between saves — optimizer-only "
          "pattern)")
    for mb in (32, 128):
        state = _state(mb)
        jax.block_until_ready(state["params"])
        rows_d = {}
        # int8_full:    the legacy full int8 save (host encode, 1 writer,
        #               per-file fsync) — the baseline the delta acceptance
        #               target is measured against
        # int8dev_full: this repo's fastest full pipeline
        # int8dev_delta: same fast pipeline + dirty-block saves
        cfgs = [("int8_full", dict(codec="int8", **legacy), False),
                ("int8dev_full", dict(device_codec=True, **fast), False),
                ("int8dev_delta", dict(device_codec=True, **fast), True)]
        for label, kwargs, is_delta in cfgs:
            crit, nbytes = _measure_lowchurn(state, delta=is_delta, **kwargs)
            name = f"ckpt_lowchurn_{mb}MB_{label}"
            print(f"{name}: critical-path={crit*1e3:.1f}ms bytes={nbytes}")
            rows.append(f"{name},{crit*1e6:.0f},bytes={nbytes}")
            results[f"{name}_crit_us"] = round(crit * 1e6)
            results[f"{name}_bytes"] = int(nbytes)
            rows_d[label] = (crit, nbytes)
        dc, db = rows_d["int8dev_delta"]
        for base in ("int8_full", "int8dev_full"):
            fc, fb = rows_d[base]
            print(f"  -> delta vs {base} at {mb}MB: critical-path "
                  f"{fc*1e3:.1f}ms -> {dc*1e3:.1f}ms "
                  f"({fc/max(dc,1e-9):.1f}x), bytes {fb} -> {db} "
                  f"({fb/max(db,1):.1f}x)")

    print("# Young/Daly optimal period (eq. 1), C from measured sync cost")
    for nodes in (16, 256, 1024, 4096):
        sysm = SystemModel(num_nodes=nodes)
        for c in (5.0, 30.0, 120.0):
            t = young_daly_period(sysm.system_mtbf, c, sysm.restart_seconds,
                                  sysm.downtime_seconds)
            print(f"young_daly nodes={nodes} C={c}s -> T_opt={t:.0f}s "
                  f"({t/3600:.2f}h)")
            name = f"young_daly_n{nodes}_C{int(c)}"
            rows.append(f"{name},{t*1e6:.0f},hours={t/3600:.3f}")
            results[name] = round(t * 1e6)

    path = write_json(results)
    print(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    main()
