"""Checkpoint-cost benchmark: C (Young/Daly's cost term) vs state size,
sync vs async vs int8-compressed, plus the eq.-(1) optimal-period table."""
from __future__ import annotations

import tempfile
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CheckpointManager
from repro.core.policy import SystemModel, young_daly_period


def _state(mb: int):
    n = mb * 1024 * 1024 // 4
    k = jax.random.PRNGKey(0)
    return {"params": {"w": jax.random.normal(k, (n,), jnp.float32)},
            "step": jnp.asarray(3, jnp.int32)}


def main() -> List[str]:
    rows = []
    print("# checkpoint cost C vs size")
    for mb in (8, 32, 128):
        state = _state(mb)
        jax.block_until_ready(state["params"]["w"])
        for codec, async_mode in [(None, False), (None, True), ("int8", True)]:
            with tempfile.TemporaryDirectory() as d:
                mgr = CheckpointManager(d, codec=codec)
                t0 = time.perf_counter()
                stats = mgr.save(1, state, blocking=not async_mode)
                on_path = time.perf_counter() - t0   # BSP critical-path cost
                mgr.wait()
                total = time.perf_counter() - t0
                name = f"ckpt_{mb}MB_{'int8' if codec else 'raw'}" \
                       f"_{'async' if async_mode else 'sync'}"
                print(f"{name}: critical-path={on_path*1e3:.1f}ms "
                      f"total={total*1e3:.1f}ms bytes={stats.bytes_written or '-'}")
                rows.append(f"{name},{on_path*1e6:.0f},total_ms={total*1e3:.2f}")

    print("# Young/Daly optimal period (eq. 1), C from measured sync cost")
    for nodes in (16, 256, 1024, 4096):
        sysm = SystemModel(num_nodes=nodes)
        for c in (5.0, 30.0, 120.0):
            t = young_daly_period(sysm.system_mtbf, c, sysm.restart_seconds,
                                  sysm.downtime_seconds)
            print(f"young_daly nodes={nodes} C={c}s -> T_opt={t:.0f}s "
                  f"({t/3600:.2f}h)")
            rows.append(f"young_daly_n{nodes}_C{int(c)},{t*1e6:.0f},"
                        f"hours={t/3600:.3f}")
    return rows


if __name__ == "__main__":
    main()
