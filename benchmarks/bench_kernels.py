"""Kernel microbenchmarks: interpret-mode pallas vs pure-jnp oracle (CPU
timings are NOT TPU performance — correctness + plumbing cost only; the
TPU roofline lives in EXPERIMENTS.md S Roofline)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> List[str]:
    rows = []
    k = jax.random.PRNGKey(0)

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, H, hd = 1, 512, 4, 64
    q = jax.random.normal(k, (B, S, H, hd), jnp.float32)
    kk = jax.random.normal(k, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(k, (B, S, H, hd), jnp.float32)
    t_int = _time(lambda *a: flash_attention(*a, block_q=128, block_k=128,
                                             interpret=True), q, kk, v)
    t_ref = _time(jax.jit(attention_ref), q, kk, v)
    print(f"flash_attention S={S}: interpret={t_int:.0f}us ref={t_ref:.0f}us")
    rows.append(f"kernel_flash_attention,{t_int:.0f},ref_us={t_ref:.0f}")

    from repro.kernels.selective_scan.ops import selective_scan
    from repro.kernels.selective_scan.ref import selective_scan_ref
    Bq, Sq, Di, N = 1, 256, 128, 16
    x = jax.random.normal(k, (Bq, Sq, Di))
    dt = jax.nn.softplus(jax.random.normal(k, (Bq, Sq, Di))) * 0.1
    bm = jax.random.normal(k, (Bq, Sq, N))
    cm = jax.random.normal(k, (Bq, Sq, N))
    a = -jnp.exp(jax.random.normal(k, (Di, N)) * 0.2)
    h0 = jnp.zeros((Bq, Di, N))
    t_int = _time(lambda *s: selective_scan(*s, interpret=True),
                  x, dt, bm, cm, a, h0)
    t_ref = _time(jax.jit(selective_scan_ref), x, dt, bm, cm, a, h0)
    print(f"selective_scan S={Sq}: interpret={t_int:.0f}us ref={t_ref:.0f}us")
    rows.append(f"kernel_selective_scan,{t_int:.0f},ref_us={t_ref:.0f}")

    from repro.kernels.ckpt_codec.ops import quantize
    from repro.kernels.ckpt_codec.ref import quantize_ref
    xq = jax.random.normal(k, (1 << 20,))
    t_int = _time(lambda s: quantize(s, interpret=True), xq)
    t_ref = _time(jax.jit(quantize_ref), xq)
    print(f"ckpt_codec 4MB: interpret={t_int:.0f}us ref={t_ref:.0f}us")
    rows.append(f"kernel_ckpt_codec,{t_int:.0f},ref_us={t_ref:.0f}")

    from repro.kernels.rmsnorm.ops import rms_norm
    from repro.kernels.rmsnorm.ref import rms_norm_ref
    xr = jax.random.normal(k, (1024, 1024), jnp.bfloat16)
    w = jnp.ones((1024,), jnp.float32)
    t_int = _time(lambda *s: rms_norm(*s, interpret=True), xr, w)
    t_ref = _time(jax.jit(rms_norm_ref), xr, w)
    print(f"rmsnorm 1Mx: interpret={t_int:.0f}us ref={t_ref:.0f}us")
    rows.append(f"kernel_rmsnorm,{t_int:.0f},ref_us={t_ref:.0f}")
    return rows


if __name__ == "__main__":
    main()
