"""Paper-reproduction benchmark: DeLIA overhead on the FWI 4D case study.

Reproduces the paper's experiment (Sec. IV-B/V): R runs of the FWI
application with and without the dependability layer, checkpointing the
global state EVERY iteration (the paper's setting, i.e. the eq.-3
max-overhead bound), then:

    overhead = (M_with - M_without) / M_with          (paper eq. 2)
    W_FF     = (T_FF - T_base) / T_FF                 (paper eq. 3)

Paper result on the NPAD cluster: median overhead ~1.4%, stddev inflation
~2x.  Beyond-paper rows: async double-buffered saves and int8-compressed
checkpoints, which shrink the same overhead.
"""
from __future__ import annotations

import statistics
import tempfile
import time
from typing import Dict, List

import jax

from repro.apps.fwi import FWIConfig, make_observed_data, run_fwi
from repro.core import Dependability, DependabilityConfig


def _timed_runs(cfg, d_obs, runs: int, dep_factory=None) -> List[float]:
    times = []
    for r in range(runs):
        dep = None
        ctx = None
        if dep_factory is not None:
            ctx = tempfile.TemporaryDirectory()
            dep = dep_factory(ctx.name)
        t0 = time.perf_counter()
        state, _ = run_fwi(cfg, d_obs, dep=dep)
        jax.block_until_ready(state["params"]["c"])
        if dep is not None:
            dep.manager.wait()
        times.append(time.perf_counter() - t0)
        if dep is not None:
            dep.stop()
        if ctx is not None:
            ctx.cleanup()
    return times


def main(runs: int = 5, iters: int = 8) -> List[str]:
    # Grid sized so the per-iteration time vs checkpoint cost ratio lands in
    # the paper's regime (their FWI iteration ~672 s vs save ~9 s; save cost
    # is latency-dominated here, so longer iterations match the C/T ratio).
    cfg = FWIConfig(nz=90, nx=90, nt=500, n_shots=4, iterations=iters)
    d_obs = make_observed_data(cfg)["baseline"]
    # warmup compile
    run_fwi(cfg, d_obs, iterations=1)

    def sync_dep(d):
        return Dependability(DependabilityConfig(
            checkpoint_dir=d, policy_mode="every_n", every_n=1,
            async_save=False, heartbeat=False, signal_detection=True)).start()

    def async_dep(d):
        return Dependability(DependabilityConfig(
            checkpoint_dir=d, policy_mode="every_n", every_n=1,
            async_save=True, heartbeat=False, signal_detection=True)).start()

    def int8_dep(d):
        return Dependability(DependabilityConfig(
            checkpoint_dir=d, policy_mode="every_n", every_n=1,
            async_save=True, codec="int8", heartbeat=False,
            signal_detection=True)).start()

    base = _timed_runs(cfg, d_obs, runs, None)
    rows = []
    m_base = statistics.median(base)
    print(f"# FWI overhead benchmark ({runs} runs x {iters} iters)")
    print(f"baseline: median={m_base:.3f}s stdev={statistics.pstdev(base):.4f}")
    for name, factory in [("delia_sync_every_iter", sync_dep),
                          ("delia_async_every_iter", async_dep),
                          ("delia_async_int8", int8_dep)]:
        ts = _timed_runs(cfg, d_obs, runs, factory)
        med = statistics.median(ts)
        overhead = (med - m_base) / med                      # eq. (2)
        w_ff = (med - m_base) / med                          # eq. (3) == here
        print(f"{name}: median={med:.3f}s stdev={statistics.pstdev(ts):.4f} "
              f"overhead={overhead*100:.2f}% (paper: ~1.4% for sync)")
        rows.append(f"fwi_overhead_{name},{med*1e6/iters:.1f},"
                    f"overhead_pct={overhead*100:.3f}")
    rows.insert(0, f"fwi_overhead_baseline,{m_base*1e6/iters:.1f},"
                   f"median_s={m_base:.4f}")
    return rows


if __name__ == "__main__":
    main()
