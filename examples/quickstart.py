"""Quickstart: DeLIA-protected LM training in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains a tiny granite-family LM on CPU with the full dependability stack
(Young/Daly checkpoint policy, async saves), then simulates a fail-stop at
step 12 and shows bit-exact recovery from the last checkpoint.
"""
import tempfile

import jax

from repro.core import (Dependability, DependabilityConfig, FaultInjector,
                        run_with_recovery)
from repro.data import make_pipeline
from repro.models import get_config
from repro.train import init_state, make_train_step


def main():
    cfg = get_config("granite-3-8b", tiny=True)
    steps = 20
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        dep = Dependability(DependabilityConfig(
            checkpoint_dir=ckpt_dir,
            policy_mode="every_n", every_n=4,
            async_save=True,
        )).start()

        data = make_pipeline(cfg, seq_len=64, global_batch=8)
        dep.register_local_state(data)            # DeLIA local state
        state = init_state(cfg, jax.random.PRNGKey(0))

        injector = FaultInjector()
        injector.schedule_failstop(12)

        def log(step, rec):
            print(f"step {step:3d}  loss={rec['loss']:.4f}  "
                  f"{rec['seconds']*1e3:6.1f} ms")

        state, info = run_with_recovery(
            dep, step_fn, state, data, steps,
            fault_injector=injector, like=state, on_metrics=log)

        print(f"\nstatus={info['status']}  restarts={info['restarts']}  "
              f"checkpoints={len(dep.save_history)}")
        print("final loss:",
              [h["loss"] for h in info["history"] if "loss" in h][-1])
        dep.stop()


if __name__ == "__main__":
    main()
