"""The paper's case study: DeLIA-protected 4D Full-Waveform Inversion —
with LOCAL-SCOPE checkpointing on, the configuration the paper could not
validate ("limitations in the original parallel computing module rendered
local-scope data checkpointing unfeasible").

    PYTHONPATH=src python examples/fwi_case_study.py

Inverts a baseline and a monitor survey (time-lapse pair) with the
dependability layer active, surviving an injected fail-stop.  Shots are
spread over DP shards; each shard's cursor + shot slice checkpoints to its
own ``local_s<k>.json`` file and remaps on restore (docs/elastic.md).
Reports the 4D difference image statistics + the measured checkpoint
overhead (the paper's eq.-2 metric).
"""
import tempfile
import time

import jax
import numpy as np

from repro.apps.fwi import (FWIConfig, make_observed_data, run_fwi,
                            true_models)
from repro.core import Dependability, DependabilityConfig, FaultInjector


def main():
    cfg = FWIConfig(nz=70, nx=70, nt=400, n_shots=3, iterations=14)
    dp_width = 3                       # one shot shard per simulated worker
    print("synthesizing observed data (baseline + monitor surveys)...")
    data = make_observed_data(cfg)

    results = {}
    for survey in ("baseline", "monitor"):
        with tempfile.TemporaryDirectory() as d:
            dep = Dependability(DependabilityConfig(
                checkpoint_dir=d, policy_mode="every_n", every_n=1,
                async_save=True)).start()
            injector = None
            if survey == "baseline":
                injector = FaultInjector()
                injector.schedule_failstop(6)
            t0 = time.perf_counter()
            state, hist = run_fwi(cfg, data[survey], dep=dep,
                                  fault_injector=injector,
                                  local_scope=True, dp_width=dp_width)
            wall = time.perf_counter() - t0
            shards = dep.manager.restore_local_shards(
                dep.manager.latest_step())
            assert len(shards) == dp_width, shards
            losses = [h["loss"] for h in hist if "loss" in h]
            print(f"{survey}: {len(losses)} iters, misfit "
                  f"{losses[0]:.2f} -> {losses[-1]:.2f}, {wall:.1f}s, "
                  f"local scope: {len(shards)} shard files"
                  + (" (recovered from fail-stop at iter 6)"
                     if injector else ""))
            results[survey] = np.asarray(state["params"]["c"])
            dep.stop()

    diff = results["monitor"] - results["baseline"]
    base_true, mon_true = true_models(cfg)
    true_diff = np.asarray(mon_true - base_true)
    anomaly = true_diff != 0
    print("\n4D difference image:")
    print(f"  mean |diff| inside true anomaly:  {np.abs(diff[anomaly]).mean():.2f} m/s")
    print(f"  mean |diff| outside true anomaly: {np.abs(diff[~anomaly]).mean():.2f} m/s")
    print(f"  (true anomaly: {true_diff.min():.0f} m/s in "
          f"{anomaly.sum()} cells)")


if __name__ == "__main__":
    main()
