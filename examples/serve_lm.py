"""Batched serving example: prefill a prompt batch, decode greedily.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models import get_config, init_cache, init_params
from repro.train import make_decode_step, make_prefill_step


def main():
    cfg = get_config("mixtral-8x7b", tiny=True)
    B, prompt_len, gen = 4, 24, 16
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, prompt_len + gen)

    prefill = jax.jit(make_prefill_step(cfg))
    # donate the KV cache: decode rewrites one slot per step, and without
    # donation every step copies the whole cache (launch/serve.py and the
    # serving engine donate it the same way)
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.perf_counter()
    tok, cache = prefill(params, {"tokens": prompts}, cache)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        tok, cache = decode(params, {"tokens": tok[:, None]}, cache)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen_tokens = jnp.stack(out, axis=1)
    print(f"prefill: {B}x{prompt_len} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {B}x{gen-1} tokens in {t_decode*1e3:.1f} ms "
          f"({B*(gen-1)/t_decode:.0f} tok/s)")
    print("generated ids[0]:", gen_tokens[0].tolist())


if __name__ == "__main__":
    main()
