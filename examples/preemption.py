"""Preemption-safe training: termination-signal detection (paper SII).

    PYTHONPATH=src python examples/preemption.py

Phase 1 trains until a SIGUSR1 arrives — the scheduler's "you're about to
be preempted" warning, replayed here from a chaos ``Scenario`` trace
(``preempt(at=9)`` compiled by ``TrainScenarioDriver``; see
docs/chaos.md).  DeLIA latches the signal, takes a final checkpoint at
the superstep boundary and exits cleanly.  Phase 2 relaunches and resumes
exactly where phase 1 stopped.
"""
import tempfile

import jax

from repro.chaos import Scenario, TrainScenarioDriver
from repro.core import Dependability, DependabilityConfig, run_bsp
from repro.data import make_pipeline
from repro.models import get_config
from repro.train import init_state, make_train_step


def main():
    cfg = get_config("gemma-7b", tiny=True)
    steps = 30
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))

    # the failure timeline as a declarative trace: the scheduler preempts
    # us at step 9 (the same JSON-able schema scenarios/*.json uses)
    scenario = Scenario("preempt-at-9").preempt(at=9)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        def make_dep():
            return Dependability(DependabilityConfig(
                checkpoint_dir=ckpt_dir, policy_mode="every_n",
                every_n=50,                 # rely on the FINAL save only
                signal_detection=True)).start()

        # ---- phase 1: preempted at step 9 ----
        dep = make_dep()
        data = make_pipeline(cfg, 64, 8)
        dep.register_local_state(data)
        state = init_state(cfg, jax.random.PRNGKey(0))

        driver = TrainScenarioDriver(scenario, settle_seconds=0)
        state, status, _ = run_bsp(dep, step_fn, state, data, steps,
                                   on_metrics=driver.on_metrics)
        print(f"phase 1: {status} (cause={dep.interruption_cause()}); "
              f"checkpoint at step {dep.manager.latest_step()}; "
              f"actions applied: {driver.report()['applied']}")
        dep.stop()

        # ---- phase 2: relaunch, resume ----
        dep = make_dep()
        data = make_pipeline(cfg, 64, 8)
        dep.register_local_state(data)
        template = jax.eval_shape(
            lambda: init_state(cfg, jax.random.PRNGKey(0)))
        state, got = dep.restore_latest(like=template)
        print(f"phase 2: resumed from step {got}")
        state, status, hist = run_bsp(dep, step_fn, state, data, steps)
        print(f"phase 2: {status} at step {int(jax.device_get(state['step']))},"
              f" final loss {[h['loss'] for h in hist][-1]:.4f}")
        dep.stop()


if __name__ == "__main__":
    main()
