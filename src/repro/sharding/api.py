"""Mesh context + sharding-constraint helpers.

A module-level mesh context lets model code express *logical* sharding
constraints that become no-ops when no mesh is active (unit tests, CPU smoke
runs) and resolve to NamedShardings on the production mesh (dry-run, train).
Axis names absent from the active mesh are silently dropped from specs, so the
same model code runs on (data, model), (pod, data, model) or no mesh at all.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = current_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def _filter_axes(mesh: Mesh, entry):
    """Drop axis names that don't exist in the mesh."""
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in mesh.axis_names else None
    # tuple of axis names
    kept = tuple(a for a in entry if a in mesh.axis_names)
    return kept if kept else None


def spec(*entries) -> P:
    return P(*entries)


def resolve(partition_spec: P, mesh: Optional[Mesh] = None):
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    filtered = P(*(_filter_axes(mesh, e) for e in partition_spec))
    return NamedSharding(mesh, filtered)


U = P.UNCONSTRAINED  # "leave this dim to GSPMD propagation"


def constrain(x, partition_spec: P):
    """with_sharding_constraint that degrades gracefully:
    - no active mesh -> identity;
    - axis names missing from the mesh -> dropped;
    - ``P.UNCONSTRAINED`` entries pass through (propagation decides);
    - tuple entries that don't divide fall back to a divisible suffix
      (("pod","data") -> ("data",)), then to UNCONSTRAINED — NEVER to
      replicated, which would silently materialize the full dim on every
      device."""
    mesh = current_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = (list(partition_spec)
               + [U] * (x.ndim - len(partition_spec)))
    fixed = []
    for dim, e in zip(x.shape, entries):
        if e is None or e is U:
            fixed.append(e)
            continue
        names = tuple(n for n in ((e,) if isinstance(e, str) else tuple(e))
                      if n in mesh.axis_names)
        while names:
            total = 1
            for n in names:
                total *= sizes[n]
            if dim % total == 0:
                break
            names = names[1:]  # drop the leading (outermost) axis
        if not names:
            fixed.append(U)
        elif len(names) == 1:
            fixed.append(names[0])
        else:
            fixed.append(names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
