from repro.sharding.api import (
    constrain,
    current_mesh,
    set_mesh,
    spec,
    mesh_context,
)
from repro.sharding.rules import (
    batch_spec,
    param_specs,
    state_specs,
    cache_specs,
    DP_AXES,
)

__all__ = [
    "constrain",
    "current_mesh",
    "set_mesh",
    "spec",
    "mesh_context",
    "batch_spec",
    "param_specs",
    "state_specs",
    "cache_specs",
    "DP_AXES",
]
