"""Logical sharding rules: parameter / activation / cache PartitionSpecs.

Layout (production mesh axes: optional "pod", "data", "model"):
- batch                 -> ("pod","data")   (pure DP; "pod" is extra DP)
- TP ("model")          -> attention heads, MLP hidden, vocab, SSM channels
- FSDP ("data")         -> the non-TP weight axis of every large matrix
- KV-cache sequence dim -> "model"          (decode sequence parallelism)
- MoE experts           -> replicated ("tp" mode, hidden-dim TP inside the
                           experts) or "model" ("ep" mode, when E % tp == 0)

Weights keep heads as separate tensor dims — (D, H, hd) instead of
(D, H*hd) — so head-axis sharding never fragments head_dim (uneven head
counts, e.g. 12 heads over tp=16, shard with GSPMD padding, which is honest:
the padded FLOPs appear in the per-device cost analysis).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Optional

import jax
from jax.sharding import PartitionSpec as P

if TYPE_CHECKING:  # avoid a package-level import cycle (models -> layers ->
    from repro.models.base import ModelConfig  # sharding.rules -> models)

DP_AXES = ("pod", "data")
FSDP = "data"
TP = "model"
EP = "expert"


def legal_tp_widths(cfg: "ModelConfig", max_width: int = 0) -> tuple:
    """Tensor-parallel widths the model reshards to EXACTLY: widths that
    divide both the (padded) head count and d_ff, so every "model"-sharded
    dim splits without GSPMD padding and checkpoint spans re-tile exactly
    across a tp change.  Always contains 1."""
    heads = cfg.effective_num_heads or 1
    dff = cfg.d_ff or heads
    lim = max_width or min(heads, dff)
    return tuple(w for w in range(1, lim + 1)
                 if heads % w == 0 and dff % w == 0)


def legal_dp_widths(cfg: "ModelConfig", max_width: int = 0) -> tuple:
    """Data-parallel (FSDP) widths the params reshard to EXACTLY: every
    FSDP-sharded dim in the spec tables is d_model-sized, so dp must
    divide d_model for ``device_put`` / checkpoint spans to split without
    padding.  Always contains 1."""
    dm = cfg.d_model or 1
    lim = max_width or dm
    return tuple(w for w in range(1, min(dm, lim) + 1) if dm % w == 0)


def batch_spec(ndim_after_batch: int = 1) -> P:
    return P(DP_AXES, *([None] * ndim_after_batch))


def res_spec(cfg: "ModelConfig") -> P:
    """Sharding of residual-stream activations (B,S,D): sequence-parallel
    over "model" when cfg.seq_shard (Megatron SP), else replicated past DP."""
    return P(DP_AXES, TP, None) if cfg.seq_shard else P(DP_AXES, None, None)


def gathered(cfg: "ModelConfig", h):
    """SP: re-gather the sequence dim before projections (no-op otherwise)."""
    if cfg.seq_shard:
        from repro.sharding.api import constrain
        return constrain(h, P(DP_AXES, None, None))
    return h


def _attn_specs(cfg: "ModelConfig", tp_size: int) -> dict:
    kv_shardable = tp_size == 0 or (cfg.num_kv_heads % max(tp_size, 1) == 0)
    kv = TP if kv_shardable else None
    s = {
        "wq": P(FSDP, TP, None),
        "wk": P(FSDP, kv, None),
        "wv": P(FSDP, kv, None),
        "wo": P(TP, None, FSDP),
    }
    if cfg.qkv_bias:
        s["bq"] = P(TP, None)
        s["bk"] = P(kv, None)
        s["bv"] = P(kv, None)
    return s


def _mlp_specs() -> dict:
    return {"w_in": P(FSDP, TP), "w_gate": P(FSDP, TP), "w_out": P(TP, FSDP)}


def _moe_specs(cfg: "ModelConfig", tp_size: int, ep) -> dict:
    """Expert-weight layout, three modes selected by ``ep``:

    - ``False``: TP inside the experts (hidden dim over "model").
    - ``True`` (legacy 2D): experts over "model" when E % tp == 0 — the
      whole model axis is repurposed as expert parallelism.
    - int >= 1 (3D mesh): experts over the dedicated "expert" axis AND
      hidden dim over "model" simultaneously.  On a mesh without an
      "expert" axis the EP entry filters away (sharding.api._filter_axes),
      degrading to the ``False`` layout — the same specs serve 2D and 3D.
    """
    if isinstance(ep, bool):
        if ep and tp_size and cfg.num_experts % tp_size == 0:
            e, tp = TP, None
        else:
            e, tp = None, TP
    else:
        e, tp = EP, TP
    return {
        "router": P(None, None),
        "w_in": P(e, FSDP, tp),
        "w_gate": P(e, FSDP, tp),
        "w_out": P(e, tp, FSDP),
    }


def _ssm_specs() -> dict:
    return {
        "in_proj": P(FSDP, TP),
        "conv_w": P(None, TP),
        "conv_b": P(TP),
        "x_proj": P(TP, None),
        "dt_w": P(None, TP),
        "dt_b": P(TP),
        "A_log": P(TP, None),
        "D": P(TP),
        "out_proj": P(TP, FSDP),
    }


def _rec_specs() -> dict:
    return {
        "x_proj": P(FSDP, TP),
        "gate_proj": P(FSDP, TP),
        "conv_w": P(None, TP),
        "conv_b": P(TP),
        "w_i": P(FSDP, TP),
        "b_i": P(TP),
        "w_r": P(FSDP, TP),
        "b_r": P(TP),
        "lam": P(TP),
        "out_proj": P(TP, FSDP),
    }


def layer_specs(cfg: "ModelConfig", kind: str, tp_size: int,
                moe_ep=False) -> dict:
    from repro.models.base import FULL, LOCAL, BIDIR, SSM, REC

    if kind in (FULL, LOCAL, BIDIR):
        s: dict = {"ln1": P(None), "ln2": P(None),
                   "attn": _attn_specs(cfg, tp_size)}
        if cfg.sandwich_norm:
            s["ln1_post"] = P(None)
            s["ln2_post"] = P(None)
        if cfg.num_experts:
            s["moe"] = _moe_specs(cfg, tp_size, moe_ep)
        else:
            s["mlp"] = _mlp_specs()
            if cfg.mlp_act not in ("silu", "gelu"):
                s["mlp"].pop("w_gate")
        return s
    if kind == SSM:
        return {"ln": P(None), "ssm": _ssm_specs()}
    if kind == REC:
        return {"ln1": P(None), "ln2": P(None), "rec": _rec_specs(),
                "mlp": _mlp_specs()}
    raise ValueError(kind)


def _prepend(tree, n: int = 1):
    return jax.tree.map(lambda p: P(*([None] * n), *p), tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: "ModelConfig", tp_size: int, moe_ep=False) -> dict:
    """PartitionSpec pytree matching ``model.init``'s parameter pytree."""
    specs: dict = {}
    if not cfg.embedding_inputs:
        specs["embed"] = {"tok": P(TP, None)}
    kinds = cfg.layer_kinds()
    if cfg.scan_layers:
        pattern = cfg.pattern
        specs["blocks"] = {
            f"l{p}": _prepend(layer_specs(cfg, pattern[p], tp_size, moe_ep))
            for p in range(len(pattern))
        }
    else:
        specs["layers"] = {
            f"layer_{i}": layer_specs(cfg, kinds[i], tp_size, moe_ep)
            for i in range(cfg.num_layers)
        }
    specs["final_norm"] = P(None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, TP)
    return specs


def cache_specs(cfg: "ModelConfig", tp_size: int) -> dict:
    """PartitionSpec tree matching the decode cache pytree (see models)."""
    from repro.models.base import FULL, LOCAL, BIDIR, SSM, REC

    def one(kind: str) -> dict:
        if kind in (FULL, LOCAL, BIDIR):
            # KV cache: (B, Sc, K, hd) — sequence dim sharded over model (SP
            # decode); batch over DP.
            return {"k": P(DP_AXES, TP, None, None),
                    "v": P(DP_AXES, TP, None, None),
                    "pos": P(None)}
        if kind == SSM:
            return {"conv": P(DP_AXES, None, TP), "h": P(DP_AXES, TP, None)}
        if kind == REC:
            return {"conv": P(DP_AXES, None, TP), "h": P(DP_AXES, TP)}
        raise ValueError(kind)

    kinds = cfg.layer_kinds()
    if cfg.scan_layers:
        return {"blocks": {f"l{p}": _prepend(one(cfg.pattern[p]))
                           for p in range(len(cfg.pattern))},
                "index": P()}
    return {"layers": {f"layer_{i}": one(kinds[i])
                       for i in range(cfg.num_layers)},
            "index": P()}


def state_specs(cfg: "ModelConfig", tp_size: int, moe_ep=False) -> dict:
    """Specs for the full TrainState pytree (params + opt moments + scalars)."""
    ps = param_specs(cfg, tp_size, moe_ep)
    return {
        "step": P(),
        "params": ps,
        "opt": {"m": ps, "v": ps, "count": P()},
        "rng": P(None),
    }
