from repro.kernels.rmsnorm import kernel, ops, ref  # noqa: F401
