"""Fused RMSNorm kernel (pl.pallas_call + BlockSpec VMEM tiling).

One HBM read + one write per element (vs separate square/mean/rsqrt/mul HLO
ops); rows tiled (ROWS x D) into VMEM, fp32 accumulation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

ROWS = 128


def _kernel(x_ref, w_ref, y_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                # (rows, D)
    var = jnp.mean(x * x, axis=1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    y_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(y_ref.dtype)


def rms_norm_2d(x, w, *, eps=1e-6, interpret=False):
    """x: (R, D); w: (D,) -> (R, D)."""
    R, D = x.shape
    rows = ROWS if R % ROWS == 0 else 1
    grid = (R // rows,)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)
