"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm_ref(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * w.astype(jnp.float32)).astype(x.dtype)
