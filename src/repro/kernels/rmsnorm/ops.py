"""Public RMSNorm wrapper (arbitrary leading dims)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rms_norm_2d


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rms_norm(x, w, *, eps=1e-6, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shape = x.shape
    y = rms_norm_2d(x.reshape(-1, shape[-1]), w, eps=eps, interpret=interpret)
    return y.reshape(shape)
