"""Public ABFT matmul wrappers: encode -> multiply -> verify -> correct.

``abft_matmul(a, b)`` returns the data product C plus a report of the
checksum verification.  A single corrupted output element e at (i, j)
shifts row-residual i and column-residual j by the same amount: the
intersection locates it and C[i,j] -= d corrects it in place — no
rollback.  Inconsistent or multiple residuals are flagged as detected but
uncorrectable (the caller falls back to checkpoint rollback).

Detection is thresholded: checksums ride through a different summation
order than the data, so residuals are compared against a tolerance scaled
by the row/column L1 mass (``rtol``) — corruption below fp accumulation
noise is indistinguishable from rounding and passes, which is the
standard ABFT trade on floating point.

``abft_dot`` is the layer-facing twin of ``x @ w`` (arbitrary leading
dims, silent single-error correction, result in x.dtype) used by the
``impl="abft"`` opt-in in layers/ and models/.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.abft_matmul.kernel import matmul_f32
from repro.kernels.abft_matmul.ref import encode_ref

TILE = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def verify_and_correct(c_full, *, rtol: float = 1e-4, atol: float = 1e-5,
                       correct: bool = True):
    """Verify an extended product; returns (c, report).

    report (jnp scalars, jit-friendly):
      detected    any residual above tolerance
      corrected   error isolated to one element (data or checksum) and,
                  for a data element, fixed in the returned c
      row, col    flagged coordinates (argmax residual; 0 when clean)
      delta       the correction magnitude applied at (row, col)
      bad_rows/bad_cols  residual counts (>1 of either => uncorrectable)
    """
    c = c_full[:-1, :-1]
    row_check = c_full[:-1, -1]          # row sums of C via the extension
    col_check = c_full[-1, :-1]          # column sums of C
    abs_c = jnp.abs(c)
    d_row = jnp.sum(c, axis=1) - row_check
    d_col = jnp.sum(c, axis=0) - col_check
    tol_row = atol + rtol * (jnp.sum(abs_c, axis=1) + jnp.abs(row_check))
    tol_col = atol + rtol * (jnp.sum(abs_c, axis=0) + jnp.abs(col_check))
    bad_row = jnp.abs(d_row) > tol_row
    bad_col = jnp.abs(d_col) > tol_col
    n_row = jnp.sum(bad_row)
    n_col = jnp.sum(bad_col)
    detected = (n_row + n_col) > 0
    i = jnp.argmax(jnp.abs(d_row) * bad_row)
    j = jnp.argmax(jnp.abs(d_col) * bad_col)
    # one data element hit: both residuals trip, with consistent magnitude
    single_data = ((n_row == 1) & (n_col == 1)
                   & (jnp.abs(d_row[i] - d_col[j]) <= tol_row[i] + tol_col[j]))
    # one checksum element hit: only its own residual trips; data is intact
    checksum_only = ((n_row == 1) & (n_col == 0)) | \
                    ((n_row == 0) & (n_col == 1))
    corrected = detected & (single_data | checksum_only)
    delta = jnp.where(single_data & correct, d_row[i], 0.0)
    c = c.at[i, j].add(-delta)
    report = {"detected": detected, "corrected": corrected,
              "row": i, "col": j, "delta": delta,
              "bad_rows": n_row, "bad_cols": n_col}
    return c, report


@functools.partial(jax.jit, static_argnames=("rtol", "atol", "correct",
                                             "inject", "interpret"))
def abft_matmul(a, b, *, rtol: float = 1e-4, atol: float = 1e-5,
                correct: bool = True,
                inject: Optional[Tuple[int, int, float]] = None,
                interpret: Optional[bool] = None):
    """a: (M, K), b: (K, N) -> (C (M, N) f32, report).

    ``inject=(i, j, delta)`` perturbs extended-product element (i, j)
    AFTER the multiply and BEFORE verification — the deterministic SDC
    hook the tests and bench use (i == M / j == N hit the checksums).
    """
    interpret = _default_interpret() if interpret is None else interpret
    M, K = a.shape
    N = b.shape[1]
    a_ext, b_ext = encode_ref(a, b)
    mp, np_, kp = (_round_up(M + 1, TILE), _round_up(N + 1, TILE),
                   _round_up(K, TILE))
    a_p = jnp.pad(a_ext, ((0, mp - M - 1), (0, kp - K)))
    b_p = jnp.pad(b_ext, ((0, kp - K), (0, np_ - N - 1)))
    c_full = matmul_f32(a_p, b_p, interpret=interpret)[:M + 1, :N + 1]
    if inject is not None:
        ii, jj, delta = inject
        c_full = c_full.at[ii, jj].add(delta)
    return verify_and_correct(c_full, rtol=rtol, atol=atol, correct=correct)


@jax.custom_vjp
def _abft_dot_2d(x2, w):
    c, _ = abft_matmul(x2, w)
    return c


def _abft_dot_fwd(x2, w):
    return _abft_dot_2d(x2, w), (x2, w)


def _abft_dot_bwd(res, g):
    # the backward contractions run through the same checksummed kernel —
    # a flipped gradient element is corrected before it reaches the update
    x2, w = res
    dx, _ = abft_matmul(g, w.T.astype(jnp.float32))
    dw, _ = abft_matmul(x2.T.astype(jnp.float32), g)
    return dx.astype(x2.dtype), dw.astype(w.dtype)


_abft_dot_2d.defvjp(_abft_dot_fwd, _abft_dot_bwd)


def abft_dot(x, w):
    """Drop-in checksummed ``x @ w``: x (..., K), w (K, N) -> (..., N).

    Computes in fp32 (checksums on half precision would drown in rounding),
    corrects a single corrupted output element silently, and returns in
    x.dtype.  Differentiable: the custom VJP routes both backward
    contractions through the checksummed kernel too.  Uncorrectable
    corruption propagates to the loss, where the tier-3 sentinel catches
    it.
    """
    shape = x.shape
    c = _abft_dot_2d(x.reshape(-1, shape[-1]), w)
    return c.reshape(shape[:-1] + (w.shape[-1],)).astype(x.dtype)
