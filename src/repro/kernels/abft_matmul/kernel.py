"""Checksum-extended matmul kernel (pl.pallas_call + BlockSpec MXU tiling).

Computes C_full = A_ext @ B_ext where the operands carry their ABFT
checksum row/column (see ref.py).  The checksums flow through the SAME
pallas_call / MXU path as the data, which is the point: a transient
compute error in any output tile perturbs the data and its checks
inconsistently and becomes detectable by the verifier in ops.py.

Standard 3-phase tiled matmul: grid (M/bm, N/bn, K/bk), fp32 accumulation
in the revisited output tile ("arbitrary" K dimension), zero-init on the
first K step.  ops.py pads the extended operands to tile multiples with
zeros (which contribute nothing to sums or products) and slices back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import CompilerParams

BM = 128
BN = 128
BK = 128


def _kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def matmul_f32(a, b, *, interpret=False):
    """a: (M, K) f32, b: (K, N) f32 -> (M, N) f32; M, N, K tile multiples."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(BM, M), min(BN, N), min(BK, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
