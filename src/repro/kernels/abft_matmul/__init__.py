from repro.kernels.abft_matmul.ops import (abft_dot, abft_matmul,
                                           verify_and_correct)
from repro.kernels.abft_matmul.ref import abft_matmul_ref, encode_ref

__all__ = ["abft_matmul", "abft_dot", "verify_and_correct",
           "abft_matmul_ref", "encode_ref"]
