"""Pure-jnp oracle for the ABFT checksum-extended matmul.

Huang & Abraham's algorithm-based fault tolerance, the form Bosilca et al.
apply to HPC linear algebra: extend A with a column-checksum row (the sum of
A's rows) and B with a row-checksum column (the sum of B's columns); one
multiply of the extended operands then yields C *and* its own row/column
checksums, computed *through* the same hardware path as the data.  Any
single corrupted output element perturbs exactly one row check and one
column check — their intersection locates it, their magnitude corrects it.
"""
from __future__ import annotations

import jax.numpy as jnp


def encode_ref(a, b):
    """(M,K),(K,N) -> checksum-extended (M+1,K),(K,N+1) in fp32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a_ext = jnp.concatenate([a, jnp.sum(a, axis=0, keepdims=True)], axis=0)
    b_ext = jnp.concatenate([b, jnp.sum(b, axis=1, keepdims=True)], axis=1)
    return a_ext, b_ext


def abft_matmul_ref(a, b):
    """Extended product C_full (M+1, N+1): data block C = C_full[:-1,:-1],
    column-checksum row C_full[-1,:-1], row-checksum column C_full[:-1,-1]."""
    a_ext, b_ext = encode_ref(a, b)
    return jnp.dot(a_ext, b_ext, preferred_element_type=jnp.float32)


def residuals_ref(c_full):
    """Row/column checksum residuals of an extended product.

    d_row[i] = sum_j C[i,j] - rowcheck[i]   (nonzero -> error in row i)
    d_col[j] = sum_i C[i,j] - colcheck[j]   (nonzero -> error in col j)
    """
    c = c_full[:-1, :-1]
    col_check = c_full[-1, :-1]     # checksum row: column sums of C
    row_check = c_full[:-1, -1]     # checksum col: row sums of C
    d_row = jnp.sum(c, axis=1) - row_check
    d_col = jnp.sum(c, axis=0) - col_check
    return d_row, d_col
