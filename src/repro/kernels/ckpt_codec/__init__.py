from repro.kernels.ckpt_codec import kernel, ops, ref  # noqa: F401
