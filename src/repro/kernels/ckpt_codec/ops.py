"""Public codec wrapper: arbitrary-shape arrays <-> int8 blocks + scales.

``quantize``/``dequantize`` are the fused flatten/pad/reshape wrappers around
the block kernels: any leaf shape is flattened, zero-padded to a BLOCK
multiple, viewed as (NB, BLOCK) and quantized in one jitted call — the
kernel itself additionally pads NB to a ROWS multiple, so *every* leaf hits
full-size grid tiles (no 1-row degradation for NB % 64 != 0).

``block_meta`` computes the static payload metadata (pad, block count) the
checkpoint manifest records for a given leaf shape.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.ckpt_codec.kernel import (BLOCK, dequantize_blocks,
                                             quantize_blocks)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def block_meta(shape):
    """Static (pad, n_blocks) of the packed payload for a leaf shape — the
    single source of truth for the pad rule (DeviceCodec and the jnp twin
    both route through it)."""
    size = math.prod(shape) if shape else 1
    pad = int((-size) % BLOCK)
    return pad, int((size + pad) // BLOCK)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x, *, interpret=None):
    """x: any shape/float dtype -> (q (NB,BLOCK) int8, scales (NB,) f32,
    static meta handled by caller via x.shape)."""
    interpret = _default_interpret() if interpret is None else interpret
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    q, s = quantize_blocks(blocks, interpret=interpret)
    return q, s[:, 0]


@functools.partial(jax.jit, static_argnames=("shape", "interpret"))
def dequantize(q, scales, shape, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    s = jnp.broadcast_to(scales[:, None], (scales.shape[0], 128))
    y = dequantize_blocks(q, s, interpret=interpret).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return y[:n].reshape(shape)
