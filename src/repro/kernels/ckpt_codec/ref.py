"""Pure-jnp oracle for the int8 block codec (mirrors repro/optim/compress.py
and repro/core/codec.py's numpy implementation)."""
from __future__ import annotations

import jax.numpy as jnp

BLOCK = 256


def quantize_blocks_ref(blocks):
    """(NB, BLOCK) f32 -> (q (NB, BLOCK) int8, scale (NB,) f32): the
    block-level oracle for kernel.quantize_blocks (any NB, incl. non
    ROWS-multiples)."""
    scale = jnp.abs(blocks).max(axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_ref(x):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return quantize_blocks_ref(flat.reshape(-1, BLOCK))


def dequantize_ref(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)
