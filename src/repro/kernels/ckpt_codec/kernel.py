"""int8 block quantize/dequantize kernels (pl.pallas_call + BlockSpec).

The paper-aligned kernel: DeLIA's dominant runtime cost is serializing the
application state (the Young/Daly C term).  Quantizing fp32 state to int8 +
per-block fp32 scales on-device shrinks the device->host snapshot and the
bytes the writer thread pushes to the parallel FS by ~3.9x.  The same codec
compresses DP gradients (repro/optim/compress.py is the jnp twin).

Layout: values are viewed as (n_blocks, BLOCK=256); each grid step processes
a (ROWS x BLOCK) VMEM tile, emitting int8 payloads and fp32 scales.  Block
counts that are not a ROWS multiple are zero-padded up to one (a zero block
quantizes to q=0 / scale=0) and sliced back after the call, so every grid
step runs the same full-size tile instead of degrading to 1-row tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

BLOCK = 256
ROWS = 64


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]                                    # (ROWS, BLOCK) f32
    amax = jnp.abs(x).max(axis=1, keepdims=True)      # (ROWS, 1)
    scale = amax / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.broadcast_to(scale, s_ref.shape)


def _dequant_kernel(q_ref, s_ref, y_ref):
    q = q_ref[...].astype(jnp.float32)
    y_ref[...] = q * s_ref[:, :1]


def _pad_rows(arrs, nb):
    """Zero-pad leading dim of each array from nb up to a ROWS multiple."""
    pad = (-nb) % ROWS
    if pad:
        arrs = [jnp.pad(a, ((0, pad), (0, 0))) for a in arrs]
    return arrs, nb + pad


def quantize_blocks(x, *, interpret=False):
    """x: (NB, BLOCK) f32 -> (q (NB, BLOCK) int8, scales (NB, 128) f32 —
    scale value broadcast across the lane dim; column 0 is canonical).

    Any NB is accepted: the grid always runs (ROWS x BLOCK) tiles over a
    zero-padded view, then slices back to NB rows."""
    nb = x.shape[0]
    (x,), nbp = _pad_rows([x], nb)
    grid = (nbp // ROWS,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 128), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nbp, 128), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    if nbp != nb:
        q, s = q[:nb], s[:nb]
    return q, s


def dequantize_blocks(q, scales, *, interpret=False):
    """q: (NB, BLOCK) int8, scales: (NB, 128) f32 -> (NB, BLOCK) f32.

    Like quantize_blocks, NB is padded to a ROWS multiple for the grid."""
    nb = q.shape[0]
    (q, scales), nbp = _pad_rows([q, scales], nb)
    grid = (nbp // ROWS,)
    y = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, BLOCK), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scales)
    return y[:nb] if nbp != nb else y
