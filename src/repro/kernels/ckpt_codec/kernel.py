"""int8 block quantize/dequantize kernels (pl.pallas_call + BlockSpec).

The paper-aligned kernel: DeLIA's dominant runtime cost is serializing the
application state (the Young/Daly C term).  Quantizing fp32 state to int8 +
per-block fp32 scales on-device shrinks the device->host snapshot and the
bytes the writer thread pushes to the parallel FS by ~3.9x.  The same codec
compresses DP gradients (repro/optim/compress.py is the jnp twin).

Layout: values are viewed as (n_blocks, BLOCK=256); each grid step processes
a (ROWS x BLOCK) VMEM tile, emitting int8 payloads and fp32 scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 256
ROWS = 64


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]                                    # (ROWS, BLOCK) f32
    amax = jnp.abs(x).max(axis=1, keepdims=True)      # (ROWS, 1)
    scale = amax / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.broadcast_to(scale, s_ref.shape)


def _dequant_kernel(q_ref, s_ref, y_ref):
    q = q_ref[...].astype(jnp.float32)
    y_ref[...] = q * s_ref[:, :1]


def quantize_blocks(x, *, interpret=False):
    """x: (NB, BLOCK) f32 -> (q (NB, BLOCK) int8, scales (NB, 128) f32 —
    scale value broadcast across the lane dim; column 0 is canonical)."""
    nb = x.shape[0]
    rows = ROWS if nb % ROWS == 0 else 1
    grid = (nb // rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows, 128), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb, 128), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)


def dequantize_blocks(q, scales, *, interpret=False):
    """q: (NB, BLOCK) int8, scales: (NB, 128) f32 -> (NB, BLOCK) f32."""
    nb = q.shape[0]
    rows = ROWS if nb % ROWS == 0 else 1
    grid = (nb // rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scales)
