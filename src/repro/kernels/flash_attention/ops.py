"""Public flash-attention wrapper: (B,S,H,hd) layout, GQA, interpret switch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "q_offset", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, q_offset=0, block_q=256, block_k=512,
                    interpret=None):
    """q: (B,S,H,hd); k,v: (B,Skv,K,hd) -> (B,S,H,hd).

    q_offset must be 0 (training/prefill); decode uses the jnp path."""
    assert q_offset == 0, "kernel path is for training/prefill only"
    interpret = _default_interpret() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)   # (B,H,S,hd)
    kt = jnp.swapaxes(k, 1, 2)   # (B,K,Sk,hd)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             softcap=softcap, scale=scale, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    return jnp.swapaxes(o, 1, 2)
