"""Pure-jnp oracle for the flash-attention kernel: exact softmax attention
with the same masking semantics (causal / window / softcap / GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
                  q_offset=0):
    """q: (B,S,H,hd); k,v: (B,Skv,K,hd) -> (B,S,H,hd); fp32 softmax."""
    B, S, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale else hd ** -0.5
    qq = (q.astype(jnp.float32) * scale).reshape(B, S, K, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qq, k.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = (jnp.arange(S, dtype=jnp.int32) + q_offset)[:, None]
    kpos = jnp.arange(Skv, dtype=jnp.int32)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (qpos - kpos < window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
