"""Flash attention forward kernel (pl.pallas_call + BlockSpec VMEM tiling).

Grid: (B, H, num_q_blocks, num_kv_blocks); the kv-block dim is the innermost
sequential ("arbitrary") dim so the online-softmax state (m, l, acc) lives in
VMEM scratch across kv iterations.  GQA is handled in the k/v index_map
(kv head = q head // group).  MXU work: (bq x hd) @ (hd x bk) and
(bq x bk) @ (bk x hd) per grid cell — block sizes default to 256/512 so both
matmul dims are 128-aligned.

Supports: causal, sliding window, attention-logit softcap, custom scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    iq = pl.program_id(2)
    if causal or window:
        qpos = iq * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]                                # (bq, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                               # (bq, bk)
    l_cur = l_prev * corr + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = jnp.broadcast_to(m_cur, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0, softcap=0.0,
                         scale=None, block_q=256, block_k=512,
                         interpret=False):
    """q: (B,H,S,hd); k,v: (B,K,Sk,hd).  Returns o: (B,H,S,hd)."""
    B, H, S, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale else hd ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, Sk)
    assert S % bq == 0 and Sk % bk == 0, (S, bq, Sk, bk)
    nq, nk = S // bq, Sk // bk

    grid = (B, H, nq, nk)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap,
                             bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
