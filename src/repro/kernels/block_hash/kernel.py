"""Per-block position-weighted mod-2^32 hash kernel (pl.pallas_call).

One pass over a leaf's storage words produces a hash per fixed-size block —
the primitive behind both incremental ("delta") checkpointing (a block
whose hash matches the last committed checkpoint never crosses the
device->host link) and the SDC scrubber's leaf checksums (a leaf checksum
is the mod-2^32 sum of its block hashes, so scrub and delta share one
reduction idiom; see repro/sdc/checksum.py).

The hash is the wraparound int32 sum of each word MULTIPLIED by an odd
per-position weight (2j+1 for word j within its block):

- single-bit upset: flips word j by ±2^k, changing the hash by
  ±2^k * (2j+1) — an odd multiple of 2^k, never 0 mod 2^32 — so the
  scrubber's single-flip guarantee holds exactly as with a plain sum;
- real state updates: a plain sum is permutation-invariant and blind to
  compensating changes (swap two words, or +d/-d pairs — easy to hit when
  e.g. two embedding rows trade places inside one block), which would make
  delta mode silently reference STALE parent blocks; position weights
  break that symmetry (a swap of unequal words w_a, w_b at j_a != j_b
  shifts the hash by 2(w_a-w_b)(j_a-j_b), zero only on a 2^31 alignment).

Zero padding (rows to a ROWS multiple, words to a WTILE multiple) is free
— zero words contribute nothing regardless of weight.

Layout: words are viewed as (NB, W); each grid step reduces a
(ROWS x WTILE) VMEM tile into a (ROWS, 128) accumulator tile (all lanes
carry the row sum; column 0 is canonical).  The word axis is "arbitrary"
so partial sums accumulate across its tiles; each tile derives its
weights from the global word index (j * WTILE + iota).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import CompilerParams

ROWS = 8        # block-hash rows per grid step (sublane tile)
WTILE = 2048    # words reduced per grid step along the word axis
LANES = 128


def _hash_kernel(w_ref, h_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    w = w_ref[...]
    # odd weight 2*(global word index)+1; int32 multiply/add wrap mod 2^32
    idx = j * WTILE + jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    part = jnp.sum(w * (2 * idx + 1), axis=1, keepdims=True)
    h_ref[...] += jnp.broadcast_to(part, h_ref.shape)


def hash_rows(w, *, interpret=False):
    """w: (NB, W) int32 word rows -> (NB,) int32 weighted row sums mod
    2^32.

    Any NB/W is accepted: rows are zero-padded to a ROWS multiple and the
    word axis to a WTILE multiple, then sliced back (zero words are
    sum-neutral)."""
    nb, width = w.shape
    padr = (-nb) % ROWS
    padw = (-width) % WTILE
    if padr or padw:
        w = jnp.pad(w, ((0, padr), (0, padw)))
    nbp, wp = nb + padr, width + padw
    grid = (nbp // ROWS, wp // WTILE)
    h = pl.pallas_call(
        _hash_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, WTILE), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, LANES), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(w)
    return h[:nb, 0]
