from repro.kernels.block_hash.ops import (BLOCK_ELEMS, block_hashes,
                                          checksum_words, words_view)

__all__ = ["BLOCK_ELEMS", "block_hashes", "checksum_words", "words_view"]
