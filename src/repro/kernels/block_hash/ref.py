"""Numpy oracle for the block-hash kernel (also the host-shard hasher).

Bit-identical to ops.words_view / ops.block_hashes: the same storage words,
the same wraparound mod-2^32 sums.  CheckpointManager uses this path
directly for shards that are already numpy arrays (no device round-trip).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.block_hash.ops import BLOCK_ELEMS


def words_np(arr: np.ndarray) -> np.ndarray:
    """Flat uint32 view of the array's storage words (matches
    ops.words_view bit for bit)."""
    a = np.ascontiguousarray(arr).reshape(-1)
    size = a.dtype.itemsize
    if size % 4 == 0:
        return a.view(np.uint32)            # 4-byte: 1 word; 8-byte: 2 words
    if size == 2:
        return a.view(np.uint16).astype(np.uint32)
    return a.view(np.uint8).astype(np.uint32)


def block_hashes_np(arr: np.ndarray,
                    block_elems: int = BLOCK_ELEMS) -> np.ndarray:
    """(NB,) uint32 per-block position-weighted word sums mod 2^32
    (NB = ceil(size/block); weight of word j within its block is 2j+1 —
    see kernel.py for the single-bit-flip / permutation rationale)."""
    w = words_np(arr)
    wpe = 2 if arr.dtype.itemsize == 8 else 1
    width = block_elems * wpe
    pad = (-w.size) % width
    if pad:
        w = np.pad(w, (0, pad))
    weights = (2 * np.arange(width, dtype=np.uint32) + 1)[None, :]
    # uint32 multiply/accumulate wraps mod 2^32 silently — exactly the hash
    return (w.reshape(-1, width) * weights).sum(axis=1, dtype=np.uint32)


def checksum_np(arr: np.ndarray, block_elems: int = BLOCK_ELEMS) -> int:
    """Whole-leaf checksum == uint32 sum of block_hashes_np."""
    return int(block_hashes_np(arr, block_elems).sum(dtype=np.uint32))
