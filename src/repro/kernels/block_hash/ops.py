"""Public block-hash wrapper: arbitrary leaves -> per-block uint32 hashes.

``words_view`` is THE shared uint32 mod-2^32 reduction idiom: any leaf is
bitcast to a flat run of 32-bit storage words (2-byte dtypes zero-extend,
8-byte dtypes split into two words).  ``block_hashes`` reduces those words
per fixed-size *element* block with odd position weights (2j+1 — see
kernel.py for why a plain sum is too weak for dirty-block detection while
the weighted sum still catches every single-bit flip);
``checksum_words`` is the uint32 sum of those block hashes — so a leaf's
scrubber checksum IS the sum of its delta-block hashes, and one hashing
pass can serve both consumers (repro/sdc/checksum.py and
CheckpointManager's delta mode).

Backend selection mirrors core/codec.DeviceCodec: the Pallas kernel on TPU,
a jit'd jnp twin elsewhere (interpret-mode Pallas is only for tests — far
too slow for multi-MB leaves on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_hash.kernel import hash_rows

BLOCK_ELEMS = 65536   # default delta block: 64 Ki elements (256 KiB fp32)


def words_view(x):
    """Flat int32 view of a leaf's storage words (same bits the host-side
    oracle in ref.py hashes).  int32 rather than uint32 so the kernel's
    adds stay on the natively supported type; wraparound is identical."""
    x = x.reshape(-1)
    size = x.dtype.itemsize
    if size == 4:
        return jax.lax.bitcast_convert_type(x, jnp.int32)
    if size == 2:
        return jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.int32)
    if size == 1:
        return jax.lax.bitcast_convert_type(x, jnp.uint8).astype(jnp.int32)
    # 8-byte dtypes bitcast to a trailing (..., 2) int32 axis
    return jax.lax.bitcast_convert_type(x, jnp.int32).reshape(-1)


def words_per_element(dtype) -> int:
    """How many 32-bit words one element contributes in ``words_view``."""
    return 2 if jnp.dtype(dtype).itemsize == 8 else 1


def _default_use_kernel() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("block_elems", "use_kernel", "interpret"))
def _block_hashes(x, block_elems, use_kernel, interpret):
    w = words_view(x)
    width = block_elems * words_per_element(x.dtype)
    pad = (-w.shape[0]) % width
    if pad:
        w = jnp.pad(w, (0, pad))
    rows = w.reshape(-1, width)
    if use_kernel:
        h = hash_rows(rows, interpret=interpret)
    else:
        weights = 2 * jnp.arange(width, dtype=jnp.int32) + 1
        h = jnp.sum(rows * weights[None, :], axis=1)  # int32: wraps mod 2^32
    return jax.lax.bitcast_convert_type(h.astype(jnp.int32), jnp.uint32)


def block_hashes(x, block_elems: int = BLOCK_ELEMS, *, use_kernel=None,
                 interpret=False):
    """x: device array, any shape/dtype -> (NB,) uint32 block hashes, still
    on device, where NB = ceil(x.size / block_elems) (the zero-padded tail
    block hashes its real words only)."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    return _block_hashes(x, int(block_elems), bool(use_kernel),
                         bool(interpret))


@functools.partial(jax.jit,
                   static_argnames=("block_elems", "use_kernel", "interpret"))
def _batched_block_hashes(leaves, block_elems, use_kernel, interpret):
    return [_block_hashes(x, block_elems, use_kernel, interpret)
            for x in leaves]


def batched_block_hashes(leaves, block_elems: int = BLOCK_ELEMS, *,
                         use_kernel=None, interpret=False):
    """Hash many leaves in ONE jitted dispatch (per-leaf dispatch overhead
    would rival the reduction itself on small states) — the save-path
    twin of sdc.checksum.checksums' batching."""
    if not leaves:
        return []
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    return _batched_block_hashes(list(leaves), int(block_elems),
                                 bool(use_kernel), bool(interpret))


def checksum_words(x, block_elems: int = BLOCK_ELEMS):
    """Whole-leaf checksum = uint32 sum of the leaf's block hashes — the
    scrubber's per-leaf checksum, traceable inside a larger jit.  Built
    from the SAME weighted block reduction delta mode uses, so one pass
    genuinely serves both (and a single-bit flip still changes exactly one
    block hash by a nonzero delta, hence the total)."""
    h = _block_hashes(x, block_elems, False, False)
    s = jnp.sum(jax.lax.bitcast_convert_type(h, jnp.int32))
    return jax.lax.bitcast_convert_type(s.astype(jnp.int32), jnp.uint32)
