"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel subpackage follows the required structure:
  <name>/kernel.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
  <name>/ops.py     — jit'd public wrapper (layout handling, interpret switch)
  <name>/ref.py     — pure-jnp oracle used by the allclose sweep tests

Kernels (DESIGN.md S3):
  flash_attention — blockwise online-softmax attention (causal / sliding
                    window / soft-cap / GQA); MXU-tiled.
  selective_scan  — Mamba-1 chunked selective scan, VMEM-resident state.
  ckpt_codec      — int8 block quantize/dequantize (checkpoint & gradient
                    compression: the paper-aligned kernel, shrinks the
                    Young/Daly C term).
  rmsnorm         — fused RMSNorm.
  abft_matmul     — checksum-extended matmul (Huang/Abraham ABFT): detects
                    and corrects a single corrupted output element; the
                    tier-1 SDC guard (docs/sdc.md).
  block_hash      — per-block uint32 mod-2^32 word sums: the dirty-block
                    detector behind incremental (delta) checkpoints AND the
                    SDC scrubber's leaf checksums (one reduction idiom,
                    two consumers — docs/checkpointing.md, docs/sdc.md).

All validated against their oracles in interpret mode on CPU (this container
has no TPU); on TPU hardware the same pallas_call lowers natively.
"""
from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; kernels
# use this alias so both spellings work.
CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")
