"""Mamba-1 selective-scan kernel (pl.pallas_call + BlockSpec VMEM tiling).

TPU adaptation of the CUDA fused scan (DESIGN.md S2): grid =
(B, channel_blocks, time_chunks); the SSM state h (bc x N) stays resident in
VMEM scratch across the sequential time-chunk dim, so HBM traffic is
O(inputs + outputs + one state snapshot per chunk) instead of
O(S * Di * N).  Inside a chunk the recurrence steps over time with a
fori_loop on VMEM-resident tiles (VPU work; the surrounding projections are
the MXU work and live outside the kernel).

    h_t = exp(dt_t * A) h_{t-1} + (dt_t B_t) x_t ;  y_t = C_t . h_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hout_ref,
            h_scr, *, q: int, nchunks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    def body(t, h):
        dt_t = dt_ref[0, t, :]                     # (bc,)
        x_t = x_ref[0, t, :]                       # (bc,)
        b_t = b_ref[0, t, :]                       # (N,)
        c_t = c_ref[0, t, :]                       # (N,)
        a = jnp.exp(dt_t[:, None] * a_ref[...])    # (bc, N)
        h = a * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = (h * c_t[None, :]).sum(axis=1)
        return h

    h = lax.fori_loop(0, q, body, h_scr[...])
    h_scr[...] = h

    @pl.when(j == nchunks - 1)
    def _flush():
        hout_ref[0] = h


def selective_scan_kernel(x, dt, bm, cm, a, h0, *, block_c=512, chunk=128,
                          interpret=False):
    """x, dt: (B,S,Di) f32; bm, cm: (B,S,N) f32; a: (Di,N) f32;
    h0: (B,Di,N) f32.  Returns (y (B,S,Di) f32, h_last (B,Di,N) f32)."""
    B, S, Di = x.shape
    N = bm.shape[-1]
    bc = min(block_c, Di)
    q = min(chunk, S)
    assert Di % bc == 0 and S % q == 0, (Di, bc, S, q)
    ncb, nch = Di // bc, S // q

    grid = (B, ncb, nch)
    kern = functools.partial(_kernel, q=q, nchunks=nch)
    y, h_last = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, bc), lambda b, c, j: (b, j, c)),   # x
            pl.BlockSpec((1, q, bc), lambda b, c, j: (b, j, c)),   # dt
            pl.BlockSpec((1, q, N), lambda b, c, j: (b, j, 0)),    # B
            pl.BlockSpec((1, q, N), lambda b, c, j: (b, j, 0)),    # C
            pl.BlockSpec((bc, N), lambda b, c, j: (c, 0)),         # A
            pl.BlockSpec((1, bc, N), lambda b, c, j: (b, c, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, q, bc), lambda b, c, j: (b, j, c)),   # y
            pl.BlockSpec((1, bc, N), lambda b, c, j: (b, c, 0)),   # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Di), jnp.float32),
            jax.ShapeDtypeStruct((B, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bc, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, bm, cm, a, h0)
    return y, h_last
