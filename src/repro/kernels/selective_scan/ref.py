"""Pure-jnp oracle for the selective scan: direct sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, bm, cm, a, h0):
    """Same contract as ops.selective_scan; lax.scan over time steps."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, t):
        x_t, dt_t, b_t, c_t = t
        decay = jnp.exp(dt_t[..., None] * a)           # (B,Di,N)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    ts = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cm.astype(jnp.float32), 1, 0))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), ts)
    return jnp.moveaxis(ys, 0, 1), h_last
