"""Public selective-scan wrapper."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.selective_scan.kernel import selective_scan_kernel


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_c", "chunk", "interpret"))
def selective_scan(x, dt, bm, cm, a, h0, *, block_c=512, chunk=128,
                   interpret=None):
    """Mamba-1 scan.  x,dt: (B,S,Di); bm,cm: (B,S,N); a: (Di,N); h0: (B,Di,N).
    Returns (y, h_last), both fp32."""
    interpret = _default_interpret() if interpret is None else interpret
    B, S, Di = x.shape
    bc = block_c
    while Di % bc:
        bc //= 2
    q = chunk
    while S % q:
        q //= 2
    return selective_scan_kernel(
        x.astype(jnp.float32), dt.astype(jnp.float32),
        bm.astype(jnp.float32), cm.astype(jnp.float32),
        a.astype(jnp.float32), h0.astype(jnp.float32),
        block_c=bc, chunk=q, interpret=interpret)
