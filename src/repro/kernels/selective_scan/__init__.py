from repro.kernels.selective_scan import kernel, ops, ref  # noqa: F401
