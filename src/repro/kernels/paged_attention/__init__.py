from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_decode_attention", "paged_attention_ref"]
