"""Pure-jnp oracle for paged gather-decode attention.

One query token per request attends a KV cache that lives in a shared
block-paged pool: ``k_pages``/``v_pages`` hold ``num_pages`` pages of
``page_size`` tokens each, and a per-request page table maps the
request's logical token positions onto physical pages (logical position
``t`` lives in page ``page_table[r, t // page_size]`` at offset
``t % page_size``).  Page id 0 is the reserved null page — table entries
pointing at it are either unallocated (masked out by the length bound)
or dead padding.

The oracle materializes every request's gathered cache and runs plain
masked softmax attention — the memory-hungry shape the Pallas kernel
exists to avoid."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.3819763e38


def paged_attention_ref(q, k_pages, v_pages, page_tables, lengths, *,
                        window: int = 0, softcap: float = 0.0,
                        scale=None):
    """q: (R, H, hd); k_pages/v_pages: (P, ps, K, hd);
    page_tables: (R, MPR) int32; lengths: (R,) int32 — the query token's
    position (it attends positions 0..lengths[r] inclusive, i.e. its own
    just-written slot plus the history).  Returns (R, H, hd)."""
    R, H, hd = q.shape
    P, ps, K, _ = k_pages.shape
    MPR = page_tables.shape[1]
    G = H // K
    scale = scale if scale else hd ** -0.5

    # gather each request's pages into a contiguous logical cache
    kc = k_pages[page_tables].reshape(R, MPR * ps, K, hd)
    vc = v_pages[page_tables].reshape(R, MPR * ps, K, hd)
    qq = (q * jnp.asarray(scale, q.dtype)).reshape(R, K, G, hd)
    logits = jnp.einsum("rkgd,rtkd->rkgt", qq, kc,
                        preferred_element_type=jnp.float32)
    if softcap and softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    t = jnp.arange(MPR * ps, dtype=jnp.int32)[None, :]       # (1, T)
    cur = lengths[:, None]
    ok = t <= cur
    if window and window > 0:
        ok = ok & (cur - t < window)
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("rkgt,rtkd->rkgd", p.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)
    return o.reshape(R, H, hd).astype(q.dtype)
