"""Paged decode-attention kernel (PrefetchScalarGridSpec + online softmax).

One query token per request gathers its KV history through a per-request
page table instead of a contiguous cache row.  The page table and the
per-request lengths ride in as *scalar prefetch* operands, so the k/v
``index_map`` can chase ``page_tables[r, j]`` to pick which physical page
the next grid step streams into VMEM — the gather never materializes.

Grid: (R, K, num_pages_per_request); the page dim is the innermost
sequential ("arbitrary") dim so the online-softmax state (m, l, acc)
lives in VMEM scratch across page iterations, exactly like the kv-block
dim of ``flash_attention``.  Pages past a request's length resolve to
the null page 0 in its table; their logits are masked by the length
bound, so they only cost the (tiny) page stream.

Layout note: pages arrive as (P, K, ps, hd) — KV-head major — so each
grid cell streams one (ps, hd) tile per head, mirroring the (bk, hd)
kv tile of the flash kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -1e30
LANES = 128


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *,
            scale: float, window: int, softcap: float, ps: int, npages: int):
    r = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (ps, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32)  # (G, ps)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    cur = len_ref[r]
    kpos = j * ps + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos <= cur                       # query sits at position cur
    if window:
        mask = mask & (cur - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]                                # (G, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_cur)
    # mask p explicitly: a fully-dead page would otherwise contribute
    # exp(NEG_INF - NEG_INF) = 1 while m is still at its init value
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)         # (G, ps)
    l_cur = l_prev * corr + p.sum(axis=1, keepdims=True)
    pv = lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                         preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = jnp.broadcast_to(m_cur, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur, l_scr.shape)

    @pl.when(j == npages - 1)
    def _flush():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


def paged_attention_rkgd(q, k_pages, v_pages, page_tables, lengths, *,
                         window=0, softcap=0.0, scale=None, interpret=False):
    """q: (R, K, G, hd); k_pages/v_pages: (P, K, ps, hd);
    page_tables: (R, MPR) int32; lengths: (R,) int32 (query position).
    Returns o: (R, K, G, hd)."""
    R, K, G, hd = q.shape
    P, _, ps, _ = k_pages.shape
    MPR = page_tables.shape[1]
    scale = scale if scale else hd ** -0.5

    grid = (R, K, MPR)
    kern = functools.partial(_kernel, scale=scale, window=window,
                             softcap=softcap, ps=ps, npages=MPR)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda r, h, j, pt, ln: (r, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda r, h, j, pt, ln: (pt[r, j], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda r, h, j, pt, ln: (pt[r, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda r, h, j, pt, ln: (r, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, K, G, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_tables, lengths, q, k_pages, v_pages)
