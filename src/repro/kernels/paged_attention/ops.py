"""Public paged decode-attention wrapper: engine layout, impl switch.

Two production paths behind one signature:

- ``impl="ref"`` (default off-TPU): gather ``k_pages[page_tables]`` into
  each request's contiguous logical cache and run the *exact* slot-pool
  decode math — a vmap over ``repro.layers.attention.decode_mha`` with
  ``cache_pos = arange``.  Because the per-example computation graph is
  identical to the legacy contiguous-slot path (same shapes, same masked
  NEG_INF softmax), greedy streams stay bit-identical to the slot pool,
  which is the failover determinism contract the paged refactor must
  keep (tests/test_paged.py pins this).
- ``impl="pallas"``: the PrefetchScalarGridSpec kernel — no gather, the
  page table is chased in the k/v index_map (kernel.py).

Pages use the serve layout (P, ps, K, hd); the kernel wants KV-head
major (P, K, ps, hd), transposed here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_rkgd
from repro.layers.attention import decode_mha


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _ref_path(q, k_pages, v_pages, page_tables, lengths, *,
              window, softcap, scale):
    R = q.shape[0]
    P, ps, K, hd = k_pages.shape
    MPR = page_tables.shape[1]
    kc = k_pages[page_tables].reshape(R, MPR * ps, K, hd)
    vc = v_pages[page_tables].reshape(R, MPR * ps, K, hd)
    cache_pos = jnp.arange(MPR * ps, dtype=jnp.int32)

    def one(qr, kr, vr, cur):
        # qr: (1, H, hd) -> decode_mha's (B=1, 1, H, hd); [0] back to (1,H,hd)
        return decode_mha(qr[None], kr[None], vr[None], cache_pos, cur,
                          window=window, softcap=softcap, scale=scale)[0]

    return jax.vmap(one)(q, kc, vc, lengths)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "impl", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_tables, lengths, *,
                           window=0, softcap=0.0, scale=None,
                           impl="ref", interpret=None):
    """q: (R, 1, H, hd); k_pages/v_pages: (P, ps, K, hd);
    page_tables: (R, MPR) int32; lengths: (R,) int32 — the query's
    position (it attends 0..lengths[r]).  Returns (R, 1, H, hd)."""
    R, S, H, hd = q.shape
    assert S == 1, "paged attention decodes one token per request"
    if impl == "pallas":
        interpret = _default_interpret() if interpret is None else interpret
        K = k_pages.shape[2]
        qk = q[:, 0].reshape(R, K, H // K, hd)
        kt = jnp.swapaxes(k_pages, 1, 2)     # (P, K, ps, hd)
        vt = jnp.swapaxes(v_pages, 1, 2)
        o = paged_attention_rkgd(qk, kt, vt, page_tables, lengths,
                                 window=window, softcap=softcap,
                                 scale=scale, interpret=interpret)
        return o.reshape(R, 1, H, hd)
    return _ref_path(q, k_pages, v_pages, page_tables, lengths,
                     window=window, softcap=softcap, scale=scale)
