"""int8 block-quantized gradient compression with error feedback.

Distributed-optimization trick (beyond-paper, see DESIGN.md S4): on pure-DP
axes the gradient all-reduce can move int8 payloads (4x fewer bytes than
fp32) at the cost of quantization noise, which error feedback re-injects on
the next step so the optimizer sees an unbiased long-run gradient.

``quantize_int8``/``dequantize_int8`` are also the checkpoint codec's
reference implementation (see repro/kernels/ckpt_codec) and MUST stay
layout-identical to the Pallas kernel: same BLOCK, same zero-pad, same
round/clip math.  ``use_kernel=True`` routes through the Pallas path
(repro.kernels.ckpt_codec.ops) so the two implementations can be swapped —
core/codec.py's DeviceCodec picks the kernel on TPU and this twin
elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_int8(x, *, use_kernel=False, interpret=None):
    """x (any shape) -> (q int8 [n_blocks, BLOCK], scale fp32 [n_blocks], meta).

    ``use_kernel=True`` dispatches to the Pallas kernel (same layout, same
    math); the default jnp path traces cleanly inside jit/shard_map."""
    if use_kernel:
        from repro.kernels.ckpt_codec.ops import block_meta, quantize
        q, scale = quantize(x, interpret=interpret)
        pad, _ = block_meta(x.shape)
        return q, scale, (x.shape, pad)
    flat, pad = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, pad)


def dequantize_int8(q, scale, meta, dtype=jnp.float32, *, use_kernel=False,
                    interpret=None):
    shape, pad = meta
    if use_kernel:
        from repro.kernels.ckpt_codec.ops import dequantize
        return dequantize(q, scale, tuple(shape),
                          interpret=interpret).astype(dtype)
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def ef_state_init(params):
    """Error-feedback residual buffers, one per param leaf (fp32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, ef, axis_name: str):
    """Inside shard_map over a pure-DP axis: error-feedback int8 all-reduce.

    g_eff = g + ef ; q = Q(g_eff) ; new_ef = g_eff - deQ(q) ;
    reduced = psum(deQ(q)) / axis_size.
    Sums dequantized fp32 values (numerically equivalent to summing int8
    payloads with per-peer scales, which is what the wire format would carry:
    int8 payload + fp32 per-block scale = ~4x byte reduction).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g_eff = g.astype(jnp.float32) + e
        q, s, meta = quantize_int8(g_eff)
        deq = dequantize_int8(q, s, meta)
        new_e = g_eff - deq
        red = jax.lax.psum(deq, axis_name) / n
        return red.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
