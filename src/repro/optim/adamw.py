"""AdamW from scratch (fp32 moments, decoupled weight decay).

Moments live in the same sharding as their parameters (the state spec maps
them through ``param_specs``), so FSDP shards the optimizer state too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    """Returns (new_params, new_opt).  ``lr`` may be a traced scalar."""
    count = opt["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        step = mh / (jnp.sqrt(vh) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
