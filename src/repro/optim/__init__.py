from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compress import (
    quantize_int8,
    dequantize_int8,
    compressed_psum,
    ef_state_init,
)

__all__ = [
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "clip_by_global_norm",
    "global_norm",
    "quantize_int8",
    "dequantize_int8",
    "compressed_psum",
    "ef_state_init",
]
