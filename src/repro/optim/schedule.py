"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    """Linear warmup then cosine decay to ``final_frac * peak_lr``."""

    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(s / max(warmup_steps, 1), 1.0)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, peak_lr * cos)

    return lr
