"""Rotary position embeddings: standard RoPE and Qwen2-VL multi-axis M-RoPE.

Convention: "rotate half" over contiguous halves of head_dim (llama/gemma
style).  All trig in fp32.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp


def _rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) int -> cos/sin (..., S, head_dim//2) fp32."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """x (B,S,H,hd); cos/sin (B,S,hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]  # (B,S,1,half)
    sin = sin[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(x, positions, theta: float = 10000.0):
    """x (B,S,H,hd), positions (B,S) int32."""
    cos, sin = _rope_angles(positions, x.shape[-1], theta)
    return _rotate(x, cos, sin)


def apply_mrope(x, positions, sections: Sequence[int], theta: float = 10000.0):
    """Qwen2-VL multi-axis RoPE.

    positions: (3, B, S) — temporal / height / width position ids.
    sections: sizes over head_dim//2 per axis (sum == head_dim//2).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    cos3, sin3 = _rope_angles(positions, x.shape[-1], theta)  # (3,B,S,half)
    chunks_c, chunks_s = [], []
    start = 0
    for i, sec in enumerate(sections):
        chunks_c.append(cos3[i, ..., start : start + sec])
        chunks_s.append(sin3[i, ..., start : start + sec])
        start += sec
    cos = jnp.concatenate(chunks_c, axis=-1)
    sin = jnp.concatenate(chunks_s, axis=-1)
    return _rotate(x, cos, sin)


def make_positions(batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))
