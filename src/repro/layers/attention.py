"""Attention: GQA/MQA multi-head attention with sliding-window, soft-capping,
bidirectional (encoder) mode, KV-cache decode, and two implementations:

- ``einsum``  : materializes the (S x S) score matrix.  Exact-FLOPs reference;
                used by the roofline probes and by small shapes.
- ``blocked`` : flash-style online-softmax over KV blocks with q blocking
                (lax.map over q blocks, lax.fori_loop over kv blocks, causal /
                window block skipping).  Memory-true path used by the scanned
                production model; same algorithm the Pallas kernel implements.
- ``pallas``  : the TPU Pallas kernel (see repro/kernels/flash_attention).

All softmax math in fp32.  q: (B,S,H,hd); k,v: (B,Skv,K,hd) with H % K == 0.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -2.3819763e38  # large negative, safe in fp32


def _softcap(logits, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits


def _mask(q_pos, k_pos, *, causal: bool, window: int):
    """Boolean mask (..., Sq, Sk): True = attend."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m = m & (kp <= qp)
    if window and window > 0:
        m = m & (qp - kp < window)
    return m


def _repeat_kv(k, G):
    """(B,T,K,hd) -> (B,T,K*G,hd): keeps the head dim a single tensor axis so
    TP sharding over heads propagates cleanly through the score einsums
    (a 5-D (K,G) split makes GSPMD pick mixed shardings and replicate)."""
    if G == 1:
        return k
    B, T, K, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, T, K, G, hd)) \
        .reshape(B, T, K * G, hd)


def mha_einsum(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
               q_offset=0):
    """Operands stay in the compute dtype (bf16 on TPU) with fp32 MXU
    accumulation + fp32 softmax — keeps attention's HBM/ICI traffic at
    2 bytes/elt instead of promoting everything to fp32."""
    B, S, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale else hd ** -0.5
    qq = q * jnp.asarray(scale, q.dtype)
    kk = _repeat_kv(k, G)
    vv = _repeat_kv(v, G)
    logits = jnp.einsum("bshd,bthd->bhst", qq, kk,
                        preferred_element_type=jnp.float32)
    logits = _softcap(logits, softcap)
    q_pos = jnp.arange(S, dtype=jnp.int32) + q_offset
    k_pos = jnp.arange(Skv, dtype=jnp.int32)
    m = _mask(q_pos, k_pos, causal=causal, window=window)  # (S,Skv)
    logits = jnp.where(m[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhst,bthd->bshd", p, vv,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def mha_blocked(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
                q_offset=0, block_q=512, block_k=1024, static_bounds=False):
    """``static_bounds=True`` visits every kv block (masked) so the loop has
    static trip counts — required for reverse-mode AD (training) and for the
    roofline probes; the dynamic-bounds default skips fully-masked blocks
    (inference)."""
    B, S, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale else hd ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)
    if S % block_q or Skv % block_k:
        # fall back for ragged shapes (only tiny test configs hit this)
        return mha_einsum(q, k, v, causal=causal, window=window,
                          softcap=softcap, scale=scale, q_offset=q_offset)
    nq, nk = S // block_q, Skv // block_k
    kr = k.reshape(B, nk, block_k, K, hd)
    vr = v.reshape(B, nk, block_k, K, hd)

    def per_q_block(i):
        qi = lax.dynamic_slice_in_dim(q, i * block_q, block_q, axis=1)
        qi = qi * jnp.asarray(scale, q.dtype)          # (B,bq,H,hd)
        q_lo = q_offset + i * block_q
        q_pos = jnp.arange(block_q, dtype=jnp.int32) + q_lo
        if static_bounds:
            lo, hi = 0, nk
        else:
            if causal:
                hi = jnp.minimum((q_lo + block_q + block_k - 1) // block_k, nk)
            else:
                hi = nk
            if window and window > 0:
                lo = jnp.maximum((q_lo - window + 1) // block_k, 0)
            else:
                lo = 0

        m0 = jnp.full((B, block_q, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, block_q, H), jnp.float32)
        a0 = jnp.zeros((B, block_q, H, hd), jnp.float32)

        def body(j, carry):
            m, l, acc = carry
            kj = _repeat_kv(kr[:, j], G)                       # (B,bk,H,hd)
            vj = _repeat_kv(vr[:, j], G)
            logits = jnp.einsum("bshd,bthd->bsht", qi, kj,
                                preferred_element_type=jnp.float32)
            logits = _softcap(logits, softcap)
            k_pos = jnp.arange(block_k, dtype=jnp.int32) + j * block_k
            msk = _mask(q_pos, k_pos, causal=causal, window=window)  # (bq,bk)
            logits = jnp.where(msk[None, :, None, :], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + \
                jnp.einsum("bsht,bthd->bshd", p.astype(vj.dtype), vj,
                           preferred_element_type=jnp.float32)
            return m_new, l_new, acc_new

        m, l, acc = lax.fori_loop(lo, hi, body, (m0, l0, a0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out

    blocks = lax.map(per_q_block, jnp.arange(nq))       # (nq,B,bq,H,hd)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def mha(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
        q_offset=0, impl="auto"):
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as _fa

        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   q_offset=q_offset)
    if impl == "auto":
        impl = "blocked" if q.shape[1] * k.shape[1] > 4096 * 4096 else "einsum"
    if impl == "blocked_static":
        return mha_blocked(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, q_offset=q_offset,
                           static_bounds=True)
    fn = mha_blocked if impl == "blocked" else mha_einsum
    return fn(q, k, v, causal=causal, window=window, softcap=softcap,
              scale=scale, q_offset=q_offset)


def decode_mha(q, k_cache, v_cache, cache_pos, cur_pos, *, window=0,
               softcap=0.0, scale=None):
    """Single-token decode attention against a (possibly rolling) KV cache.

    q: (B,1,H,hd); k_cache/v_cache: (B,Sc,K,hd);
    cache_pos: (Sc,) int32 — absolute position stored in each slot (-1 empty);
    cur_pos: scalar int32 — absolute position of the query token.
    """
    B, _, H, hd = q.shape
    Sc, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale else hd ** -0.5
    qq = (q * jnp.asarray(scale, q.dtype)).reshape(B, K, G, hd)
    logits = jnp.einsum("bkgd,btkd->bkgt", qq, k_cache,
                        preferred_element_type=jnp.float32)
    logits = _softcap(logits, softcap)
    ok = (cache_pos >= 0) & (cache_pos <= cur_pos)
    if window and window > 0:
        ok = ok & (cur_pos - cache_pos < window)
    logits = jnp.where(ok[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)
