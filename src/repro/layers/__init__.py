from repro.layers.norms import rms_norm
from repro.layers.rope import apply_rope, apply_mrope
from repro.layers.attention import mha, decode_mha
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.moe import drop_experts, moe_apply, moe_init, router_probs

__all__ = [
    "rms_norm",
    "apply_rope",
    "apply_mrope",
    "mha",
    "decode_mha",
    "mlp_apply",
    "mlp_init",
    "moe_apply",
    "moe_init",
    "drop_experts",
    "router_probs",
]
