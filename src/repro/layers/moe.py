"""Top-k Mixture-of-Experts with capacity-bounded scatter dispatch.

FLOPs-honest: expert compute is ``B * E * C * (...)`` with
``C = ceil(S * k / E * capacity_factor)`` — i.e. ~``capacity_factor`` x the
active-expert FLOPs, never the dense all-experts product.  Dispatch/combine
are scatter/gather (no T x E x C one-hot matmuls).

Token -> slot routing is computed independently per batch row so every op
keeps the batch dim leading and data-parallel sharding propagates untouched.
For decode (S == 1) the batch dim itself is treated as the token axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.api import U, constrain
from repro.sharding.rules import DP_AXES, TP


def moe_init(key, d_model: int, d_ff: int, num_experts: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "router": (jax.random.normal(k1, (d_model, num_experts)) * s_in
                   ).astype(jnp.float32),
        "w_in": (jax.random.normal(k2, (num_experts, d_model, d_ff)) * s_in
                 ).astype(dtype),
        "w_gate": (jax.random.normal(k3, (num_experts, d_model, d_ff)) * s_in
                   ).astype(dtype),
        "w_out": (jax.random.normal(k4, (num_experts, d_ff, d_model)) * s_out
                  ).astype(dtype),
    }


def _capacity(tokens: int, num_experts: int, k: int, cf: float) -> int:
    c = -(-tokens * k * cf // num_experts)
    return max(int(c), 1)


def router_probs(logits, num_experts: int, dead_experts=()):
    """Router distribution over experts; (..., E) logits -> (..., E) probs.

    With ``dead_experts`` the softmax runs on the COMPACTED live columns
    and scatters back (not a -inf mask over all E): that keeps the
    reduction order identical to a model holding just the survivor
    experts, so degraded routing is bit-exact vs ``drop_experts`` — dead
    experts get exactly zero mass either way."""
    dead = tuple(sorted({int(e) for e in dead_experts}))
    if not dead:
        return jax.nn.softmax(logits, axis=-1)
    live_idx = jnp.asarray([e for e in range(num_experts)
                            if e not in dead])
    sub = jax.nn.softmax(logits[..., live_idx], axis=-1)
    return jnp.zeros_like(logits).at[..., live_idx].set(sub)


def drop_experts(params, dead_experts):
    """Physically remove lost experts: slice their router columns and weight
    rows out.  Running the result with the survivor expert count is
    bit-identical to running the full model with ``dead_experts`` masked in
    ``moe_apply`` — masking is the zero-copy fast path after a failure,
    dropping is the compaction that reclaims the memory."""
    dead = set(int(e) for e in dead_experts)
    num = params["router"].shape[1]
    keep = jnp.asarray([e for e in range(num) if e not in dead])
    return {
        "router": params["router"][:, keep],
        "w_in": params["w_in"][keep],
        "w_gate": params["w_gate"][keep],
        "w_out": params["w_out"][keep],
    }


def moe_apply(params, x, *, num_experts: int, k: int, capacity_factor: float,
              act, compute_dtype, ep: bool = False, dead_experts=()):
    """x: (B, S, D) -> (B, S, D).  Aux loss returned for load balancing.

    ``dead_experts`` (a STATIC tuple of expert ids — it shapes capacity) is
    graceful degradation after an expert slice dies: the softmax runs over
    the surviving columns only, so the router renormalizes over the
    survivors (lost experts get exactly zero mass) and capacity + aux loss
    are computed from the live count.  The live expert path is bit-exact
    vs a model holding just the survivor experts (see ``drop_experts``)."""
    B, S, D = x.shape
    decode = S == 1
    if decode:
        # fold batch into the token axis; single "row"
        x = x.reshape(1, B, D)
        B, S = 1, B
    E = num_experts
    dead = tuple(sorted({int(e) for e in dead_experts}))
    if any(e < 0 or e >= E for e in dead):
        raise ValueError(f"dead_experts {dead} out of range for E={E}")
    live = E - len(dead)
    if live <= 0:
        raise ValueError(f"all {E} experts dead: nothing to route to")
    k = min(k, live)
    C = _capacity(S, live, k, capacity_factor)

    router = params["router"].astype(jnp.float32)
    logits = x.astype(jnp.float32) @ router                    # (B,S,E)
    probs = router_probs(logits, E, dead)                      # (B,S,E)
    gate_w, gate_i = jax.lax.top_k(probs, k)                   # (B,S,k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style, over live experts)
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = jax.nn.one_hot(gate_i[..., 0], E).mean(axis=(0, 1))
    balance = me * ce
    if dead:
        balance = balance[jnp.asarray([e for e in range(E)
                                       if e not in dead])]
    aux_loss = live * jnp.sum(balance)

    # ---- slot assignment, per batch row ----
    T = S * k
    fe = gate_i.reshape(B, T)                                  # expert of each copy
    fw = gate_w.reshape(B, T)
    oh = jax.nn.one_hot(fe, E, dtype=jnp.int32)                # (B,T,E)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=1), fe[..., None],
                              axis=2)[..., 0] - 1              # (B,T)
    keep = pos < C
    dest = jnp.where(keep, fe * C + pos, E * C)                # overflow slot

    xs = jnp.repeat(x, k, axis=1)                              # (B,T,D)
    brow = jnp.arange(B)[:, None]
    slots = jnp.zeros((B, E * C + 1, D), x.dtype).at[brow, dest].add(
        jnp.where(keep[..., None], xs, 0))
    xe = slots[:, : E * C].reshape(B, E, C, D)
    xe = constrain(xe, P(DP_AXES, TP if ep else U, U, U))

    # ---- expert computation (TP over d_ff, or EP over experts) ----
    w_in = params["w_in"].astype(compute_dtype)
    w_gate = params["w_gate"].astype(compute_dtype)
    w_out = params["w_out"].astype(compute_dtype)
    h = jnp.einsum("becd,edf->becf", xe.astype(compute_dtype), w_in)
    g = jnp.einsum("becd,edf->becf", xe.astype(compute_dtype), w_gate)
    h = act(g) * h
    h = constrain(h, P(DP_AXES, TP if ep else U, U, TP if not ep else U))
    ye = jnp.einsum("becf,efd->becd", h, w_out)                # (B,E,C,D)

    # ---- combine ----
    flat = jnp.concatenate(
        [ye.reshape(B, E * C, D),
         jnp.zeros((B, 1, D), ye.dtype)], axis=1)              # (B,E*C+1,D)
    back = jnp.take_along_axis(flat, dest[..., None], axis=1)  # (B,T,D)
    back = back * (fw * keep)[..., None]
    y = back.reshape(B, S, k, D).sum(axis=2)
    if decode:
        y = y.reshape(S, 1, D)
    return y.astype(compute_dtype), aux_loss
