"""Normalization layers (fp32 accumulation, cast back to compute dtype)."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6, *, use_pallas: bool = False):
    """RMSNorm: x * w / rms(x).  ``weight`` follows the (1+w) gemma convention
    when initialized to zeros; standard convention when initialized to ones —
    we use the standard convention (init to ones) everywhere."""
    if use_pallas:
        from repro.kernels.rmsnorm import ops as _ops

        return _ops.rms_norm(x, weight, eps=eps)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * weight.astype(jnp.float32)).astype(dtype)
