"""Gated / plain MLP blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.api import U, constrain
from repro.sharding.rules import DP_AXES, TP


def _act(name: str):
    if name in ("silu", "swish"):
        return jax.nn.silu
    if name in ("gelu", "gelu_plain"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype):
    gated = act in ("silu", "gelu")
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def _dot(x, w, impl):
    if impl == "abft":
        from repro.kernels.abft_matmul.ops import abft_dot

        return abft_dot(x, w)
    return x @ w


def mlp_apply(params, x, act: str, compute_dtype, impl=None):
    """``impl="abft"`` routes the projection matmuls through the
    checksum-extended kernel (docs/sdc.md tier 1): single corrupted output
    elements are located and corrected in place, at fp32 compute cost."""
    gated = act in ("silu", "gelu")
    fn = _act(act)
    h = _dot(x, params["w_in"].astype(compute_dtype), impl)
    if gated:
        g = _dot(x, params["w_gate"].astype(compute_dtype), impl)
        h = fn(g) * h
    else:
        h = fn(h)
    # Pin the hidden to TP sharding: the transpose pins the COTANGENT too,
    # keeping the backward dx = dh @ w_out^T contraction aligned with
    # w_out's "model" sharding (otherwise GSPMD all-gathers the full weight
    # in the remat-backward region — EXPERIMENTS.md S Perf).
    h = constrain(h, P(DP_AXES, U, TP))
    return _dot(h, params["w_out"].astype(compute_dtype), impl)
