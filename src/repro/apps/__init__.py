from repro.apps.fwi import (
    FWIConfig,
    FWIData,
    FWIShardData,
    forward_model,
    make_fwi_step,
    make_observed_data,
    run_fwi,
)

__all__ = ["FWIConfig", "FWIData", "FWIShardData", "forward_model",
           "make_fwi_step", "make_observed_data", "run_fwi"]
