from repro.apps.fwi import (
    FWIConfig,
    forward_model,
    make_fwi_step,
    make_observed_data,
    run_fwi,
)

__all__ = ["FWIConfig", "forward_model", "make_fwi_step",
           "make_observed_data", "run_fwi"]
