"""4D Full-Waveform Inversion — the paper's case-study application, in JAX.

2D acoustic FDTD wave propagation (lax.scan over time steps), adjoint
gradients via jax.grad through the scan, iterative model updates (Adam).
Shots are the data-parallel unit (the paper distributed 50 samples over 32
cores); here shots vmap/shard over the "data" axis.

"4D" = time-lapse: invert a baseline survey and a monitor survey (reservoir
perturbation injected into the true model); the difference image is the 4D
signal.  Each FWI iteration is one BSP superstep -> the Dependability layer
wraps it exactly like an LM training step (global state = velocity model +
optimizer moments; local state = the data cursor).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class FWIConfig:
    nz: int = 80
    nx: int = 80
    nt: int = 500
    dx: float = 10.0          # m
    dt: float = 1e-3          # s
    f0: float = 12.0          # Ricker peak frequency, Hz
    n_shots: int = 4
    c_background: float = 2000.0
    c_layer: float = 2400.0
    c_anomaly_4d: float = -150.0   # monitor-survey velocity change
    layer_frac: float = 0.33       # depth of the reflector (fraction of nz)
    anom_frac: float = 0.5         # depth of the 4D anomaly
    c_min: float = 1500.0
    c_max: float = 3200.0
    lr: float = 15.0
    iterations: int = 20


def ricker(cfg: FWIConfig) -> jnp.ndarray:
    t = jnp.arange(cfg.nt) * cfg.dt - 1.0 / cfg.f0
    a = (jnp.pi * cfg.f0 * t) ** 2
    return (1 - 2 * a) * jnp.exp(-a)


def shot_positions(cfg: FWIConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Source x-positions (one per shot, z=2) and receiver x-positions
    (every 2nd column, z=2)."""
    sx = jnp.linspace(5, cfg.nx - 6, cfg.n_shots).astype(jnp.int32)
    rx = jnp.arange(2, cfg.nx - 2, 2, dtype=jnp.int32)
    return sx, rx


def forward_model(c, src_x, cfg: FWIConfig):
    """Propagate one shot through velocity model c (nz,nx).
    Returns the seismogram (nt, n_receivers) recorded at z=2."""
    wav = ricker(cfg)
    _, rx = shot_positions(cfg)
    lap_k = (cfg.dt / cfg.dx) ** 2
    c2 = c * c

    def stencil(p):
        lap = (-4.0 * p
               + jnp.roll(p, 1, 0) + jnp.roll(p, -1, 0)
               + jnp.roll(p, 1, 1) + jnp.roll(p, -1, 1))
        # zero-pressure boundary (simple free surface on all sides)
        lap = lap.at[0, :].set(0).at[-1, :].set(0)
        lap = lap.at[:, 0].set(0).at[:, -1].set(0)
        return lap

    def step(carry, w_t):
        p_prev, p = carry
        p_next = 2 * p - p_prev + c2 * lap_k * stencil(p)
        p_next = p_next.at[2, src_x].add(w_t)
        rec = p_next[2, rx]
        return (p, p_next), rec

    p0 = jnp.zeros((cfg.nz, cfg.nx), jnp.float32)
    (_, _), seis = jax.lax.scan(step, (p0, p0), wav)
    return seis                                        # (nt, n_rec)


def true_models(cfg: FWIConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(baseline, monitor) true velocity models: layered + 4D anomaly."""
    z = jnp.arange(cfg.nz)[:, None]
    x = jnp.arange(cfg.nx)[None, :]
    base = jnp.where(z > int(cfg.nz * cfg.layer_frac), cfg.c_layer,
                     cfg.c_background)
    base = base * jnp.ones((cfg.nz, cfg.nx))
    # reservoir blob in the deep layer
    cz, cx, r = int(cfg.nz * cfg.anom_frac), int(cfg.nx * 0.5), cfg.nx // 10
    blob = ((z - cz) ** 2 + (x - cx) ** 2) < r * r
    monitor = base + jnp.where(blob, cfg.c_anomaly_4d, 0.0)
    return base.astype(jnp.float32), monitor.astype(jnp.float32)


def make_observed_data(cfg: FWIConfig) -> Dict[str, jnp.ndarray]:
    """Synthesizes observed seismograms for both surveys (all shots)."""
    base, monitor = true_models(cfg)
    sx, _ = shot_positions(cfg)
    fm = jax.vmap(lambda s, c: forward_model(c, s, cfg), in_axes=(0, None))
    return {
        "baseline": fm(sx, base),                      # (shots, nt, nrec)
        "monitor": fm(sx, monitor),
        "model_baseline": base,
        "model_monitor": monitor,
    }


def fwi_loss(c, d_obs, cfg: FWIConfig):
    """Sum of squared residuals over all shots (vmapped)."""
    sx, _ = shot_positions(cfg)
    pred = jax.vmap(lambda s: forward_model(c, s, cfg))(sx)
    resid = pred - d_obs
    return 0.5 * jnp.sum(resid * resid) / d_obs.shape[0]


def init_fwi_state(cfg: FWIConfig):
    """Global state (DeLIA terms): model + moments + iteration count."""
    c0 = jnp.full((cfg.nz, cfg.nx), cfg.c_background, jnp.float32)
    params = {"c": c0}
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": adamw_init(params),
        "rng": jax.random.PRNGKey(0),
    }


def make_fwi_step(cfg: FWIConfig):
    """One BSP superstep: grad over all shots -> Adam update on c."""

    def step(state, batch):
        d_obs = batch["d_obs"]
        loss, grads = jax.value_and_grad(
            lambda p: fwi_loss(p["c"], d_obs, cfg))(state["params"])
        new_params, new_opt = adamw_update(
            grads, state["opt"], state["params"], lr=cfg.lr,
            weight_decay=0.0)
        new_params = {"c": jnp.clip(new_params["c"], cfg.c_min, cfg.c_max)}
        new_state = {
            "step": state["step"] + 1,
            "params": new_params,
            "opt": new_opt,
            "rng": state["rng"],
        }
        return new_state, {"loss": loss}

    return step


class FWIData:
    """Constant-dataset pipeline with a DeLIA local-state cursor."""

    def __init__(self, d_obs):
        self.d_obs = d_obs
        self.step = 0

    def next_batch(self):
        self.step += 1
        return {"d_obs": self.d_obs}

    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, s):
        self.step = int(s["step"])


class FWIShardData:
    """Local-SCOPE FWI pipeline: shots are the DP unit (the paper spread 50
    samples over 32 cores) and shard k owns the contiguous shot slice
    ``[lo, hi)`` of the observed data plus its own cursor.

    Each shard's ``{"step", "shot_lo", "shot_hi"}`` dict is saved as its
    OWN checkpoint file (``local_s<k>.json``) and remapped onto the current
    DP width on restore — the local-scope configuration the paper's
    parallel module could not support.  The merged batch is always the full
    shot set, so the inversion trajectory is width-independent."""

    def __init__(self, d_obs, dp_width: int = 1):
        self.d_obs = d_obs
        self.n_shots = int(d_obs.shape[0])
        self.step = 0
        self.remapped_from: Optional[int] = None
        self.repartition(dp_width)

    def repartition(self, dp_width: int) -> None:
        from repro.data.pipeline import even_spans

        self.spans = even_spans(self.n_shots, dp_width)
        self.dp_width = dp_width

    def next_batch(self):
        self.step += 1
        return {"d_obs": self.d_obs}

    def shard_batch(self, k: int):
        """Shard k's slice of the observed data (what that worker alone
        would propagate)."""
        lo, hi = self.spans[k]
        return {"d_obs": self.d_obs[lo:hi]}

    # ---- DeLIA local scope ----
    def state_dict(self):
        return {"step": int(self.step), "width": int(self.dp_width),
                "n_shots": int(self.n_shots), "scope": "sharded"}

    def load_state_dict(self, s):
        self.step = int(s["step"])

    def shard_state_dicts(self):
        return [{"shard": k, "width": int(self.dp_width),
                 "step": int(self.step), "shot_lo": int(lo),
                 "shot_hi": int(hi)}
                for k, (lo, hi) in enumerate(self.spans)]

    def load_shard_state_dicts(self, dicts):
        dicts = sorted(dicts, key=lambda d: int(d["shard"]))
        steps = {int(d["step"]) for d in dicts}
        assert len(steps) == 1, f"saved shard cursors diverged: {steps}"
        # the saved spans must tile the shot axis exactly, else data was
        # lost between save and restore
        covered = [(int(d["shot_lo"]), int(d["shot_hi"])) for d in dicts]
        assert covered[0][0] == 0 and covered[-1][1] == self.n_shots \
            and all(a[1] == b[0] for a, b in zip(covered, covered[1:])), \
            f"saved shot spans do not tile [0, {self.n_shots}): {covered}"
        self.remapped_from = len(dicts)
        self.step = steps.pop()
        self.repartition(self.dp_width)   # recompute spans for our width


def run_fwi(cfg: FWIConfig, d_obs, *, dep=None, iterations: Optional[int] = None,
            state=None, fault_injector=None, local_scope: bool = False,
            dp_width: int = 1):
    """Runs FWI; with ``dep`` the loop is DeLIA-protected (checkpoints etc.).

    ``local_scope=True`` uses the per-shard pipeline (``FWIShardData`` over
    ``dp_width`` shot shards) so each shard's cursor/data-slice checkpoints
    to its own file.  Returns (state, history)."""
    iterations = iterations or cfg.iterations
    step_fn = jax.jit(make_fwi_step(cfg))
    state = state if state is not None else init_fwi_state(cfg)
    data = (FWIShardData(d_obs, dp_width=dp_width) if local_scope
            else FWIData(d_obs))
    if dep is None:
        hist = []
        for _ in range(int(state["step"]), iterations):
            state, m = step_fn(state, data.next_batch())
            hist.append({"loss": float(m["loss"])})
        return state, hist
    from repro.core import run_with_recovery

    dep.register_local_state(data)
    template = jax.eval_shape(lambda: init_fwi_state(cfg))
    state, info = run_with_recovery(dep, step_fn, state, data, iterations,
                                    fault_injector=fault_injector,
                                    like=template)
    return state, info["history"]
