"""qwen1.5-110b [dense] — 80L d=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
QKV bias.  [hf:Qwen/Qwen1.5 family; hf]"""
from repro.models.base import FULL, ModelConfig, register

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    pattern=(FULL,),
    mlp_act="silu",
    tie_embeddings=False,
    seq_shard=True,
)

TINY = ModelConfig(
    name="qwen1.5-110b-tiny",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    pattern=(FULL,),
    tie_embeddings=False,
)

register("qwen1.5-110b", CONFIG, TINY)
