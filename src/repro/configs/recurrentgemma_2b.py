"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]

26 % 3 != 0, so for O(1-layer) HLO the stack scans a 13-layer pattern block
(2 x 13 = 26): ((rec,rec,local) x 4, rec) repeated twice.  Same composition
as the published arch (8 local-attention + 18 recurrent layers); attention
positions in the second half shift by one vs the strict 1:2 interleave —
recorded as a compile-tractability adaptation in DESIGN.md.
"""
from repro.models.base import LOCAL, REC, ModelConfig, register

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    rope_theta=10000.0,
    window=2048,
    pattern=(REC, REC, LOCAL) * 4 + (REC,),
    mlp_act="gelu",
    lru_width=2560,
    conv_width=4,
    embed_scale=True,
    tie_embeddings=True,
    scan_layers=True,
    pad_heads_to=16,   # 10 q-heads -> 16 for even tp=16 sharding (masked pad)
)

TINY = ModelConfig(
    name="recurrentgemma-2b-tiny",
    family="hybrid",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    window=8,
    pattern=(REC, REC, LOCAL),
    mlp_act="gelu",
    lru_width=64,
    embed_scale=True,
    tie_embeddings=True,
    scan_layers=False,
)

register("recurrentgemma-2b", CONFIG, TINY)
