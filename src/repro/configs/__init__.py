"""Architecture configs (assigned pool).  Importing this package registers
every architecture with the model registry."""
from repro.configs import (  # noqa: F401
    falcon_mamba_7b,
    gemma2_27b,
    gemma_7b,
    granite_3_8b,
    hubert_xlarge,
    mixtral_8x7b,
    phi35_moe,
    qwen15_110b,
    qwen2_vl_2b,
    recurrentgemma_2b,
)

ALL_ARCHS = (
    "qwen2-vl-2b",
    "granite-3-8b",
    "qwen1.5-110b",
    "gemma-7b",
    "gemma2-27b",
    "mixtral-8x7b",
    "phi3.5-moe-42b-a6.6b",
    "falcon-mamba-7b",
    "recurrentgemma-2b",
    "hubert-xlarge",
)
