"""qwen2-vl-2b [vlm] — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]
Backbone only: the vision frontend is a stub — inputs are precomputed patch
embeddings plus (3, B, S) M-RoPE position ids."""
from repro.models.base import FULL, ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    pattern=(FULL,),
    mlp_act="silu",
    embedding_inputs=True,
    tie_embeddings=False,
    pad_heads_to=16,   # 12 q-heads -> 16 for even tp=16 sharding (masked pad)
)

TINY = ModelConfig(
    name="qwen2-vl-2b-tiny",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    mrope_sections=(2, 3, 3),
    pattern=(FULL,),
    embedding_inputs=True,
    tie_embeddings=False,
)

register("qwen2-vl-2b", CONFIG, TINY)
