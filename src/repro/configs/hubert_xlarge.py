"""hubert-xlarge [audio] — 48L d=1280 16H d_ff=5120 vocab=504 (cluster
codebook).  Encoder-only bidirectional transformer (w2v2 arch).
[arXiv:2106.07447]

Backbone only: the waveform conv frontend is a stub — inputs are precomputed
frame embeddings (B, S, d_model).  Plain-GELU (non-gated) FFN.  No decode.
"""
from repro.models.base import BIDIR, ModelConfig, register

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    pattern=(BIDIR,),
    mlp_act="gelu_plain",
    embedding_inputs=True,
    tie_embeddings=False,
)

TINY = ModelConfig(
    name="hubert-xlarge-tiny",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    pattern=(BIDIR,),
    mlp_act="gelu_plain",
    embedding_inputs=True,
    tie_embeddings=False,
)

register("hubert-xlarge", CONFIG, TINY)
