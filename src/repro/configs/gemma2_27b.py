"""gemma2-27b [dense] — 46L d=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Local+global alternating attention, logit softcaps, sandwich norms.
[arXiv:2408.00118; hf]"""
from repro.models.base import FULL, LOCAL, ModelConfig, register

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    rope_theta=10000.0,
    window=4096,
    pattern=(LOCAL, FULL),
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,
    mlp_act="gelu",
    embed_scale=True,
    sandwich_norm=True,
    tie_embeddings=True,
)

TINY = ModelConfig(
    name="gemma2-27b-tiny",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    window=8,
    pattern=(LOCAL, FULL),
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_act="gelu",
    embed_scale=True,
    sandwich_norm=True,
    tie_embeddings=True,
)

register("gemma2-27b", CONFIG, TINY)
