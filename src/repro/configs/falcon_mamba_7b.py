"""falcon-mamba-7b [ssm] — 64L d=4096 (attention-free) vocab=65024,
ssm_state=16, Mamba-1 architecture.  [arXiv:2410.05355]"""
from repro.models.base import SSM, ModelConfig, register

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    d_ff=0,
    vocab_size=65024,
    pattern=(SSM,),
    ssm_state=16,
    conv_width=4,
    expand=2,
    tie_embeddings=True,
    seq_shard=True,
)

TINY = ModelConfig(
    name="falcon-mamba-7b-tiny",
    family="ssm",
    num_layers=2,
    d_model=64,
    d_ff=0,
    vocab_size=256,
    pattern=(SSM,),
    ssm_state=4,
    conv_width=4,
    expand=2,
    tie_embeddings=True,
)

register("falcon-mamba-7b", CONFIG, TINY)
