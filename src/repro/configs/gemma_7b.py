"""gemma-7b [dense] — 28L d=3072 16H (kv=16) d_ff=24576 vocab=256000.
GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
from repro.models.base import FULL, ModelConfig, register

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    rope_theta=10000.0,
    pattern=(FULL,),
    mlp_act="gelu",
    embed_scale=True,
    tie_embeddings=True,
)

TINY = ModelConfig(
    name="gemma-7b-tiny",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    pattern=(FULL,),
    mlp_act="gelu",
    embed_scale=True,
    tie_embeddings=True,
)

register("gemma-7b", CONFIG, TINY)
