"""granite-3-8b [dense] — 40L d=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base scaled family; hf]"""
from repro.models.base import FULL, ModelConfig, register

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10000.0,
    pattern=(FULL,),
    mlp_act="silu",
    tie_embeddings=True,
    seq_shard=True,
)

TINY = ModelConfig(
    name="granite-3-8b-tiny",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pattern=(FULL,),
    tie_embeddings=True,
)

register("granite-3-8b", CONFIG, TINY)
