"""phi3.5-moe-42b-a6.6b [moe] — 32L d=4096 32H (GQA kv=8) d_ff=6400
vocab=32064.  MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.base import FULL, ModelConfig, register

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    rope_theta=10000.0,
    pattern=(FULL,),
    mlp_act="silu",
    num_experts=16,
    experts_per_token=2,
    tie_embeddings=False,
    seq_shard=True,
)

TINY = ModelConfig(
    name="phi3.5-moe-tiny",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    pattern=(FULL,),
    num_experts=4,
    experts_per_token=2,
    tie_embeddings=False,
)

register("phi3.5-moe-42b-a6.6b", CONFIG, TINY)
