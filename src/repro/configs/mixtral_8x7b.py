"""mixtral-8x7b [moe] — 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
MoE 8 experts top-2, sliding-window attention.  [arXiv:2401.04088; hf]"""
from repro.models.base import LOCAL, ModelConfig, register

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1e6,
    window=4096,
    pattern=(LOCAL,),
    mlp_act="silu",
    num_experts=8,
    experts_per_token=2,
    tie_embeddings=False,
    seq_shard=True,
)

TINY = ModelConfig(
    name="mixtral-8x7b-tiny",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    window=8,
    pattern=(LOCAL,),
    num_experts=4,
    experts_per_token=2,
    tie_embeddings=False,
)

register("mixtral-8x7b", CONFIG, TINY)
