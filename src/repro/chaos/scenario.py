"""Declarative chaos scenarios: timed correlated-failure timelines.

A ``Scenario`` is a validated list of timed events — the trace a chaos run
replays.  Production failures arrive correlated (a rack loss during an SDC
storm under a flash crowd), so a scenario composes freely:

    sc = (Scenario("rack-loss-under-load", clock="step")
          .kill_hosts([2, 3], at=5)
          .sdc_storm(rate=0.2, window=(4, 12))
          .traffic_spike(mult=8, window=(3, 10))
          .rejoin(2, at=14))

or loads from a dict / JSON trace (``scenarios/*.json`` ships a canned
library)::

    sc = Scenario.from_json("scenarios/compound.json")

The event clock is **deterministic**: ``clock="step"`` keys events to
superstep / engine-step boundaries (training and serving — both loops are
step-driven), ``clock="time"`` keys them to virtual seconds (the
control-plane simulator, ``repro.chaos.sim``).  Events are totally ordered
by ``(at, id)``, so two replays of one trace fire identically.

Event kinds (see docs/chaos.md for the full schema):

==============  =========================================================
kill_hosts      fail-stop of one or more hosts/replicas at ``at``
partition       drop heartbeat datagrams between ``groups`` in
                ``[at, heal_at)`` — the monitor sees asymmetric liveness
sdc_storm       bit-flips at ``rate`` per step over ``window`` (seeded,
                deterministic), optionally confined to ``leaves``
straggle        ``host`` runs ``factor``x slower over ``window``
traffic_spike   arrival rate multiplied by ``mult`` over ``window``
rejoin          a previously killed host comes back at ``at``
preempt         the scheduler's termination warning (SIGUSR1) at ``at``
precursor_storm ``host`` straggles at ``factor``x over ``window`` and
                then (``kill=True``, the default) fail-stops AT the
                window's end — the straggle-then-kill trace the
                telemetry plane's detectors must catch in time
==============  =========================================================

Drivers apply the kinds that exist on their plane and ignore the rest
(``traffic_spike`` means nothing to a training loop; ``preempt`` nothing
to the serving engine) — one JSON trace drives ``run_elastic``, the
``ServeEngine``, and the simulator.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

KINDS = ("kill_hosts", "partition", "sdc_storm", "straggle",
         "traffic_spike", "rejoin", "preempt", "precursor_storm")
CLOCKS = ("step", "time")

#: kinds that occupy a ``[at, until)`` window rather than a point in time
WINDOW_KINDS = ("partition", "sdc_storm", "straggle", "traffic_spike",
                "precursor_storm")


class ScenarioError(ValueError):
    """A scenario failed validation (bad event args or timeline)."""


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One timed event.  ``until`` is None for point events; window events
    are active over ``[at, until)``."""
    eid: int
    kind: str
    at: float
    until: Optional[float]
    args: Dict[str, Any]

    def active(self, t: float) -> bool:
        if self.until is None:
            return t == self.at
        return self.at <= t < self.until

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, **self.args}
        if self.until is None:
            d["at"] = self.at
        else:
            d["window"] = [self.at, self.until]
        return d


def _check_window(kind: str, window) -> Tuple[float, float]:
    try:
        start, end = float(window[0]), float(window[1])
    except (TypeError, ValueError, IndexError):
        raise ScenarioError(f"{kind}: window must be (start, end), "
                            f"got {window!r}")
    if start < 0 or end <= start:
        raise ScenarioError(f"{kind}: need 0 <= start < end, "
                            f"got window={window!r}")
    return start, end


def _check_at(kind: str, at) -> float:
    try:
        at = float(at)
    except (TypeError, ValueError):
        raise ScenarioError(f"{kind}: 'at' must be a number, got {at!r}")
    if at < 0:
        raise ScenarioError(f"{kind}: 'at' must be >= 0, got {at}")
    return at


class Scenario:
    def __init__(self, name: str = "scenario", clock: str = "step",
                 seed: int = 0):
        if clock not in CLOCKS:
            raise ScenarioError(f"clock {clock!r} not in {CLOCKS}")
        self.name = name
        self.clock = clock
        self.seed = int(seed)
        self.events: List[ChaosEvent] = []

    # ------------------------------------------------------------------
    # builders (each validates, appends, and returns self for chaining)
    # ------------------------------------------------------------------
    def _add(self, kind: str, at: float, until: Optional[float],
             **args) -> "Scenario":
        self.events.append(ChaosEvent(len(self.events), kind, at, until,
                                      args))
        return self

    def kill_hosts(self, ids: Sequence[int], at: float) -> "Scenario":
        """Fail-stop hosts (training) / replicas (serving) ``ids`` at
        ``at``.  Several ids at one instant model a correlated rack loss."""
        ids = [int(i) for i in (ids if isinstance(ids, (list, tuple))
                                else [ids])]
        if not ids or len(set(ids)) != len(ids):
            raise ScenarioError(f"kill_hosts: ids must be non-empty and "
                                f"unique, got {ids!r}")
        return self._add("kill_hosts", _check_at("kill_hosts", at), None,
                         hosts=sorted(ids))

    def partition(self, groups: Sequence[Sequence[int]], at: float,
                  heal_at: float) -> "Scenario":
        """Drop heartbeat traffic between ``groups`` over [at, heal_at).
        Groups must be disjoint and non-empty; hosts not named keep full
        connectivity."""
        at = _check_at("partition", at)
        heal = _check_at("partition", heal_at)
        if heal <= at:
            raise ScenarioError(f"partition: heal_at ({heal_at}) must be "
                                f"> at ({at})")
        gs = [sorted(int(h) for h in g) for g in groups]
        if len(gs) < 2 or any(not g for g in gs):
            raise ScenarioError(f"partition: need >= 2 non-empty groups, "
                                f"got {groups!r}")
        seen: set = set()
        for g in gs:
            if seen.intersection(g):
                raise ScenarioError(f"partition: groups overlap on "
                                    f"{sorted(seen.intersection(g))}")
            seen.update(g)
        return self._add("partition", at, heal, groups=gs)

    def sdc_storm(self, rate: float, window: Sequence[float],
                  leaves: Optional[Sequence[str]] = None,
                  max_bit: int = 30) -> "Scenario":
        """Silent bit-flips at probability ``rate`` per step over
        ``window``, confined to state ``leaves`` (None: the driver picks
        from the registered state).  Seeded by ``Scenario.seed`` — two
        replays flip the same bits at the same steps."""
        if not 0 < float(rate) <= 1:
            raise ScenarioError(f"sdc_storm: rate must be in (0, 1], "
                                f"got {rate!r}")
        start, end = _check_window("sdc_storm", window)
        if max_bit < 1:
            raise ScenarioError(f"sdc_storm: max_bit must be >= 1, "
                                f"got {max_bit}")
        return self._add("sdc_storm", start, end, rate=float(rate),
                         leaves=(list(leaves) if leaves else None),
                         max_bit=int(max_bit))

    def straggle(self, host: int, factor: float,
                 window: Sequence[float]) -> "Scenario":
        """``host`` runs ``factor``x slower over ``window`` (fail-stutter:
        alive, beating, but late at every barrier)."""
        if float(factor) <= 1:
            raise ScenarioError(f"straggle: factor must be > 1, "
                                f"got {factor!r}")
        start, end = _check_window("straggle", window)
        return self._add("straggle", start, end, host=int(host),
                         factor=float(factor))

    def traffic_spike(self, mult: float,
                      window: Sequence[float]) -> "Scenario":
        """Arrival rate multiplied by ``mult`` over ``window`` (flash
        crowd).  Serving / simulator planes only."""
        if float(mult) < 1:
            raise ScenarioError(f"traffic_spike: mult must be >= 1, "
                                f"got {mult!r}")
        start, end = _check_window("traffic_spike", window)
        return self._add("traffic_spike", start, end, mult=float(mult))

    def rejoin(self, host: int, at: float) -> "Scenario":
        """A previously killed host comes back (grow event) at ``at``."""
        return self._add("rejoin", _check_at("rejoin", at), None,
                         host=int(host))

    def precursor_storm(self, host: int, factor: float,
                        window: Sequence[float],
                        kill: bool = True) -> "Scenario":
        """``host`` degrades visibly — ``factor``x slower over
        ``window`` — and then fail-stops at the window's END (unless
        ``kill=False``: a near-miss that recovers).  The canonical
        precursor trace for the telemetry plane (docs/observability.md):
        the straggle is the symptom the drift detector must turn into a
        ``precursor/*`` event early enough for a proactive checkpoint /
        pre-drain to land before the kill."""
        if float(factor) <= 1:
            raise ScenarioError(f"precursor_storm: factor must be > 1, "
                                f"got {factor!r}")
        start, end = _check_window("precursor_storm", window)
        return self._add("precursor_storm", start, end, host=int(host),
                         factor=float(factor), kill=bool(kill))

    def preempt(self, at: float, sig: str = "SIGUSR1") -> "Scenario":
        """Deliver the scheduler's preemption warning signal at ``at``
        (training plane: latch -> final checkpoint -> clean exit)."""
        if not sig.startswith("SIG"):
            raise ScenarioError(f"preempt: sig must be a signal name "
                                f"(SIGUSR1, ...), got {sig!r}")
        return self._add("preempt", _check_at("preempt", at), None, sig=sig)

    # ------------------------------------------------------------------
    # validation + queries
    # ------------------------------------------------------------------
    def validate(self) -> "Scenario":
        """Whole-timeline checks (builders validate per-event args):
        every rejoin names a host killed strictly earlier; a host is not
        killed twice without a rejoin in between.  Kill/rejoin actions
        are ordered by their EFFECTIVE time — ``kill_hosts`` and
        ``rejoin`` fire at ``at``, a killing ``precursor_storm`` at its
        window's ``until`` — so a storm's deferred kill pairs correctly
        with a later rejoin.  Returns self."""
        actions: List[Tuple[float, int, str, int]] = []
        for ev in self.sorted_events():
            if ev.kind == "kill_hosts":
                for h in ev.args["hosts"]:
                    actions.append((ev.at, ev.eid, "kill", h))
            elif ev.kind == "precursor_storm" and ev.args["kill"]:
                actions.append((ev.until, ev.eid, "kill",
                                ev.args["host"]))
            elif ev.kind == "rejoin":
                actions.append((ev.at, ev.eid, "rejoin",
                                ev.args["host"]))
        dead_since: Dict[int, float] = {}
        for t, _, action, h in sorted(actions):
            if action == "kill":
                if h in dead_since:
                    raise ScenarioError(
                        f"host {h} killed at t={t} but already dead "
                        f"since t={dead_since[h]} (no rejoin in "
                        "between)")
                dead_since[h] = t
            else:
                if h not in dead_since:
                    raise ScenarioError(
                        f"rejoin of host {h} at t={t} but it was never "
                        "killed before that")
                del dead_since[h]
        return self

    def sorted_events(self) -> List[ChaosEvent]:
        """Deterministic replay order: (at, insertion id)."""
        return sorted(self.events, key=lambda e: (e.at, e.eid))

    def point_events(self, kind: Optional[str] = None) -> List[ChaosEvent]:
        return [e for e in self.sorted_events() if e.until is None
                and (kind is None or e.kind == kind)]

    def window_events(self, kind: Optional[str] = None) -> List[ChaosEvent]:
        return [e for e in self.sorted_events() if e.until is not None
                and (kind is None or e.kind == kind)]

    def at(self, t: float, kind: Optional[str] = None) -> List[ChaosEvent]:
        """Point events firing exactly at ``t``."""
        return [e for e in self.point_events(kind) if e.at == t]

    def active(self, t: float,
               kind: Optional[str] = None) -> List[ChaosEvent]:
        """Window events whose [at, until) covers ``t``."""
        return [e for e in self.window_events(kind) if e.active(t)]

    @property
    def horizon(self) -> float:
        """Last instant anything happens (0 for an empty scenario)."""
        return max((e.at if e.until is None else e.until
                    for e in self.events), default=0.0)

    # ------------------------------------------------------------------
    # (de)serialization
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "clock": self.clock, "seed": self.seed,
                "events": [e.to_dict() for e in self.sorted_events()]}

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.to_dict(), indent=2)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Scenario":
        sc = cls(name=d.get("name", "scenario"),
                 clock=d.get("clock", "step"), seed=d.get("seed", 0))
        for i, ev in enumerate(d.get("events", ())):
            ev = dict(ev)
            kind = ev.pop("kind", None)
            if kind not in KINDS:
                raise ScenarioError(f"event {i}: kind {kind!r} not in "
                                    f"{KINDS}")
            try:
                if kind == "kill_hosts":
                    sc.kill_hosts(ev.pop("hosts"), at=ev.pop("at"))
                elif kind == "partition":
                    # accept either the serialized window form or the
                    # hand-written at/heal_at form
                    if "window" in ev:
                        start, heal = _check_window("partition",
                                                    ev.pop("window"))
                    else:
                        start, heal = ev.pop("at"), ev.pop("heal_at")
                    sc.partition(ev.pop("groups"), at=start, heal_at=heal)
                elif kind == "sdc_storm":
                    sc.sdc_storm(ev.pop("rate"), ev.pop("window"),
                                 leaves=ev.pop("leaves", None),
                                 max_bit=ev.pop("max_bit", 30))
                elif kind == "straggle":
                    sc.straggle(ev.pop("host"), ev.pop("factor"),
                                ev.pop("window"))
                elif kind == "traffic_spike":
                    sc.traffic_spike(ev.pop("mult"), ev.pop("window"))
                elif kind == "rejoin":
                    sc.rejoin(ev.pop("host"), at=ev.pop("at"))
                elif kind == "preempt":
                    sc.preempt(ev.pop("at"), sig=ev.pop("sig", "SIGUSR1"))
                elif kind == "precursor_storm":
                    sc.precursor_storm(ev.pop("host"), ev.pop("factor"),
                                       ev.pop("window"),
                                       kill=ev.pop("kill", True))
            except KeyError as e:
                raise ScenarioError(f"event {i} ({kind}): missing "
                                    f"required field {e}")
            if ev:
                raise ScenarioError(f"event {i} ({kind}): unknown fields "
                                    f"{sorted(ev)}")
        return sc.validate()

    @classmethod
    def from_json(cls, path_or_text: str) -> "Scenario":
        """Load from a JSON file path or a JSON string."""
        if os.path.exists(path_or_text):
            with open(path_or_text) as f:
                text = f.read()
        else:
            text = path_or_text
        try:
            d = json.loads(text)
        except ValueError as e:
            raise ScenarioError(f"not valid scenario JSON: {e}")
        return cls.from_dict(d)

    def __repr__(self) -> str:
        return (f"Scenario({self.name!r}, clock={self.clock!r}, "
                f"{len(self.events)} events, horizon={self.horizon})")
