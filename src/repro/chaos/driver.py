"""Scenario drivers: compile a ``Scenario`` onto the live fault-injection
hooks and drive the training and serving loops through it.

Two adapters share one trace format (``repro.chaos.scenario``):

- ``TrainScenarioDriver`` + ``run_scenario_elastic`` replay a scenario
  against ``core.elastic_loop.run_elastic``: kills pause heartbeat
  emitters (the monitor detects, the mesh shrinks), rejoins resume them
  (grow), partitions drop emitter datagrams via the heartbeat layer's
  ``send_filter`` network gate (asymmetric liveness — the partitioned
  host keeps running and believes it is connected), SDC storms compile to
  seeded ``schedule_bitflip`` schedules, straggles to
  ``schedule_straggle``, and ``preempt`` to the termination signal.
  ``run_scenario_elastic`` additionally closes the corruption loop the
  elastic runner alone leaves open: a storm flip detected by a scrub /
  sentinel tier raises ``CorruptionDetected`` out of ``run_elastic``; the
  wrapper rolls back to the newest verified checkpoint and re-enters on
  the surviving hosts (``initial_hosts``) — compound scenarios where a
  rack dies *during* an SDC storm recover end to end.

- ``ServeScenarioDriver`` replays the same trace against a running
  ``ServeEngine``: kills become ``schedule_replica_kill`` (several ids at
  one step = a correlated rack loss), SDC storms become
  ``schedule_replica_sdc`` (the sentinel drain path), straggles become
  latency spikes, partitions gate replica emitters, and traffic spikes
  multiply the driver's own request arrivals (flash crowd).  The driver
  records conservation samples every engine step so
  ``invariants.check_conservation`` / ``check_monotonic_drain`` audit the
  whole run.

Event kinds outside a plane (``traffic_spike`` for training, ``preempt``
for serving) are recorded in the driver's ``skipped`` report, never
silently lost.  All event clocks here are ``clock="step"``; virtual-time
scenarios belong to the simulator (``repro.chaos.sim``).
"""
from __future__ import annotations

import os
import random
import signal as signal_module
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.chaos.scenario import Scenario, ScenarioError
from repro.core.failures import CorruptionDetected, FaultInjector


def _emit_scenario(obs, scenario: Scenario, plane: str) -> None:
    """Record the compiled scenario declaratively on the bus: one
    ``chaos/<kind>`` event per scenario event (original at/until/args)
    plus a ``chaos/scenario`` meta event carrying name/clock/seed.
    ``repro.obs.export.to_scenario`` reconstructs the Scenario losslessly
    from these — the record half of record-and-replay."""
    if obs is None:
        return
    obs.emit("chaos", "scenario", name=scenario.name,
             clock=scenario.clock, seed=scenario.seed, plane=plane)
    for ev in scenario.sorted_events():
        obs.emit("chaos", ev.kind, at=ev.at, until=ev.until, plane=plane,
                 **ev.args)


def _storm_flips(scenario: Scenario, event, leaf_names: Sequence[str]
                 ) -> List[Tuple[int, str, int]]:
    """Deterministic (step, leaf, bit) schedule for one sdc_storm event —
    seeded by (scenario.seed, event id), so replays and both planes agree."""
    leaves = event.args["leaves"] or list(leaf_names)
    if not leaves:
        raise ScenarioError(
            "sdc_storm: no target leaves — the event names none and the "
            "driver was given no leaf_names")
    rng = random.Random(f"{scenario.seed}/storm/{event.eid}")
    flips = []
    for step in range(int(event.at), int(event.until)):
        if rng.random() < event.args["rate"]:
            flips.append((step, rng.choice(list(leaves)),
                          rng.randrange(event.args["max_bit"])))
    return flips


class TrainScenarioDriver:
    """Compile a Scenario for the elastic training loop.

    - ``emitters``: host id -> ``HeartbeatEmitter`` (include host 0's own
      ``dep.emitter`` if the scenario may touch it).
    - ``leaf_names``: dotted state-leaf names sdc_storm flips pick from
      when the event doesn't name its own.
    - ``step_seconds``: the expected superstep duration straggle factors
      convert against.
    - ``settle_seconds``: wall-time slept after pausing/gating emitters so
      the monitor's timeout fires before the next superstep boundary.

    Wire ``on_metrics`` into ``run_bsp``/``run_elastic``; injector-borne
    events (flips, straggles) are scheduled at construction.  Actions fire
    once: a rollback replaying earlier steps does not re-kill a host.
    """

    def __init__(self, scenario: Scenario, *,
                 injector: Optional[FaultInjector] = None,
                 emitters: Optional[Dict[int, Any]] = None,
                 monitor_host: int = 0,
                 leaf_names: Sequence[str] = (),
                 step_seconds: float = 0.05,
                 settle_seconds: float = 0.35,
                 obs=None):
        if scenario.clock != "step":
            raise ScenarioError(
                f"training driver needs clock='step', scenario "
                f"{scenario.name!r} uses {scenario.clock!r}")
        scenario.validate()
        self.scenario = scenario
        self.obs = obs
        self.injector = injector if injector is not None else FaultInjector()
        if obs is not None and self.injector.obs is None:
            self.injector.obs = obs
        self.emitters = dict(emitters or {})
        self.monitor_host = monitor_host
        self.settle_seconds = settle_seconds
        self.skipped: List[str] = []
        self.applied: List[Dict] = []          # chronological action log
        self._records: Dict[int, Dict] = {}    # step -> newest metrics rec
        self._fired: set = set()               # (eid, phase) already fired
        # (step, eid, phase, fn) boundary actions, step-ordered
        self._actions: List[Tuple[int, int, str, Callable[[], None]]] = []
        self._compile(leaf_names, step_seconds)
        self._actions.sort(key=lambda a: (a[0], a[1]))
        _emit_scenario(self.obs, scenario, plane="train")

    # ------------------------------------------------------------------
    # compilation
    # ------------------------------------------------------------------
    def _emitter(self, host: int):
        if host not in self.emitters:
            raise ScenarioError(
                f"scenario {self.scenario.name!r} touches host {host} but "
                f"no emitter was provided (have {sorted(self.emitters)})")
        return self.emitters[host]

    def _compile(self, leaf_names, step_seconds) -> None:
        for ev in self.scenario.sorted_events():
            if ev.kind == "kill_hosts":
                for h in ev.args["hosts"]:
                    self._emitter(h)           # fail fast on bad ids
                self._actions.append((int(ev.at), ev.eid, "kill",
                                      self._make_kill(ev.args["hosts"])))
            elif ev.kind == "rejoin":
                self._emitter(ev.args["host"])
                self._actions.append((int(ev.at), ev.eid, "rejoin",
                                      self._make_rejoin(ev)))
            elif ev.kind == "partition":
                for g in ev.args["groups"]:
                    for h in g:
                        self._emitter(h)
                self._actions.append((int(ev.at), ev.eid, "partition",
                                      self._make_partition(ev)))
                self._actions.append((int(ev.until), ev.eid, "heal",
                                      self._make_heal(ev)))
            elif ev.kind == "preempt":
                self._actions.append((int(ev.at), ev.eid, "preempt",
                                      self._make_preempt(ev)))
            elif ev.kind == "sdc_storm":
                for step, leaf, bit in _storm_flips(self.scenario, ev,
                                                    leaf_names):
                    self.injector.schedule_bitflip(step, leaf, bit)
            elif ev.kind == "straggle":
                extra = (ev.args["factor"] - 1.0) * step_seconds
                for step in range(int(ev.at), int(ev.until)):
                    self.injector.schedule_straggle(step, extra)
            elif ev.kind == "precursor_storm":
                # symptom: the host straggles over [at, until) ...
                self._emitter(ev.args["host"])
                extra = (ev.args["factor"] - 1.0) * step_seconds
                for step in range(int(ev.at), int(ev.until)):
                    self.injector.schedule_straggle(step, extra)
                # ... then the predicted failure lands AT the window end
                if ev.args["kill"]:
                    self._actions.append((
                        int(ev.until), ev.eid, "kill",
                        self._make_kill([ev.args["host"]])))
            else:
                self.skipped.append(ev.kind)

    def _gated_hosts(self, ev) -> List[int]:
        """Hosts whose datagrams the partition drops: every group not
        containing the monitor host (the monitor's own side keeps
        delivering)."""
        groups = ev.args["groups"]
        keep = next((g for g in groups if self.monitor_host in g),
                    groups[0])
        return [h for g in groups if g is not keep for h in g]

    def _make_kill(self, hosts):
        def fire():
            for h in hosts:
                self._emitter(h).pause()
            time.sleep(self.settle_seconds)
        return fire

    def _make_rejoin(self, ev):
        def fire():
            self._emitter(ev.args["host"]).resume()
            time.sleep(self.settle_seconds)
        return fire

    def _make_partition(self, ev):
        def fire():
            for h in self._gated_hosts(ev):
                self._emitter(h).send_filter = lambda payload: False
            time.sleep(self.settle_seconds)
        return fire

    def _make_heal(self, ev):
        def fire():
            for h in self._gated_hosts(ev):
                self._emitter(h).send_filter = None
            time.sleep(self.settle_seconds)
        return fire

    def _make_preempt(self, ev):
        def fire():
            os.kill(os.getpid(),
                    getattr(signal_module, ev.args["sig"]))
        return fire

    # ------------------------------------------------------------------
    # runtime
    # ------------------------------------------------------------------
    def on_metrics(self, step: int, rec: Dict) -> None:
        """Chain into the BSP loop's ``on_metrics``: fires every due
        boundary action exactly once and keeps the newest metrics record
        per step (a replay after rollback overwrites the corrupted-era
        record, so the merged trajectory is the one that survived)."""
        self._records[step] = rec
        if self.obs is not None:
            self.obs.emit("chaos", "record", **rec)
        for at, eid, phase, fire in self._actions:
            if at > step:
                break
            key = (eid, phase)
            if key in self._fired:
                continue
            self._fired.add(key)
            self.applied.append({"step": step, "at": at, "phase": phase,
                                 "event": eid})
            if self.obs is not None:
                self.obs.emit("chaos", "applied", step=step, at=at,
                              phase=phase, event=eid)
            fire()

    def history(self) -> List[Dict]:
        """Merged per-step metrics records, step-ordered (newest record
        wins for steps replayed after a rollback).  With ``obs`` attached
        the records live on the bus ("chaos"/"record"); newest-per-step
        still wins because later emits overwrite earlier steps' entries
        in the reconstruction."""
        if self.obs is not None:
            recs: Dict[int, Dict] = {}
            for e in self.obs.events(subsystem="chaos", kind="record"):
                recs[e.data["step"]] = dict(e.data)
            # the bus ring is bounded: records that fell off the front are
            # still in the local dict — merge, bus (newer) wins
            merged = dict(self._records)
            merged.update(recs)
            return [merged[s] for s in sorted(merged)]
        return [self._records[s] for s in sorted(self._records)]

    def dead_intervals(self) -> Dict[int, List[Tuple[float, float]]]:
        """host -> [(t_kill, t_rejoin_or_inf)] from the scenario timeline
        (for ``invariants.check_no_dead_growth``)."""
        out: Dict[int, List[Tuple[float, float]]] = {}
        open_at: Dict[int, float] = {}
        kills: List[Tuple[float, int]] = []    # (effective time, host)
        for ev in self.scenario.sorted_events():
            if ev.kind == "kill_hosts":
                kills.extend((ev.at, h) for h in ev.args["hosts"])
            elif ev.kind == "precursor_storm" and ev.args["kill"]:
                kills.append((ev.until, ev.args["host"]))
        rejoins = [(ev.at, ev.args["host"])
                   for ev in self.scenario.point_events("rejoin")]
        marks = ([(t, 0, h) for t, h in kills]
                 + [(t, 1, h) for t, h in rejoins])
        for t, action, h in sorted(marks):
            if action == 0:
                open_at[h] = t
            else:
                if h in open_at:
                    out.setdefault(h, []).append((open_at.pop(h), t))
        for h, t0 in open_at.items():
            out.setdefault(h, []).append((t0, float("inf")))
        return out

    def report(self) -> Dict:
        return {"scenario": self.scenario.name,
                "applied": list(self.applied),
                "skipped": sorted(set(self.skipped)),
                "pending_injections": len(self.injector.pending()),
                "sdc_injected": list(self.injector.sdc_injected)}


def run_scenario_elastic(dep, make_step, state, data, num_steps, *,
                         scenario: Scenario,
                         emitters: Dict[int, Any],
                         host_devices: Dict[int, Sequence[Any]],
                         model_axis: int = 1,
                         like=None,
                         shardings_fn: Optional[Callable] = None,
                         leaf_names: Sequence[str] = (),
                         step_seconds: float = 0.05,
                         settle_seconds: Optional[float] = None,
                         max_rollbacks: int = 4,
                         on_metrics: Optional[Callable] = None,
                         on_event: Optional[Callable] = None,
                         obs=None,
                         **kw) -> Tuple[Any, Dict]:
    """Drive ``run_elastic`` through ``scenario``, surviving detected
    corruption by rolling back to the newest verified checkpoint and
    re-entering on the surviving hosts.

    Returns ``(state, info)``: ``info["history"]`` is the merged per-step
    trajectory (loss records, deduplicated across replays),
    ``info["events"]`` every ``MeshEvent`` across re-entries,
    ``info["rollbacks"]`` the corruption-recovery count, and
    ``info["report"]`` the driver's applied/skipped action log.
    """
    from repro.core.elastic_loop import run_elastic

    if settle_seconds is None:
        settle_seconds = 7.0 * dep.config.heartbeat_period
    if obs is None:
        obs = dep.obs                      # reuse an attached handle
    elif dep.obs is None:
        dep.attach_obs(obs)                # thread telemetry end to end
    driver = TrainScenarioDriver(
        scenario, emitters=emitters, leaf_names=leaf_names,
        step_seconds=step_seconds, settle_seconds=settle_seconds, obs=obs)

    def chained_metrics(step, rec):
        driver.on_metrics(step, rec)
        if on_metrics is not None:
            on_metrics(step, rec)

    events: List[Any] = []
    alive = sorted(host_devices)

    def chained_event(ev):
        events.append(ev)
        nonlocal alive
        if ev.kind == "shrink":
            alive = [h for h in alive if h not in ev.hosts]
        else:
            alive = sorted(set(alive) | set(ev.hosts))
        if on_event is not None:
            on_event(ev)

    rollbacks = 0
    extra_history: List[Dict] = []
    while True:
        try:
            state, info = run_elastic(
                dep, make_step, state, data, num_steps,
                host_devices=host_devices, initial_hosts=alive,
                model_axis=model_axis, like=like, shardings_fn=shardings_fn,
                fault_injector=driver.injector, on_metrics=chained_metrics,
                on_event=chained_event, **kw)
            break
        except CorruptionDetected as e:
            rollbacks += 1
            extra_history.append({
                "step": e.step, "event": f"corruption:{e.kind}:{e.detail}"})
            if rollbacks > max_rollbacks:
                raise
            dep.manager.wait()
            state, got = dep.restore_latest(like=like)
            extra_history.append({"step": got, "event": f"rollback:{got}"})
            dep.reset_sdc()
            if obs is not None:
                # the re-entry IS the resume for this corruption incident
                obs.emit("train", "resume", step=got,
                         rolled_back_from=e.step, rollbacks=rollbacks)
    merged = driver.history() + extra_history
    merged.extend(h for h in info["history"] if "event" in h)
    info = dict(info, events=events, rollbacks=rollbacks,
                history=sorted(merged, key=lambda h: h["step"]),
                report=driver.report())
    return state, info


class ServeScenarioDriver:
    """Replay a Scenario against a live ``ServeEngine``.

    The driver owns the workload: ``base_rate`` requests are submitted per
    engine step (deterministic prompts from ``scenario.seed``), multiplied
    by any active ``traffic_spike``.  ``QueueFull`` rejections are counted
    (admission control working as designed), never raised to the caller.

    Construction compiles injector-borne events (kills, SDC storms,
    straggle latency spikes) onto the engine's ``FaultInjector``;
    ``step``/``run`` fire partition gates at engine-step boundaries and
    record one conservation sample per step for the invariant checks.
    """

    def __init__(self, engine, scenario: Scenario, *,
                 base_rate: int = 1,
                 prompt_len: int = 8,
                 max_new_tokens: int = 8,
                 step_seconds: float = 0.02,
                 settle_seconds: Optional[float] = None):
        if scenario.clock != "step":
            raise ScenarioError(
                f"serve driver needs clock='step', scenario "
                f"{scenario.name!r} uses {scenario.clock!r}")
        scenario.validate()
        self.engine = engine
        self.scenario = scenario
        # the engine always owns an Observability; the driver records its
        # compiled scenario on the same bus so one log tells both stories
        self.obs = getattr(engine, "obs", None)
        self.base_rate = int(base_rate)
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.settle_seconds = settle_seconds
        if engine.injector is None:
            engine.injector = FaultInjector()
        self.injector = engine.injector
        self.skipped: List[str] = []
        self.rejected = 0
        self.submitted_rids: List[int] = []
        self.prompts: Dict[int, List[int]] = {}   # rid -> prompt
        self.samples: List[Dict[str, int]] = []
        self.page_samples: List[Dict[str, int]] = []   # paged engines only
        self.drained_series: List[int] = []
        self._gates_on: set = set()
        self._prompt_rng = random.Random(f"{scenario.seed}/prompts")
        if self.obs is not None and self.injector.obs is None:
            self.injector.obs = self.obs
        self._compile(step_seconds)
        _emit_scenario(self.obs, scenario, plane="serve")

    # ------------------------------------------------------------------
    # compilation
    # ------------------------------------------------------------------
    def _compile(self, step_seconds: float) -> None:
        replica_ids = sorted(self.engine.router.replicas)
        rng = random.Random(f"{self.scenario.seed}/serve")
        for ev in self.scenario.sorted_events():
            if ev.kind == "kill_hosts":
                for rid in ev.args["hosts"]:
                    self.injector.schedule_replica_kill(int(ev.at), rid)
            elif ev.kind == "sdc_storm":
                # the storm strikes replicas here: rate per engine step,
                # victim drawn from the replicas present at compile time
                for step in range(int(ev.at), int(ev.until)):
                    if rng.random() < ev.args["rate"]:
                        self.injector.schedule_replica_sdc(
                            step, rng.choice(replica_ids),
                            detail=f"storm:{self.scenario.name}")
            elif ev.kind == "straggle":
                extra = (ev.args["factor"] - 1.0) * step_seconds
                for step in range(int(ev.at), int(ev.until)):
                    self.injector.schedule_latency_spike(
                        step, extra, replica_id=ev.args["host"])
            elif ev.kind == "precursor_storm":
                # symptom: latency spikes over the window; predicted
                # failure: the replica kill lands at the window end —
                # the pre-drain must beat it there
                extra = (ev.args["factor"] - 1.0) * step_seconds
                for step in range(int(ev.at), int(ev.until)):
                    self.injector.schedule_latency_spike(
                        step, extra, replica_id=ev.args["host"])
                if ev.args["kill"]:
                    self.injector.schedule_replica_kill(
                        int(ev.until), ev.args["host"])
            elif ev.kind in ("partition", "traffic_spike"):
                pass                       # fired/queried at step time
            else:
                self.skipped.append(ev.kind)

    # ------------------------------------------------------------------
    # workload
    # ------------------------------------------------------------------
    def _make_prompt(self) -> List[int]:
        vocab = self.engine.cfg.vocab_size
        return [self._prompt_rng.randrange(vocab)
                for _ in range(self.prompt_len)]

    def arrival_rate(self, step: int) -> int:
        """Requests to submit at ``step``: base rate x any active spike.
        The workload lasts through the scenario horizon — past it arrivals
        stop, so ``run`` can drain to completion."""
        if step > self.scenario.horizon:
            return 0
        mult = 1.0
        for ev in self.scenario.active(step, "traffic_spike"):
            mult = max(mult, ev.args["mult"])
        return int(round(self.base_rate * mult))

    def _fire_partitions(self, step: int) -> None:
        for ev in self.scenario.window_events("partition"):
            on = ev.active(step)
            if on and ev.eid not in self._gates_on:
                self._gates_on.add(ev.eid)
                for rid in self._partitioned(ev):
                    rep = self.engine.router.replicas.get(rid)
                    if rep is not None and rep.emitter is not None:
                        rep.emitter.send_filter = lambda payload: False
                # let the monitor's timeout land inside the window
                time.sleep(self._settle())
            elif not on and ev.eid in self._gates_on and step >= ev.until:
                self._gates_on.discard(ev.eid)
                for rid in self._partitioned(ev):
                    rep = self.engine.router.replicas.get(rid)
                    if rep is not None and rep.emitter is not None:
                        rep.emitter.send_filter = None

    def _partitioned(self, ev) -> List[int]:
        """Replicas the partition cuts off from the monitor: every group
        but the first (the monitor's side)."""
        return [r for g in ev.args["groups"][1:] for r in g]

    def _settle(self) -> float:
        if self.settle_seconds is not None:
            return self.settle_seconds
        mon = self.engine.monitor
        return (1.5 * mon.timeout) if mon is not None else 0.0

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def step(self) -> None:
        from repro.serve.scheduler import QueueFull

        estep = self.engine.engine_step
        self._fire_partitions(estep)
        for _ in range(self.arrival_rate(estep)):
            prompt = self._make_prompt()
            try:
                rid = self.engine.submit(prompt, self.max_new_tokens)
            except QueueFull:
                self.rejected += 1
                continue
            self.submitted_rids.append(rid)
            self.prompts[rid] = prompt
        self.engine.step()
        self._sample()

    def _sample(self) -> None:
        sched = self.engine.scheduler
        terminal = sum(1 for r in sched.requests.values()
                       if r.state in ("DONE", "FAILED"))
        self.samples.append({
            "submitted": sched._next_rid,
            "completed": terminal,
            "queued": sched.pending(),
            "in_flight": len(sched.in_flight()),
        })
        if getattr(self.engine, "paged", False):
            # page accounting rides along every request-conservation
            # sample: free + held == total and refcounts consistent at
            # every step, across kills and drains (check_page_conservation)
            self.page_samples.append(self.engine.page_conservation())
        self.drained_series.append(len(sched.retried_rids))

    def run(self, max_steps: Optional[int] = None) -> Dict[int, List[int]]:
        """Step until the scenario horizon has passed AND every request is
        done; returns rid -> tokens.  ``max_steps`` guards liveness."""
        if max_steps is None:
            max_steps = int(4 * self.scenario.horizon + 200
                            + 8 * self.max_new_tokens
                            * max(self.base_rate, 1))
        start = self.engine.engine_step
        while (self.engine.engine_step <= self.scenario.horizon
               or not self.engine.scheduler.all_done()):
            if self.engine.engine_step - start > max_steps:
                raise RuntimeError(
                    f"scenario {self.scenario.name!r} did not drain after "
                    f"{max_steps} engine steps")
            self.step()
        return self.engine.results()

    def report(self) -> Dict:
        return {"scenario": self.scenario.name,
                "submitted": len(self.submitted_rids),
                "rejected": self.rejected,
                "retried": len(set(self.engine.scheduler.retried_rids)),
                "skipped": sorted(set(self.skipped)),
                "pending_injections": len(self.injector.pending())}
