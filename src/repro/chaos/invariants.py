"""Standing invariants every chaos run must hold — shared by tests and
``benchmarks/bench_chaos.py``.

Each ``check_*`` returns an ``InvariantResult`` (never raises), so a bench
can report pass RATES across a scenario library; ``verify`` turns a result
list into hard assertions for tests.  The catalog (docs/chaos.md):

- **zero-drop**: every admitted request finishes DONE — failover may retry,
  admission control may reject at submit, but nothing admitted is lost.
- **token-identical**: retried greedy streams match the uninterrupted
  reference token for token (greedy decode is a pure function of the
  prompt).
- **trajectory-match**: the training loss history after rollback/reshard
  matches the uninterrupted reference (bit-exact on one mesh; within a
  tolerance across mesh widths — bf16 cross-mesh reduction-order noise).
- **no-lost-steps**: one loss record per superstep, none repeated.
- **no-dead-growth**: the mesh never grows onto a host that was dead at
  grow time (the (inc, seq) rejoin-ordering guarantee).
- **monotonic-drain**: drained-request accounting only ever increases, and
  submitted == completed + queued + in-flight + rejected at every sample.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class InvariantViolation(AssertionError):
    """A chaos invariant did not hold (raised by ``verify``)."""


@dataclasses.dataclass(frozen=True)
class InvariantResult:
    name: str
    passed: bool
    detail: str = ""

    def __bool__(self) -> bool:
        return self.passed


def _ok(name: str, detail: str = "") -> InvariantResult:
    return InvariantResult(name, True, detail)


def _bad(name: str, detail: str) -> InvariantResult:
    return InvariantResult(name, False, detail)


# ---------------------------------------------------------------------------
# serving plane
# ---------------------------------------------------------------------------

def check_zero_drop(scheduler, submitted_rids: Optional[Iterable[int]] = None
                    ) -> InvariantResult:
    """Every admitted request reached DONE.  ``scheduler`` is the engine's
    ``Scheduler`` (or any object with ``requests``/``failed_rids``);
    ``submitted_rids`` defaults to every request the scheduler has seen.
    Call before results are reaped (reaping evicts the records)."""
    failed = sorted(set(scheduler.failed_rids))
    if failed:
        return _bad("zero-drop", f"{len(failed)} requests FAILED past "
                    f"their retry budget: {failed[:8]}")
    rids = (set(submitted_rids) if submitted_rids is not None
            else set(scheduler.requests))
    lost = sorted(r for r in rids if r not in scheduler.requests)
    if lost:
        return _bad("zero-drop", f"{len(lost)} submitted requests have no "
                    f"record at all: {lost[:8]}")
    not_done = sorted(r for r in rids
                      if scheduler.requests[r].state != "DONE")
    if not_done:
        return _bad("zero-drop", f"{len(not_done)} requests not DONE: "
                    f"{not_done[:8]}")
    return _ok("zero-drop", f"{len(rids)} requests all DONE")


def check_token_identical(results: Dict[int, List[int]],
                          reference: Dict[int, List[int]]
                          ) -> InvariantResult:
    """Every stream in ``results`` matches ``reference`` token for token
    (retried requests included — that is the failover determinism
    guarantee)."""
    missing = sorted(set(reference) - set(results))
    if missing:
        return _bad("token-identical",
                    f"streams missing from results: {missing[:8]}")
    for rid in sorted(reference):
        if list(results[rid]) != list(reference[rid]):
            return _bad("token-identical",
                        f"stream {rid} diverged: got {results[rid][:8]}... "
                        f"want {reference[rid][:8]}...")
    return _ok("token-identical", f"{len(reference)} streams bit-exact")


# ---------------------------------------------------------------------------
# training plane
# ---------------------------------------------------------------------------

def check_trajectory_match(losses: Sequence[float],
                           ref_losses: Sequence[float],
                           tol: float = 0.15) -> InvariantResult:
    """Loss trajectory matches the uninterrupted reference within ``tol``
    per step (``tol=0`` demands bit-exact — same mesh, bit-exact
    rollback)."""
    if len(losses) != len(ref_losses):
        return _bad("trajectory-match",
                    f"{len(losses)} loss records vs {len(ref_losses)} "
                    "reference steps")
    for i, (a, b) in enumerate(zip(losses, ref_losses)):
        if (a != b) if tol == 0 else (abs(a - b) > tol):
            return _bad("trajectory-match",
                        f"step {i}: loss {a} vs reference {b} "
                        f"(tol={tol})")
    return _ok("trajectory-match", f"{len(losses)} steps within {tol}")


def check_no_lost_steps(history: Sequence[Dict], num_steps: int
                        ) -> InvariantResult:
    """Exactly one loss record per superstep 1..num_steps — failover
    replay must neither skip nor double-count a step in the merged
    history."""
    steps = [h["step"] for h in history if "loss" in h]
    want = list(range(1, num_steps + 1))
    if steps != want:
        return _bad("no-lost-steps", f"superstep records {steps[:12]}... "
                    f"!= 1..{num_steps}")
    return _ok("no-lost-steps", f"{num_steps} supersteps, each exactly once")


def check_no_dead_growth(grow_events: Sequence[Tuple[float, Sequence[int]]],
                         dead_intervals: Dict[int, List[Tuple[float, float]]]
                         ) -> InvariantResult:
    """No grow event added a host that was dead when it fired.

    ``grow_events``: [(t, hosts_added)]; ``dead_intervals``: host ->
    [(t_dead, t_alive_again)] with ``float('inf')`` for never-rejoined.
    The heartbeat layer's (inc, seq) ordering is what makes this hold:
    a stale in-flight datagram from a dead host must not read as a
    rejoin."""
    for t, hosts in grow_events:
        for h in hosts:
            for dead_at, alive_at in dead_intervals.get(h, ()):
                if dead_at <= t < alive_at:
                    return _bad("no-dead-growth",
                                f"grow at t={t} added host {h}, dead over "
                                f"[{dead_at}, {alive_at})")
    return _ok("no-dead-growth", f"{len(grow_events)} grow events clean")


# ---------------------------------------------------------------------------
# accounting (serving + simulator)
# ---------------------------------------------------------------------------

def check_monotonic_drain(drained_series: Sequence[int]) -> InvariantResult:
    """Cumulative drained-request count never decreases (a decrement means
    a drained request vanished from the accounting)."""
    for i in range(1, len(drained_series)):
        if drained_series[i] < drained_series[i - 1]:
            return _bad("monotonic-drain",
                        f"drained count fell {drained_series[i - 1]} -> "
                        f"{drained_series[i]} at sample {i}")
    return _ok("monotonic-drain", f"{len(drained_series)} samples "
               "non-decreasing")


def check_conservation(samples: Sequence[Dict[str, int]]) -> InvariantResult:
    """At every sample: submitted == completed + queued + in_flight +
    rejected.  A leak on either side is a dropped or duplicated request."""
    for i, s in enumerate(samples):
        have = (s["completed"] + s["queued"] + s["in_flight"]
                + s.get("rejected", 0))
        if have != s["submitted"]:
            return _bad("request-conservation",
                        f"sample {i}: submitted={s['submitted']} but "
                        f"accounted={have} ({s})")
    return _ok("request-conservation", f"{len(samples)} samples balanced")


def check_page_conservation(samples: Sequence[Dict[str, int]]
                            ) -> InvariantResult:
    """Paged-KV accounting (serve/page_table.py): at every sample,
    free + held == total, reservations never exceed the free list, and
    the pool's refcount audit came back clean — across admissions, prefix
    sharing, copy-on-write, and ``release_all`` drains.  A violation is a
    page leak or double-free.  Samples come from
    ``ServeEngine.page_conservation()`` (``ServeScenarioDriver`` records
    one per step in ``page_samples``)."""
    if not samples:
        return _bad("page-conservation",
                    "no page samples recorded (engine not paged?)")
    for i, s in enumerate(samples):
        if s["pages_free"] + s["pages_held"] != s["pages_total"]:
            return _bad("page-conservation",
                        f"sample {i}: free={s['pages_free']} + "
                        f"held={s['pages_held']} != "
                        f"total={s['pages_total']}")
        if s["pages_reserved"] > s["pages_free"]:
            return _bad("page-conservation",
                        f"sample {i}: {s['pages_reserved']} pages "
                        f"reserved but only {s['pages_free']} free")
        if not s["refs_ok"]:
            return _bad("page-conservation",
                        f"sample {i}: refcount audit failed ({s})")
    return _ok("page-conservation", f"{len(samples)} samples balanced")


# ---------------------------------------------------------------------------
# telemetry plane
# ---------------------------------------------------------------------------

#: proactive actions the telemetry plane takes on a precursor
ACT_KINDS = (("checkpoint", "proactive"), ("serve", "replica_predrained"))


def check_detect_before_act(events) -> InvariantResult:
    """The telemetry plane's detect -> act ordering (docs/observability.md):

    - at least one ``precursor/*`` event fired (the detectors saw the
      staged symptom at all);
    - every proactive ACT — a forced checkpoint (``checkpoint/proactive``)
      or a serve pre-drain (``serve/replica_predrained``) — happens at or
      after the first precursor (nothing acts on a prediction that does
      not exist yet);
    - every observed failure of a host a precursor named — a
      ``heartbeat/failure`` for that host, or a ``serve/replica_failed``
      whose ``hosts`` include it — happens after that host's first
      precursor: the plane predicted the failures it claims to predict.

    ``events`` is any ``Event`` sequence (bus ring, collector merge, or
    ``load_jsonl``)."""
    name = "detect-before-act"
    evs = sorted(events, key=lambda e: (e.t_mono, e.seq))
    first_any: Optional[float] = None
    first_by_host: Dict[int, float] = {}
    for e in evs:
        if e.subsystem == "precursor":
            if first_any is None:
                first_any = e.t_mono
            h = e.data.get("host")
            if h is not None:
                first_by_host.setdefault(int(h), e.t_mono)
    if first_any is None:
        return _bad(name, "no precursor/* event fired")
    for e in evs:
        if (e.subsystem, e.kind) in ACT_KINDS and e.t_mono < first_any:
            return _bad(name,
                        f"{e.subsystem}/{e.kind} at t={e.t_mono:.3f} "
                        f"precedes the first precursor "
                        f"(t={first_any:.3f})")
    for e in evs:
        hosts: List[int] = []
        if (e.subsystem, e.kind) == ("heartbeat", "failure") and \
                e.data.get("host") is not None:
            hosts = [int(e.data["host"])]
        elif (e.subsystem, e.kind) == ("serve", "replica_failed"):
            hosts = [int(h) for h in e.data.get("hosts", ())]
        for h in hosts:
            if h in first_by_host and e.t_mono < first_by_host[h]:
                return _bad(name,
                            f"host {h} failed at t={e.t_mono:.3f} "
                            f"before its first precursor "
                            f"(t={first_by_host[h]:.3f})")
    acts = sum(1 for e in evs if (e.subsystem, e.kind) in ACT_KINDS)
    return _ok(name, f"{sum(1 for e in evs if e.subsystem == 'precursor')}"
               f" precursors before {acts} proactive acts")


# ---------------------------------------------------------------------------
# suite helpers
# ---------------------------------------------------------------------------

def verify(results: Iterable[InvariantResult]) -> List[InvariantResult]:
    """Raise ``InvariantViolation`` listing every failed invariant;
    returns the results when all pass (test-side entry point)."""
    results = list(results)
    failed = [r for r in results if not r.passed]
    if failed:
        raise InvariantViolation(
            "; ".join(f"{r.name}: {r.detail}" for r in failed))
    return results


def pass_rate(results: Iterable[InvariantResult]) -> float:
    results = list(results)
    if not results:
        return 1.0
    return sum(1 for r in results if r.passed) / len(results)


def summarize(results: Iterable[InvariantResult]) -> Dict[str, bool]:
    """name -> passed map for machine-readable bench output."""
    return {r.name: r.passed for r in results}
