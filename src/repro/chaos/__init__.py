"""Chaos scenario engine: trace-driven correlated-failure injection and a
cluster-scale control-plane simulator (docs/chaos.md).

One declarative ``Scenario`` (timed kills, partitions, SDC storms,
stragglers, traffic spikes, rejoins) replays against three planes with the
same semantics: the elastic training loop (``run_scenario_elastic``), the
serving engine (``ServeScenarioDriver``), and a device-free simulator that
validates the control-plane protocol at thousands of virtual hosts
(``ControlPlaneSim``).  ``invariants`` holds the standing post-run checks
every plane is audited against.
"""
from repro.chaos.driver import (ServeScenarioDriver, TrainScenarioDriver,
                                run_scenario_elastic)
from repro.chaos.invariants import (InvariantResult, InvariantViolation,
                                    check_conservation,
                                    check_detect_before_act,
                                    check_monotonic_drain,
                                    check_no_dead_growth,
                                    check_no_lost_steps,
                                    check_page_conservation,
                                    check_token_identical,
                                    check_trajectory_match, check_zero_drop,
                                    pass_rate, summarize, verify)
from repro.chaos.scenario import (ChaosEvent, Scenario, ScenarioError,
                                  KINDS, WINDOW_KINDS)
from repro.chaos.sim import ControlPlaneSim, SimReport

__all__ = [
    "ChaosEvent", "ControlPlaneSim", "InvariantResult",
    "InvariantViolation", "KINDS", "Scenario", "ScenarioError",
    "ServeScenarioDriver", "SimReport", "TrainScenarioDriver",
    "WINDOW_KINDS", "check_conservation", "check_detect_before_act",
    "check_monotonic_drain",
    "check_no_dead_growth", "check_no_lost_steps",
    "check_page_conservation", "check_token_identical",
    "check_trajectory_match", "check_zero_drop", "pass_rate",
    "run_scenario_elastic", "summarize", "verify",
]
