"""Cluster-scale control-plane simulator: the heartbeat / policy / mesh /
drain logic at thousands of hosts, with no devices and no real clock.

The real stack caps out at what one process can host (8 XLA host devices,
a handful of UDP emitters).  The decisions the dependability layer makes,
though — who is dead, what mesh survives, how the checkpoint cadence
tracks fleet size, whether a stale datagram can resurrect a corpse — are
pure control-plane logic.  ``ControlPlaneSim`` re-implements the *protocol*
(the same (inc, seq) beat ordering as ``core/heartbeat.py``, the same
``largest_grid`` mesh selection, the real ``CheckpointPolicy`` object) on
a synthetic tick clock, so a scenario can be replayed against 1000+
virtual hosts in well under a minute:

- **liveness**: every alive, un-partitioned host delivers one beat per
  tick; the monitor model times hosts out after ``timeout_factor`` beat
  periods, exactly like ``HeartbeatMonitor``.  Detection latency (kill ->
  declared dead) is recorded per failure.
- **stale rejoin ordering**: a kill strands a few in-flight datagrams
  carrying the dead host's old (inc, seq); they deliver AFTER the host
  was excluded and must be rejected — a rejoin requires a beat ordered
  after the last accepted one, and a real rejoin bumps ``inc`` (emitter
  lifetime), so only a genuinely restarted host grows the mesh.
- **mesh selection**: each exclusion/rejoin rebuilds the member set and
  recomputes the (data, model) grid via the real ``largest_grid``.
- **Young/Daly cadence**: the real ``CheckpointPolicy`` is re-sized at
  every membership change (``system.num_nodes`` follows the mesh) and its
  ``interval_steps`` is checked tick-by-tick against the closed-form
  ``young_daly_period`` — the cadence must track fleet MTBF as the fleet
  shrinks and regrows.
- **drain/requeue accounting**: a serve-plane queue model (arrivals x
  traffic-spike multiplier, per-host slots, fixed service time) drains a
  dead host's in-flight work back to the queue; ``invariants``'
  conservation and monotonic-drain checks audit every tick.

The output (``SimReport``) feeds ``benchmarks/bench_chaos.py`` and the
tier-1 test ``tests/test_chaos.py::test_sim_thousand_hosts``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.chaos import invariants as inv
from repro.chaos.scenario import Scenario, ScenarioError
from repro.core.elastic import (MeshSpec, NoSurvivorsError, best_grid3d,
                                largest_grid)
from repro.core.policy import CheckpointPolicy, SystemModel, young_daly_period


def _pctl(xs, q: float) -> float:
    """Nearest-rank percentile (same convention as ``serve.engine.pctl``,
    re-stated here so the simulator stays import-light)."""
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


@dataclasses.dataclass
class _Host:
    alive: bool = True
    inc: int = 1          # emitter lifetime — bumps on every restart
    seq: int = 0
    t_killed: Optional[float] = None


@dataclasses.dataclass
class SimReport:
    name: str
    num_hosts: int
    ticks: int
    wall_seconds: float
    detections: List[Dict]            # {"host", "t_lost", "t_detected"}
    grow_events: List[Tuple[float, List[int]]]
    stale_delivered: int
    stale_rejected: int
    mesh_history: List[Dict]          # {"t", "members", "dp", "mp"}
    cadence: List[Dict]               # {"t", "nodes", "interval", "expected"}
    invariants: List[inv.InvariantResult]
    drained_total: int
    completed_total: int

    @property
    def detection_latencies(self) -> List[float]:
        return [d["t_detected"] - d["t_lost"] for d in self.detections]

    @property
    def cadence_ok(self) -> bool:
        return all(c["interval"] == c["expected"] for c in self.cadence)

    def to_dict(self) -> Dict:
        lat = self.detection_latencies
        return {
            "name": self.name,
            "num_hosts": self.num_hosts,
            "ticks": self.ticks,
            "wall_seconds": round(self.wall_seconds, 3),
            "detected": len(self.detections),
            "detection_latency_p50": _pctl(lat, 0.50),
            "detection_latency_p99": _pctl(lat, 0.99),
            "grow_events": len(self.grow_events),
            "stale_delivered": self.stale_delivered,
            "stale_rejected": self.stale_rejected,
            "mesh_changes": len(self.mesh_history),
            "final_dp": (self.mesh_history[-1]["dp"]
                         if self.mesh_history else None),
            "cadence_checks": len(self.cadence),
            "cadence_ok": self.cadence_ok,
            "drained": self.drained_total,
            "completed": self.completed_total,
            "invariants": inv.summarize(self.invariants),
            "invariant_pass_rate": inv.pass_rate(self.invariants),
        }


class ControlPlaneSim:
    """See the module docstring.  ``devices_per_host`` sizes the grid the
    mesh selection reasons over; serve-plane knobs (``base_rate``,
    ``slots_per_host``, ``service_ticks``) shape the drain model."""

    def __init__(self, num_hosts: int, *,
                 period: float = 0.1,
                 timeout_factor: float = 5.0,
                 devices_per_host: int = 1,
                 model_axis: int = 1,
                 mesh_spec: Optional[MeshSpec] = None,
                 monitor_host: int = 0,
                 stale_in_flight: int = 3,
                 stale_delay_ticks: int = 2,
                 node_mtbf_seconds: float = 3.15e7,
                 ckpt_cost_s: float = 30.0,
                 step_time_s: float = 1.0,
                 base_rate: int = 0,
                 slots_per_host: int = 4,
                 service_ticks: int = 3):
        if num_hosts < 1:
            raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
        self.num_hosts = num_hosts
        self.period = period
        self.timeout = timeout_factor * period
        self.devices_per_host = devices_per_host
        self.model_axis = model_axis
        # 3D mode: mesh selection runs the real best_grid3d factorization
        # (legal tp widths, ep | experts, ep -> dp -> tp degradation) and
        # every host gets (dp, tp, ep) coordinates — the 1000-host traces
        # validate the same shrink protocol run_elastic executes on devices
        self.mesh_spec = mesh_spec
        self.monitor_host = monitor_host
        self.stale_in_flight = stale_in_flight
        self.stale_delay_ticks = stale_delay_ticks
        self.node_mtbf_seconds = node_mtbf_seconds
        self.ckpt_cost_s = ckpt_cost_s
        self.step_time_s = step_time_s
        self.base_rate = base_rate
        self.slots_per_host = slots_per_host
        self.service_ticks = service_ticks

    # ------------------------------------------------------------------
    # axis-aware host coordinates (3D mode)
    # ------------------------------------------------------------------
    def host_coords(self, members=None) -> Dict[int, Tuple[int, int, int]]:
        """host id -> (data, model, expert) coordinate of its FIRST device
        under the current members' best legal grid.  Placement matches
        ``core.elastic.survivor_mesh3d`` exactly — expert-major, hosts own
        contiguous device ranges — so a trace replayed here excludes the
        same expert slice the device-backed loop would.  Hosts whose
        devices fall off the grid (n not a multiple of dp*tp*ep) map to
        no coordinate and are omitted."""
        if self.mesh_spec is None:
            raise ValueError("host_coords requires mesh_spec (3D mode)")
        live = sorted(range(self.num_hosts) if members is None else members)
        n = len(live) * self.devices_per_host
        dp, tp, ep = best_grid3d(n, self.mesh_spec)
        out: Dict[int, Tuple[int, int, int]] = {}
        for pos, h in enumerate(live):
            v = pos * self.devices_per_host      # first device's flat index
            if v >= dp * tp * ep:
                continue
            k, rem = divmod(v, dp * tp)
            i, j = divmod(rem, tp)
            out[h] = (i, j, k)
        return out

    def _legal_grid_entry(self, m: Dict) -> bool:
        spec = self.mesh_spec
        dp, tp, ep = m["dp"], m["mp"], m.get("ep", 1)
        n = m["members"] * self.devices_per_host
        if dp * tp * ep > n or min(dp, tp, ep) < 1:
            return False
        if spec.legal_model is not None and tp not in spec.legal_model:
            return False
        if spec.num_experts and spec.num_experts % ep:
            return False
        return tp <= spec.model and ep <= max(spec.expert, 1)

    # ------------------------------------------------------------------
    # clock
    # ------------------------------------------------------------------
    def _tick_of(self, at: float, clock: str) -> int:
        """Scenario event time -> tick index.  clock='step': one superstep
        per tick; clock='time': virtual seconds over the beat period."""
        return int(at) if clock == "step" else int(round(at / self.period))

    # ------------------------------------------------------------------
    # the run
    # ------------------------------------------------------------------
    def run(self, scenario: Scenario, extra_ticks: Optional[int] = None
            ) -> SimReport:
        import time as _time
        scenario.validate()
        t_wall = _time.perf_counter()
        clock = scenario.clock
        if extra_ticks is None:
            # past the horizon: room for the timeout to expire and the
            # queue to drain
            extra_ticks = (int(self.timeout / self.period) + 2
                           + 4 * self.service_ticks + 4)
        ticks = self._tick_of(scenario.horizon, clock) + extra_ticks

        hosts = [_Host() for _ in range(self.num_hosts)]
        # monitor model state (mirrors HeartbeatMonitor fields)
        last_beat: Dict[int, Tuple[int, int]] = {}
        last_seen: Dict[int, float] = {}
        excluded: set = set()
        failed: set = set()
        members = set(range(self.num_hosts))

        # scenario events, pre-bucketed by tick
        kills: Dict[int, List[int]] = {}
        rejoins: Dict[int, List[int]] = {}
        for ev in scenario.point_events("kill_hosts"):
            for h in ev.args["hosts"]:
                if not 0 <= h < self.num_hosts:
                    raise ScenarioError(
                        f"kill_hosts targets host {h}; sim has "
                        f"{self.num_hosts}")
                kills.setdefault(self._tick_of(ev.at, clock), []).append(h)
        for ev in scenario.window_events("precursor_storm"):
            # the straggle itself is invisible to the control plane (the
            # host keeps beating); the deferred kill is not
            if not ev.args["kill"]:
                continue
            h = ev.args["host"]
            if not 0 <= h < self.num_hosts:
                raise ScenarioError(
                    f"precursor_storm targets host {h}; sim has "
                    f"{self.num_hosts}")
            kills.setdefault(self._tick_of(ev.until, clock),
                             []).append(h)
        for ev in scenario.point_events("rejoin"):
            rejoins.setdefault(self._tick_of(ev.at, clock), []).append(
                ev.args["host"])
        partitions = [(self._tick_of(ev.at, clock),
                       self._tick_of(ev.until, clock), ev.args["groups"])
                      for ev in scenario.window_events("partition")]
        spikes = [(self._tick_of(ev.at, clock),
                   self._tick_of(ev.until, clock), ev.args["mult"])
                  for ev in scenario.window_events("traffic_spike")]
        # datagrams stranded in flight: (deliver_tick, host, inc, seq)
        stale_queue: List[Tuple[int, int, int, int]] = []

        policy = CheckpointPolicy(
            mode="young_daly",
            system=SystemModel(node_mtbf_seconds=self.node_mtbf_seconds,
                               num_nodes=len(members)))
        policy.observe_step(self.step_time_s)
        policy.observe_checkpoint(self.ckpt_cost_s)

        detections: List[Dict] = []
        grow_events: List[Tuple[float, List[int]]] = []
        mesh_history: List[Dict] = []
        cadence: List[Dict] = []
        stale_delivered = stale_rejected = 0
        dead_intervals: Dict[int, List[Tuple[float, float]]] = {}
        dead_open: Dict[int, float] = {}

        # serve-plane drain model
        queued = in_flight_n = completed = submitted = 0
        host_flight: Dict[int, List[int]] = {h: [] for h in members}
        drained_series: List[int] = []
        drained_total = 0
        samples: List[Dict[str, int]] = []

        def record_mesh(now: float) -> None:
            n = len(members) * self.devices_per_host
            if self.mesh_spec is not None:
                dp, mp, ep = best_grid3d(n, self.mesh_spec)
            else:
                dp, mp = largest_grid(n, self.model_axis)
                ep = 1
            mesh_history.append({"t": now, "members": len(members),
                                 "dp": dp, "mp": mp, "ep": ep})
            policy.system.num_nodes = len(members)

        def dropped_by_partition(h: int, tick: int) -> bool:
            for t0, t1, groups in partitions:
                if t0 <= tick < t1:
                    keep = next((g for g in groups
                                 if self.monitor_host in g), groups[0])
                    if any(h in g for g in groups if g is not keep):
                        return True
            return False

        def accept_beat(h: int, inc: int, seq: int, now: float) -> bool:
            """The (inc, seq) ordering rule of ``HeartbeatMonitor``: a
            beat counts only if strictly newer than the last accepted."""
            if last_beat.get(h, (0, -1)) >= (inc, seq):
                return False
            last_beat[h] = (inc, seq)
            last_seen[h] = now
            return True

        record_mesh(0.0)
        for tick in range(ticks):
            now = tick * self.period

            # -- scenario events due this tick --------------------------
            for h in kills.get(tick, ()):
                host = hosts[h]
                if not host.alive:
                    continue
                host.alive = False
                host.t_killed = now
                dead_open[h] = now
                # strand the last few datagrams "on the wire"
                for k in range(self.stale_in_flight):
                    stale_queue.append(
                        (tick + self.stale_delay_ticks + k, h,
                         host.inc, max(host.seq - k, 0)))
            for h in rejoins.get(tick, ()):
                host = hosts[h]
                if host.alive:
                    continue
                host.alive = True
                host.inc += 1     # emitter restart stamps a new lifetime
                host.seq = 0
                host.t_killed = None
                if h in dead_open:
                    dead_intervals.setdefault(h, []).append(
                        (dead_open.pop(h), now))

            # -- beat delivery ------------------------------------------
            for h, host in enumerate(hosts):
                if not host.alive:
                    continue
                host.seq += 1
                if dropped_by_partition(h, tick):
                    continue      # seq advanced, datagram lost: asymmetric
                newer = accept_beat(h, host.inc, host.seq, now)
                if newer and h in excluded:
                    # ordered-after-exclusion beat: genuine rejoin
                    excluded.discard(h)
                    failed.discard(h)
                    members.add(h)
                    host_flight[h] = []
                    grow_events.append((now, [h]))
                    record_mesh(now)

            # -- stale in-flight datagrams ------------------------------
            still = []
            for due, h, inc, seq in stale_queue:
                if due != tick:
                    still.append((due, h, inc, seq))
                    continue
                stale_delivered += 1
                if not accept_beat(h, inc, seq, now):
                    stale_rejected += 1
                elif h in excluded:
                    # accepted AND excluded would be a protocol hole: a
                    # corpse grew the mesh (check_no_dead_growth flags it)
                    excluded.discard(h)
                    members.add(h)
                    grow_events.append((now, [h]))
                    record_mesh(now)
            stale_queue = still

            # -- timeout detection --------------------------------------
            for h in sorted(members):
                if h in failed or h in excluded:
                    continue
                seen = last_seen.get(h, 0.0)
                if now - seen > self.timeout:
                    failed.add(h)
                    host = hosts[h]
                    t_lost = (host.t_killed if host.t_killed is not None
                              else seen)
                    detections.append({"host": h, "t_lost": t_lost,
                                       "t_detected": now})

            # -- control plane: acknowledge + shrink --------------------
            newly = sorted(failed - excluded)
            if newly:
                for h in newly:
                    excluded.add(h)
                    members.discard(h)
                    # drain the dead host's in-flight work to the queue
                    lost = host_flight.pop(h, [])
                    drained_total += len(lost)
                    queued += len(lost)
                    in_flight_n -= len(lost)
                if not members:
                    raise NoSurvivorsError(
                        f"sim: every host dead at t={now}")
                record_mesh(now)

            # -- Young/Daly cadence check -------------------------------
            interval = policy.interval_steps()
            t_opt = young_daly_period(
                self.node_mtbf_seconds / max(len(members), 1),
                self.ckpt_cost_s, policy.system.restart_seconds,
                policy.system.downtime_seconds, formula=policy.formula)
            expected = max(policy.min_interval,
                           min(int(round(t_opt / self.step_time_s)),
                               policy.max_interval))
            cadence.append({"t": now, "nodes": len(members),
                            "interval": interval, "expected": expected})

            # -- serve-plane queue model --------------------------------
            if self.base_rate:
                mult = 1.0
                for t0, t1, m in spikes:
                    if t0 <= tick < t1:
                        mult = max(mult, m)
                arrivals = int(round(self.base_rate * mult))
                submitted += arrivals
                queued += arrivals
                # completions first (frees slots), then admissions
                for h in sorted(members):
                    fl = host_flight.setdefault(h, [])
                    done = [d for d in fl if d <= tick]
                    completed += len(done)
                    in_flight_n -= len(done)
                    host_flight[h] = [d for d in fl if d > tick]
                for h in sorted(members):
                    fl = host_flight[h]
                    while queued and len(fl) < self.slots_per_host:
                        fl.append(tick + self.service_ticks)
                        queued -= 1
                        in_flight_n += 1
                drained_series.append(drained_total)
                samples.append({"submitted": submitted,
                                "completed": completed,
                                "queued": queued,
                                "in_flight": in_flight_n})

        for h, t0 in dead_open.items():
            dead_intervals.setdefault(h, []).append((t0, float("inf")))

        checks = [inv.check_no_dead_growth(grow_events, dead_intervals),
                  inv.check_monotonic_drain(drained_series)]
        if samples:
            checks.append(inv.check_conservation(samples))
        if self.mesh_spec is not None:
            bad = [m for m in mesh_history
                   if not self._legal_grid_entry(m)]
            checks.append(inv.InvariantResult(
                "legal-3d-grid", not bad,
                (f"{len(bad)} illegal grids: {bad[:3]}" if bad else
                 f"{len(mesh_history)} grids legal under "
                 f"(tp|heads, ep|experts)")))
        if not self.cadence_tolerated(cadence):
            checks.append(inv.InvariantResult(
                "young-daly-cadence", False,
                "policy interval diverged from closed form"))
        else:
            checks.append(inv.InvariantResult(
                "young-daly-cadence", True,
                f"{len(cadence)} ticks track eq. (1)"))

        return SimReport(
            name=scenario.name, num_hosts=self.num_hosts, ticks=ticks,
            wall_seconds=_time.perf_counter() - t_wall,
            detections=detections, grow_events=grow_events,
            stale_delivered=stale_delivered, stale_rejected=stale_rejected,
            mesh_history=mesh_history, cadence=cadence, invariants=checks,
            drained_total=drained_total, completed_total=completed)

    @staticmethod
    def cadence_tolerated(cadence: List[Dict]) -> bool:
        return all(c["interval"] == c["expected"] for c in cadence)
