"""Sharded, atomic, resharding-on-restore checkpoint manager (from scratch).

DeLIA mapping (DESIGN.md S2):
- *global state*  = any pytree (TrainState): each host writes only its
  addressable shards + metadata; restore can target ANY mesh/sharding
  (elastic recovery) because the manifest records global shapes and every
  shard's index span.
- *local state*   = small JSON dict per host (data-pipeline cursor etc.).

Layout (one directory per step):

    <dir>/step_00000420/
        manifest.json               global shapes/dtypes/codec/CRCs
        <leaf-name>.s<k>.npy        shard k of that leaf (np .npy payload)
        local_h<i>.json             per-host local state
        ack_h<i>                    per-host completion marker
    <dir>/step_00000420.tmp.<pid>   staging dir, atomically renamed

Commit protocol: every host writes shards + ack into the staging dir; host 0
renames it into place once all acks are present (single-process runs commit
immediately).  A reader only trusts directories whose manifest parses and
whose CRCs verify — a crash mid-write never corrupts the latest checkpoint.

Fast path (the Young/Daly C term, end to end):

1. *Snapshot* (the only on-critical-path cost in async mode): with
   ``device_codec=True`` each floating leaf >= 1 KiB is quantized to int8 +
   per-block fp32 scales *on device* (Pallas kernel on TPU, jnp twin
   elsewhere — see core/codec.DeviceCodec) and the int8 payload is what
   crosses the device->host link: ~3.9x fewer bytes than fp32.  All shards
   transfer in one batched ``jax.device_get``.
2. *Write*: shards are encoded (host codec, if any) and written
   concurrently by a ``ShardIOEngine`` thread pool; each ``.npy`` is
   streamed through memoryview chunks with the CRC32 computed in the same
   pass — no ``tobytes()`` copies anywhere.
3. *Durability*: fsync is batched — files first, then one directory fsync —
   instead of a per-file write->fsync lockstep (``fsync`` mode knob).
4. *Restore*: shard loads and leaf assembly are parallelized on the same
   pool; CRC verification is zero-copy over the loaded buffers.

Async mode: ``save(..., blocking=False)`` snapshots device arrays to host
memory and hands serialization to a writer thread (double-buffered: a new
save drains the previous one; ``wait()`` re-raises writer errors).
"""
from __future__ import annotations

import functools
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import CODECS, Codec, DeviceCodec
from repro.core.io_engine import (ShardIOEngine, crc32_array, fsync_path,
                                  read_json, write_json, write_npy)

_STEP_RE = re.compile(r"^step_(\d{8})$")
_LOCAL_SHARD_RE = re.compile(r"^local_s(\d{5})\.json$")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def _flatten_named(tree) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_leaf_name(p), v) for p, v in leaves]


class SaveStats:
    def __init__(self, step, bytes_written, snapshot_s, write_s, blocking):
        self.step = step
        self.bytes_written = bytes_written
        self.snapshot_seconds = snapshot_s
        self.write_seconds = write_s
        self.blocking = blocking

    def __repr__(self):
        return (f"SaveStats(step={self.step}, MB={self.bytes_written/1e6:.1f},"
                f" snapshot={self.snapshot_seconds:.3f}s,"
                f" write={self.write_seconds:.3f}s, blocking={self.blocking})")


class CheckpointManager:
    def __init__(self, directory: str, *, host_id: int = 0, num_hosts: int = 1,
                 codec: Optional[str] = None, device_codec: bool = False,
                 io_threads: int = 0, fsync: str = "batch",
                 verify_crc: bool = True, keep: int = 3):
        self.directory = directory
        self.host_id = host_id
        self.num_hosts = num_hosts
        if device_codec:
            if codec not in (None, "int8"):
                raise ValueError(
                    f"device_codec implies the int8 layout, got codec={codec!r}")
            codec = "int8"
        self.codec: Optional[Codec] = CODECS[codec] if codec else None
        self.codec_name = codec
        self._dcodec: Optional[DeviceCodec] = (DeviceCodec()
                                               if device_codec else None)
        self._engine = ShardIOEngine(threads=io_threads, fsync_mode=fsync)
        self.verify_crc = verify_crc
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        self._writer_err: Optional[BaseException] = None

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def _staging(self, step: int) -> str:
        return os.path.join(self.directory,
                            f"step_{step:08d}.tmp.{os.getpid()}")

    def _final(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _shards_of(self, value):
        """Addressable shards of a jax.Array (kept on device) or a single
        numpy shard; (spans, data) pairs."""
        if isinstance(value, jax.Array) and hasattr(value, "addressable_shards"):
            out = []
            for sh in value.addressable_shards:
                idx = sh.index  # tuple of slices into the global array
                spans = [[s.start or 0,
                          s.stop if s.stop is not None else dim]
                         for s, dim in zip(idx, value.shape)] or []
                out.append((sh.replica_id, spans, sh.data))
            # only keep replica 0 to avoid duplicate writes
            return [(spans, data) for rid, spans, data in out if rid == 0]
        arr = np.asarray(value)
        spans = [[0, d] for d in arr.shape]
        return [(spans, arr)]

    def _snapshot(self, named):
        """Device -> host: the only cost on the BSP critical path in async
        mode.  With device_codec, eligible leaves are quantized on device
        first so only int8 + scales cross the link; all device buffers move
        in one batched device_get.  Returns (shard_plan, manifest_arrays)
        where each plan item owns its manifest shard-meta dict (mutated by
        the writer jobs with codec/crc info before the manifest is dumped).
        """
        plan: List[Dict[str, Any]] = []
        manifest_arrays: Dict[str, Any] = {}
        dev: List[Any] = []          # device arrays awaiting transfer
        fill: List[Tuple[Any, Any]] = []  # (container, key) to fill per dev
        for name, value in named:
            shards = self._shards_of(value)
            first = shards[0][1]
            dtype = str(first.dtype if hasattr(first, "dtype")
                        else np.asarray(first).dtype)
            entry = {"shape": list(np.shape(value)), "dtype": dtype,
                     "shards": []}
            for k, (spans, data) in enumerate(shards):
                fname = f"{name}.s{self.host_id}_{k}.npy"
                smeta: Dict[str, Any] = {"file": fname, "spans": spans}
                entry["shards"].append(smeta)
                item: Dict[str, Any] = {"fname": fname, "meta": smeta}
                if (self._dcodec is not None and isinstance(data, jax.Array)
                        and jnp.issubdtype(data.dtype, jnp.floating)
                        and data.size >= 1024):
                    q, s = self._dcodec.encode(data)
                    smeta["codec"] = {"name": self.codec_name,
                                      **DeviceCodec.block_meta(data.shape)}
                    item["kind"] = "parts"
                    item["parts"] = [None, None]
                    for j, a in enumerate((q, s)):
                        fill.append((item["parts"], j))
                        dev.append(a)
                elif isinstance(data, jax.Array):
                    item["kind"] = "host"
                    item["data"] = None
                    fill.append((item, "data"))
                    dev.append(data)
                else:
                    item["kind"] = "host"
                    item["data"] = data
                plan.append(item)
            manifest_arrays[name] = entry
        if dev:
            for (container, key), arr in zip(fill, jax.device_get(dev)):
                container[key] = np.asarray(arr)
        return plan, manifest_arrays

    def _write_shard(self, staging: str, item: Dict[str, Any]) -> Tuple[str, int]:
        """One writer-pool job: (host-)encode + stream one shard to disk."""
        path = os.path.join(staging, item["fname"])
        meta = item["meta"]
        per_file = self._engine.per_file_fsync
        if item["kind"] == "parts":     # device-encoded: q blocks + scales
            nbytes, crc = write_npy(path, item["parts"], fsync=per_file)
        else:
            payload = item["data"]
            if (self.codec is not None and payload.dtype in
                    (np.float32, np.float64) and payload.size >= 1024):
                payload, codec_meta = self.codec.encode(payload)
                meta["codec"] = {"name": self.codec_name, **codec_meta}
            nbytes, crc = write_npy(path, payload, fsync=per_file)
        meta["crc32"] = crc
        return path, nbytes

    def save(self, step: int, state, local_state: Optional[Dict] = None, *,
             local_shards: Optional[List[Dict]] = None,
             blocking: bool = True) -> SaveStats:
        """``local_state``: this host's local-scope dict (one file per host).
        ``local_shards``: finer-grained local scope — one dict per DP shard
        this host owns, each written as its OWN ``local_s<k>.json`` file so
        restore can remap them individually when the shard count changes
        (the feature the paper's FWI study could not enable)."""
        self.wait()  # double-buffer: drain previous async write
        t0 = time.perf_counter()
        named = _flatten_named(state)
        shard_plan, manifest_arrays = self._snapshot(named)
        snapshot_s = time.perf_counter() - t0

        def write():
            t1 = time.perf_counter()
            staging = self._staging(step)
            os.makedirs(staging, exist_ok=True)
            total, paths = self._engine.run_jobs(
                [functools.partial(self._write_shard, staging, item)
                 for item in shard_plan])
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "codec": self.codec_name,
                "arrays": manifest_arrays,
            }
            if local_shards is not None:
                manifest["local_shards"] = [int(sd.get("shard", k))
                                            for k, sd in
                                            enumerate(local_shards)]
            mpath = os.path.join(staging, f"manifest_h{self.host_id}.json")
            paths.append(write_json(mpath, manifest))
            if local_state is not None:
                lpath = os.path.join(staging, f"local_h{self.host_id}.json")
                paths.append(write_json(lpath, local_state))
            for k, sd in enumerate(local_shards or ()):
                idx = int(sd.get("shard", k))
                spath = os.path.join(staging, f"local_s{idx:05d}.json")
                paths.append(write_json(spath, sd))
            apath = os.path.join(staging, f"ack_h{self.host_id}")
            open(apath, "w").close()
            paths.append(apath)
            self._engine.finalize(staging, paths)
            # commit when all hosts acked (single-process: immediately)
            acks = [os.path.exists(os.path.join(staging, f"ack_h{h}"))
                    for h in range(self.num_hosts)]
            if all(acks) and self.host_id == 0:
                final = self._final(step)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(staging, final)
                if self._engine.fsync_mode != "none":
                    fsync_path(self.directory)  # make the rename durable
                self._gc()
            return total, time.perf_counter() - t1

        if blocking:
            total, write_s = write()
            return SaveStats(step, total, snapshot_s, write_s, True)

        stats = SaveStats(step, 0, snapshot_s, 0.0, False)

        def run():
            try:
                total, write_s = write()
                stats.bytes_written = total
                stats.write_seconds = write_s
            except BaseException as e:  # surfaced on next wait()
                self._writer_err = e

        self._writer = threading.Thread(target=run, daemon=True)
        self._writer.start()
        return stats

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._writer_err is not None:
            err, self._writer_err = self._writer_err, None
            raise err

    def close(self) -> None:
        """Drain the async writer and shut the I/O pool down."""
        self.wait()
        self._engine.close()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._final(s), ignore_errors=True)

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.directory, d,
                                                 "manifest_h0.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_manifests(self, step: int) -> Dict[str, Any]:
        final = self._final(step)
        merged: Dict[str, Any] = {}
        for h in range(self.num_hosts):
            p = os.path.join(final, f"manifest_h{h}.json")
            if not os.path.exists(p):
                continue
            with open(p) as f:
                man = json.load(f)
            for name, entry in man["arrays"].items():
                if name not in merged:
                    merged[name] = {"shape": entry["shape"],
                                    "dtype": entry["dtype"], "shards": []}
                merged[name]["shards"].extend(entry["shards"])
        return merged

    def _load_shard(self, final: str, entry: Dict[str, Any],
                    sh: Dict[str, Any]) -> np.ndarray:
        path = os.path.join(final, sh["file"])
        payload = np.load(path)
        if self.verify_crc and "crc32" in sh:
            if crc32_array(payload) != sh["crc32"]:
                raise IOError(f"CRC mismatch in {path}")
        if "codec" in sh:
            payload = CODECS[sh["codec"]["name"]].decode(payload, sh["codec"])
        want = np.dtype(entry["dtype"])
        if payload.dtype.kind == "V" and payload.dtype.itemsize == want.itemsize:
            # ml_dtypes customs (bf16, fp8) round-trip .npy as raw void
            # bytes; reinterpret rather than cast
            payload = payload.view(want)
        return payload.astype(want, copy=False)

    def _read_leaf(self, final: str, entry: Dict[str, Any], *,
                   parallel: bool = True) -> np.ndarray:
        """Reassemble one leaf from its shard spans; shard loads run on the
        I/O pool unless already inside it (parallel=False avoids nesting)."""
        shape = tuple(entry["shape"])
        shards = entry["shards"]
        if parallel and len(shards) > 1:
            payloads = self._engine.read_many(
                [functools.partial(self._load_shard, final, entry, sh)
                 for sh in shards])
        else:
            payloads = [self._load_shard(final, entry, sh) for sh in shards]
        out: Optional[np.ndarray] = None
        for sh, payload in zip(shards, payloads):
            spans = sh["spans"]
            if not spans:  # scalar
                return payload.reshape(shape)
            if out is None:
                out = np.empty(shape, dtype=entry["dtype"])
            sl = tuple(slice(a, b) for a, b in spans)
            out[sl] = payload.reshape(tuple(b - a for a, b in spans))
        assert out is not None, entry
        return out.reshape(shape)

    def _fetch_leaves(self, final: str, merged: Dict[str, Any],
                      names: List[str]) -> Dict[str, np.ndarray]:
        """Load many leaves concurrently (leaf-level parallelism; shard-level
        kicks in instead when a single leaf dominates)."""
        if len(names) > 1:
            arrs = self._engine.read_many(
                [functools.partial(self._read_leaf, final, merged[n],
                                   parallel=False) for n in names])
        else:
            arrs = [self._read_leaf(final, merged[n]) for n in names]
        return dict(zip(names, arrs))

    def restore(self, *, step: Optional[int] = None, like=None,
                shardings=None) -> Tuple[Any, Optional[Dict]]:
        """Returns (state, local_state).

        ``like``: template pytree (arrays or ShapeDtypeStructs) defining the
        tree structure.  ``shardings``: matching pytree of Shardings (or
        None -> numpy arrays) — may describe a DIFFERENT mesh than the one
        that saved (elastic restore: reassembled from spans).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        final = self._final(step)
        merged = self._load_manifests(step)

        if like is None:
            # rebuild a nested dict from dotted names
            cache = self._fetch_leaves(final, merged, list(merged))
            root: Dict[str, Any] = {}
            for name in merged:
                parts = name.split(".")
                d = root
                for p in parts[:-1]:
                    d = d.setdefault(p, {})
                d[parts[-1]] = cache[name]
            state = root
        else:
            named = _flatten_named(like)
            for name, _ in named:
                if name not in merged:
                    raise KeyError(f"leaf {name!r} missing from checkpoint "
                                   f"{final}")
            flat_shardings = (jax.tree_util.tree_flatten_with_path(shardings)[0]
                              if shardings is not None else None)
            cache = self._fetch_leaves(final, merged, [n for n, _ in named])
            rebuilt = []
            for i, (name, leaf) in enumerate(named):
                sh = flat_shardings[i][1] if flat_shardings is not None else None
                arr = cache[name]
                rebuilt.append(arr if sh is None else jax.device_put(arr, sh))
            state = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like), rebuilt)

        local = None
        lp = os.path.join(final, f"local_h{self.host_id}.json")
        if os.path.exists(lp):
            local = read_json(lp)
        return state, local

    def restore_local_shards(self, step: int) -> List[Dict]:
        """Load every per-shard local-scope file of ``step``, ordered by
        shard index (reads run on the I/O pool).  Returns [] when the
        checkpoint predates local-scope saving — callers fall back to the
        host-scope local dict."""
        final = self._final(step)
        found = []
        for fn in os.listdir(final):
            m = _LOCAL_SHARD_RE.match(fn)
            if m:
                found.append((int(m.group(1)), os.path.join(final, fn)))
        found.sort()
        return self._engine.read_many(
            [functools.partial(read_json, p) for _, p in found])

    def restore_latest(self, *, like=None, shardings=None,
                       candidates: Optional[List[int]] = None,
                       with_local_shards: bool = False
                       ) -> Tuple[Any, Optional[Dict], int, List[Tuple[int, str]]]:
        """Restore the newest checkpoint that actually verifies.

        On a corrupt checkpoint (CRC mismatch, truncated shard, unreadable
        or incomplete manifest) it walks back through the retained ``keep``
        history instead of failing the whole restore.  ``candidates``
        overrides the try-order (first entry tried first) — e.g. the
        SDC layer passes scrub-verified steps first.
        ``with_local_shards``: also load the per-shard local-scope files as
        part of candidate verification, so a corrupt/truncated
        ``local_s<k>.json`` walks back like any other corrupt shard instead
        of killing the restore.

        Returns (state, local_state, step, skipped) — or, with
        ``with_local_shards``, (state, local_state, shard_dicts, step,
        skipped) — where ``skipped`` is [(step, reason), ...] for every
        checkpoint that had to be passed over — callers should surface it:
        each entry is lost work.
        """
        if candidates is None:
            candidates = list(reversed(self.all_steps()))
        skipped: List[Tuple[int, str]] = []
        for s in candidates:
            try:
                state, local = self.restore(step=s, like=like,
                                            shardings=shardings)
                if with_local_shards:
                    shard_dicts = self.restore_local_shards(s)
                    return state, local, shard_dicts, s, skipped
                return state, local, s, skipped
            except (IOError, ValueError, json.JSONDecodeError) as e:
                # NOT KeyError: a template leaf missing from the manifest
                # is a caller bug that affects every candidate identically
                # — walking back would silently discard all progress
                skipped.append((s, f"{type(e).__name__}: {e}"))
        detail = "; ".join(f"step {s}: {r}" for s, r in skipped)
        raise FileNotFoundError(
            f"no restorable checkpoint under {self.directory}"
            + (f" (skipped {detail})" if detail else ""))
