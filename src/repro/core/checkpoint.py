"""Sharded, atomic, resharding-on-restore checkpoint manager (from scratch).

DeLIA mapping (DESIGN.md S2):
- *global state*  = any pytree (TrainState): each host writes only its
  addressable shards + metadata; restore can target ANY mesh/sharding
  (elastic recovery) because the manifest records global shapes and every
  shard's index span.
- *local state*   = small JSON dict per host (data-pipeline cursor etc.).

Layout (one directory per step):

    <dir>/step_00000420/
        manifest.json               global shapes/dtypes/codec/CRCs
        <leaf-name>.s<k>.npy        shard k of that leaf (np .npy payload)
        local_h<i>.json             per-host local state
        ack_h<i>                    per-host completion marker
    <dir>/step_00000420.tmp.<pid>   staging dir, atomically renamed

Commit protocol: every host writes shards + ack into the staging dir; host 0
renames it into place once all acks are present (single-process runs commit
immediately).  A reader only trusts directories whose manifest parses and
whose CRCs verify — a crash mid-write never corrupts the latest checkpoint.

Async mode: ``save(..., blocking=False)`` snapshots device arrays to host
memory (the only on-critical-path cost) and hands serialization to a writer
thread (double-buffered: a new save drains the previous one).
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.codec import CODECS, Codec

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def _flatten_named(tree) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_leaf_name(p), v) for p, v in leaves]


class SaveStats:
    def __init__(self, step, bytes_written, snapshot_s, write_s, blocking):
        self.step = step
        self.bytes_written = bytes_written
        self.snapshot_seconds = snapshot_s
        self.write_seconds = write_s
        self.blocking = blocking

    def __repr__(self):
        return (f"SaveStats(step={self.step}, MB={self.bytes_written/1e6:.1f},"
                f" snapshot={self.snapshot_seconds:.3f}s,"
                f" write={self.write_seconds:.3f}s, blocking={self.blocking})")


class CheckpointManager:
    def __init__(self, directory: str, *, host_id: int = 0, num_hosts: int = 1,
                 codec: Optional[str] = None, verify_crc: bool = True,
                 keep: int = 3):
        self.directory = directory
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.codec: Optional[Codec] = CODECS[codec] if codec else None
        self.codec_name = codec
        self.verify_crc = verify_crc
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        self._writer_err: Optional[BaseException] = None

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def _staging(self, step: int) -> str:
        return os.path.join(self.directory,
                            f"step_{step:08d}.tmp.{os.getpid()}")

    def _final(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _snapshot(self, tree):
        """Device -> host copy.  This is the only cost on the BSP critical
        path in async mode."""
        named = _flatten_named(tree)
        arrs = jax.device_get([v for _, v in named])
        return [(n, np.asarray(a)) for (n, _), a in zip(named, arrs)]

    def _shards_of(self, value):
        """Addressable shards of a jax.Array (or a single numpy shard)."""
        if isinstance(value, jax.Array) and hasattr(value, "addressable_shards"):
            out = []
            for sh in value.addressable_shards:
                idx = sh.index  # tuple of slices into the global array
                spans = [[s.start or 0,
                          s.stop if s.stop is not None else dim]
                         for s, dim in zip(idx, value.shape)] or []
                out.append((sh.replica_id, spans, np.asarray(sh.data)))
            # only keep replica 0 to avoid duplicate writes
            return [(spans, data) for rid, spans, data in out if rid == 0]
        arr = np.asarray(value)
        spans = [[0, d] for d in arr.shape]
        return [(spans, arr)]

    def save(self, step: int, state, local_state: Optional[Dict] = None, *,
             blocking: bool = True) -> SaveStats:
        self.wait()  # double-buffer: drain previous async write
        t0 = time.perf_counter()
        named = _flatten_named(state)
        shard_plan = []
        manifest_arrays: Dict[str, Any] = {}
        for name, value in named:
            shards = self._shards_of(value)
            dtype = str(np.asarray(shards[0][1]).dtype)
            shape = list(np.shape(value))
            entry = {"shape": shape, "dtype": dtype, "shards": []}
            for k, (spans, data) in enumerate(shards):
                fname = f"{name}.s{self.host_id}_{k}.npy"
                entry["shards"].append({"file": fname, "spans": spans})
                shard_plan.append((fname, data, entry["shards"][-1]))
            manifest_arrays[name] = entry
        snapshot_s = time.perf_counter() - t0

        def write():
            t1 = time.perf_counter()
            staging = self._staging(step)
            os.makedirs(staging, exist_ok=True)
            total = 0
            for fname, data, meta in shard_plan:
                path = os.path.join(staging, fname)
                payload = data
                if self.codec is not None and payload.dtype in (
                        np.float32, np.float64) and payload.size >= 1024:
                    payload, codec_meta = self.codec.encode(payload)
                    meta["codec"] = {"name": self.codec_name, **codec_meta}
                with open(path, "wb") as f:
                    np.save(f, payload)
                    f.flush()
                    os.fsync(f.fileno())
                meta["crc32"] = zlib.crc32(payload.tobytes()) & 0xFFFFFFFF
                total += payload.nbytes
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "codec": self.codec_name,
                "arrays": manifest_arrays,
            }
            with open(os.path.join(staging, f"manifest_h{self.host_id}.json"),
                      "w") as f:
                json.dump(manifest, f)
            if local_state is not None:
                with open(os.path.join(staging,
                                       f"local_h{self.host_id}.json"), "w") as f:
                    json.dump(local_state, f)
            open(os.path.join(staging, f"ack_h{self.host_id}"), "w").close()
            # commit when all hosts acked (single-process: immediately)
            acks = [os.path.exists(os.path.join(staging, f"ack_h{h}"))
                    for h in range(self.num_hosts)]
            if all(acks) and self.host_id == 0:
                final = self._final(step)
                if os.path.exists(final):
                    import shutil
                    shutil.rmtree(final)
                os.rename(staging, final)
                self._gc()
            return total, time.perf_counter() - t1

        if blocking:
            total, write_s = write()
            return SaveStats(step, total, snapshot_s, write_s, True)

        stats = SaveStats(step, 0, snapshot_s, 0.0, False)

        def run():
            try:
                total, write_s = write()
                stats.bytes_written = total
                stats.write_seconds = write_s
            except BaseException as e:  # surfaced on next wait()
                self._writer_err = e

        self._writer = threading.Thread(target=run, daemon=True)
        self._writer.start()
        return stats

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._writer_err is not None:
            err, self._writer_err = self._writer_err, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(self._final(s), ignore_errors=True)

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.directory, d,
                                                 "manifest_h0.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_manifests(self, step: int) -> Dict[str, Any]:
        final = self._final(step)
        merged: Dict[str, Any] = {}
        for h in range(self.num_hosts):
            p = os.path.join(final, f"manifest_h{h}.json")
            if not os.path.exists(p):
                continue
            with open(p) as f:
                man = json.load(f)
            for name, entry in man["arrays"].items():
                if name not in merged:
                    merged[name] = {"shape": entry["shape"],
                                    "dtype": entry["dtype"], "shards": []}
                merged[name]["shards"].extend(entry["shards"])
        return merged

    def _read_leaf(self, final: str, entry: Dict[str, Any]) -> np.ndarray:
        shape = tuple(entry["shape"])
        out: Optional[np.ndarray] = None
        for sh in entry["shards"]:
            path = os.path.join(final, sh["file"])
            payload = np.load(path)
            if self.verify_crc and "crc32" in sh:
                crc = zlib.crc32(payload.tobytes()) & 0xFFFFFFFF
                if crc != sh["crc32"]:
                    raise IOError(f"CRC mismatch in {path}")
            if "codec" in sh:
                payload = CODECS[sh["codec"]["name"]].decode(
                    payload, sh["codec"])
            payload = payload.astype(entry["dtype"], copy=False)
            spans = sh["spans"]
            if not spans:  # scalar
                return payload.reshape(shape)
            if out is None:
                out = np.empty(shape, dtype=entry["dtype"])
            sl = tuple(slice(a, b) for a, b in spans)
            out[sl] = payload.reshape(tuple(b - a for a, b in spans))
        assert out is not None, entry
        return out.reshape(shape)

    def restore(self, *, step: Optional[int] = None, like=None,
                shardings=None) -> Tuple[Any, Optional[Dict]]:
        """Returns (state, local_state).

        ``like``: template pytree (arrays or ShapeDtypeStructs) defining the
        tree structure.  ``shardings``: matching pytree of Shardings (or
        None -> numpy arrays) — may describe a DIFFERENT mesh than the one
        that saved (elastic restore: reassembled from spans).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        final = self._final(step)
        merged = self._load_manifests(step)

        def build(name: str, sharding=None):
            arr = self._read_leaf(final, merged[name])
            if sharding is None:
                return arr
            return jax.device_put(arr, sharding)

        if like is None:
            # rebuild a nested dict from dotted names
            root: Dict[str, Any] = {}
            for name in merged:
                parts = name.split(".")
                d = root
                for p in parts[:-1]:
                    d = d.setdefault(p, {})
                d[parts[-1]] = build(name)
            state = root
        else:
            named = _flatten_named(like)
            flat_shardings = (jax.tree_util.tree_flatten_with_path(shardings)[0]
                              if shardings is not None else None)
            rebuilt = []
            for i, (name, leaf) in enumerate(named):
                if name not in merged:
                    raise KeyError(f"leaf {name!r} missing from checkpoint "
                                   f"{final}")
                sh = flat_shardings[i][1] if flat_shardings is not None else None
                rebuilt.append(build(name, sh))
            state = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like), rebuilt)

        local = None
        lp = os.path.join(final, f"local_h{self.host_id}.json")
        if os.path.exists(lp):
            with open(lp) as f:
                local = json.load(f)
        return state, local
