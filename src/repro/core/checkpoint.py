"""Sharded, atomic, resharding-on-restore checkpoint manager (from scratch).

DeLIA mapping (DESIGN.md S2):
- *global state*  = any pytree (TrainState): each host writes only its
  addressable shards + metadata; restore can target ANY mesh/sharding
  (elastic recovery) because the manifest records global shapes and every
  shard's index span.
- *local state*   = small JSON dict per host (data-pipeline cursor etc.).

Layout (one directory per step):

    <dir>/step_00000420/
        manifest.json               global shapes/dtypes/codec/CRCs
        <leaf-name>.s<k>.npy        shard k of that leaf (np .npy payload)
        local_h<i>.json             per-host local state
        ack_h<i>                    per-host completion marker
    <dir>/step_00000420.tmp.<pid>   staging dir, atomically renamed

Commit protocol: every host writes shards + ack into the staging dir; host 0
renames it into place once all acks are present (single-process runs commit
immediately).  A reader only trusts directories whose manifest parses and
whose CRCs verify — a crash mid-write never corrupts the latest checkpoint.
Staging directories abandoned by crashed writers are swept on manager init
and at every GC (a dir is stale when no live process owns its pid suffix
and no writer of THIS process has it registered in-flight).

Fast path (the Young/Daly C term, end to end):

1. *Snapshot* (the only on-critical-path cost in async mode): with
   ``device_codec=True`` each floating leaf >= 1 KiB is quantized to int8 +
   per-block fp32 scales *on device* (Pallas kernel on TPU, jnp twin
   elsewhere — see core/codec.DeviceCodec) and the int8 payload is what
   crosses the device->host link: ~3.9x fewer bytes than fp32.  All shards
   transfer in one batched ``jax.device_get``.
2. *Write*: shards are encoded (host codec, if any) and written
   concurrently by a ``ShardIOEngine`` thread pool; each ``.npy`` is
   streamed through memoryview chunks with the CRC32 computed in the same
   pass — no ``tobytes()`` copies anywhere.
3. *Durability*: fsync is batched — files first, then one directory fsync —
   instead of a per-file write->fsync lockstep (``fsync`` mode knob).
4. *Restore*: shard loads and leaf assembly are parallelized on the same
   pool; CRC verification is zero-copy over the loaded buffers; shard spans
   are validated to EXACTLY tile each leaf (a lost host manifest raises
   IOError instead of returning uninitialized memory, so ``restore_latest``
   walks back).

Incremental ("delta") mode (``delta=True``, docs/checkpointing.md):

Each shard is split into fixed-size blocks of ``delta_block`` elements
whose mod-2^32 word-sum hashes are computed ON DEVICE by the block_hash
Pallas kernel (the same reduction the SDC scrubber uses for leaf
checksums).  A save writes only the blocks whose hash changed since the
last committed checkpoint: clean blocks become manifest references into
the parent step's files, forming a bounded-depth chain (``full_every``
forces a periodic full save; a restore resets the base, so the save after
a rollback is always full).  ``delta_block`` must be a multiple of the
int8 codec's 256-element block so a standalone encode of the dirty blocks
is bit-identical to the matching slice of a full-save encode — delta
restores are therefore bit-exact against a full-save oracle for every
codec config.  ``_gc`` is chain-aware: a parent step survives ``keep``
while any retained child references it; a corrupt parent invalidates every
child that references it (the chain walk raises IOError and
``restore_latest`` skips the whole chain).

Async mode: ``save(..., blocking=False)`` snapshots device arrays to host
memory and hands serialization to a writer thread (double-buffered: a new
save drains the previous one; ``wait()`` re-raises writer errors).
"""
from __future__ import annotations

import functools
import json
import os
import re
import shutil
import threading
import time
import uuid
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import (CODECS, Codec, DeviceCodec,
                              validate_delta_block)
from repro.core.io_engine import (ShardIOEngine, crc32_array, fsync_path,
                                  pid_alive, read_json, write_json,
                                  write_npy)
from repro.kernels.block_hash.ops import batched_block_hashes
from repro.kernels.block_hash.ref import block_hashes_np

_STEP_RE = re.compile(r"^step_(\d{8})$")
_STAGING_RE = re.compile(r"^step_(\d{8})\.tmp\.(\d+)$")
_LOCAL_SHARD_RE = re.compile(r"^local_s(\d{5})\.json$")

# leaves below this many elements are always saved in full (same floor the
# codecs use: hashing/packing overhead would exceed the bytes saved)
_DELTA_MIN_ELEMS = 1024


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def _flatten_named(tree) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_leaf_name(p), v) for p, v in leaves]


@functools.partial(jax.jit, static_argnames=("block",))
def _gather_blocks_device(x, idx, block: int):
    """Jitted device gather (an eager op chain pays ~10x in dispatch +
    unfused gather lowering).  Retraces per (shape, dirty-count) — the
    steady-state churn pattern is stable, so the cache hits."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block)[idx].reshape(-1)


def _gather_blocks(data, idx: np.ndarray, block: int):
    """Blocks ``idx`` of the flattened shard, concatenated flat (each block
    zero-padded to ``block`` elements).  Device arrays gather ON DEVICE so
    only the dirty bytes ever cross the device->host link."""
    if isinstance(data, jax.Array):
        return _gather_blocks_device(data, jnp.asarray(idx, jnp.int32),
                                     int(block))
    flat = np.ascontiguousarray(data).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = np.pad(flat, (0, pad))
    return np.ascontiguousarray(flat.reshape(-1, block)[idx].reshape(-1))


class SaveStats:
    def __init__(self, step, bytes_written, snapshot_s, write_s, blocking,
                 kind="full", dirty_blocks=0, total_blocks=0):
        self.step = step
        self.bytes_written = bytes_written
        self.snapshot_seconds = snapshot_s
        self.write_seconds = write_s
        self.blocking = blocking
        self.kind = kind                      # "full" | "delta"
        self.dirty_blocks = dirty_blocks      # blocks written (delta mode)
        self.total_blocks = total_blocks      # blocks tracked (delta mode)

    def __repr__(self):
        extra = ""
        if self.total_blocks:
            extra = (f", kind={self.kind}, blocks={self.dirty_blocks}/"
                     f"{self.total_blocks}")
        return (f"SaveStats(step={self.step}, MB={self.bytes_written/1e6:.1f},"
                f" snapshot={self.snapshot_seconds:.3f}s,"
                f" write={self.write_seconds:.3f}s, blocking={self.blocking}"
                f"{extra})")


class CheckpointManager:
    # staging dirs currently owned by a live writer of THIS process — the
    # stale-staging sweep must never remove these.  REFCOUNTED, not a set:
    # in single-process multi-host simulations several managers register
    # the SAME staging path (same pid, same step), and one manager's
    # close() must not strip protection while another's writer still uses
    # the dir.  A commit clears the path outright (the dir was renamed
    # away; every host's interest in it is moot).
    _ACTIVE_STAGING: Dict[str, int] = {}
    _STAGING_LOCK = threading.Lock()

    def __init__(self, directory: str, *, host_id: int = 0, num_hosts: int = 1,
                 codec: Optional[str] = None, device_codec: bool = False,
                 io_threads: int = 0, fsync: str = "batch",
                 verify_crc: bool = True, keep: int = 3,
                 delta: bool = False, delta_block: int = 65536,
                 full_every: int = 8):
        self.directory = directory
        self.host_id = host_id
        self.num_hosts = num_hosts
        if device_codec:
            if codec not in (None, "int8"):
                raise ValueError(
                    f"device_codec implies the int8 layout, got codec={codec!r}")
            codec = "int8"
        self.codec: Optional[Codec] = CODECS[codec] if codec else None
        self.codec_name = codec
        self._dcodec: Optional[DeviceCodec] = (DeviceCodec()
                                               if device_codec else None)
        self._engine = ShardIOEngine(threads=io_threads, fsync_mode=fsync)
        self.verify_crc = verify_crc
        self.keep = keep
        self.delta = bool(delta)
        self.delta_block = validate_delta_block(delta_block) if delta else int(
            delta_block)
        if delta and full_every < 1:
            raise ValueError(f"full_every must be >= 1, got {full_every}")
        self.full_every = int(full_every)
        # per-shard base of the last committed save: fname -> {step, hashes,
        # block_steps, step_sids, spans, dtype, size}.  In-memory only: a
        # restarted manager saves one full checkpoint first, then resumes
        # deltas.  ``step_sids`` maps each referenced step to the lineage id
        # its shards were saved under — a walk-back + resume can REGENERATE
        # a parent step number with different content, and a stale delta
        # must not silently resolve against it (restore verifies sids).
        self._delta_base: Dict[str, Dict[str, Any]] = {}
        self._chain_len = 0           # delta saves since the last full
        self._my_staging: Set[str] = set()   # this manager's registrations
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        self._writer_err: Optional[BaseException] = None
        self._sweep_stale_staging()

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def _staging(self, step: int) -> str:
        return os.path.join(self.directory,
                            f"step_{step:08d}.tmp.{os.getpid()}")

    def _final(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _register_staging(self, path: str) -> None:
        active = CheckpointManager._ACTIVE_STAGING
        with CheckpointManager._STAGING_LOCK:
            active[path] = active.get(path, 0) + 1
        self._my_staging.add(path)

    def _unregister_staging(self, path: str) -> None:
        """Drop THIS manager's hold on ``path`` (other co-hosted managers'
        holds keep protecting it)."""
        if path not in self._my_staging:
            return
        self._my_staging.discard(path)
        active = CheckpointManager._ACTIVE_STAGING
        with CheckpointManager._STAGING_LOCK:
            count = active.get(path, 0)
            if count <= 1:
                active.pop(path, None)
            else:
                active[path] = count - 1

    def _clear_staging(self, path: str) -> None:
        """Commit path: the staging dir was renamed into place, so every
        host's registration of it is moot — clear outright."""
        self._my_staging.discard(path)
        with CheckpointManager._STAGING_LOCK:
            CheckpointManager._ACTIVE_STAGING.pop(path, None)

    def _sweep_stale_staging(self) -> None:
        """Remove ``step_<n>.tmp.<pid>`` staging dirs abandoned by crashed
        writers.  A dir is stale unless a writer of this process has it
        registered in-flight, or its pid suffix belongs to another LIVE
        process (a co-hosted writer mid-save)."""
        try:
            names = os.listdir(self.directory)
        except FileNotFoundError:
            return
        for dname in names:
            m = _STAGING_RE.match(dname)
            if not m:
                continue
            path = os.path.join(self.directory, dname)
            with CheckpointManager._STAGING_LOCK:
                if CheckpointManager._ACTIVE_STAGING.get(path, 0) > 0:
                    continue
            pid = int(m.group(2))
            if pid != os.getpid() and pid_alive(pid):
                continue
            shutil.rmtree(path, ignore_errors=True)

    def _shards_of(self, value):
        """Addressable shards of a jax.Array (kept on device) or a single
        numpy shard; (spans, data) pairs."""
        if isinstance(value, jax.Array) and hasattr(value, "addressable_shards"):
            out = []
            for sh in value.addressable_shards:
                idx = sh.index  # tuple of slices into the global array
                spans = [[s.start or 0,
                          s.stop if s.stop is not None else dim]
                         for s, dim in zip(idx, value.shape)] or []
                out.append((sh.replica_id, spans, sh.data))
            # only keep replica 0 to avoid duplicate writes
            return [(spans, data) for rid, spans, data in out if rid == 0]
        arr = np.asarray(value)
        spans = [[0, d] for d in arr.shape]
        return [(spans, arr)]

    def _dcodec_ok(self, data) -> bool:
        """Would a full save device-encode this shard?  Delta saves must
        encode gathered dirty blocks iff the full save would have (decided
        on the ORIGINAL shard — a gathered payload can be smaller than the
        codec floor), or the decoded values diverge from the full-save
        oracle."""
        return (self._dcodec is not None and isinstance(data, jax.Array)
                and jnp.issubdtype(data.dtype, jnp.floating)
                and data.size >= 1024)

    def _host_codec_ok(self, data) -> bool:
        """Same, for the host-side codec in the writer pool (applies to
        numpy shards even in device-codec mode, matching the full path)."""
        if self.codec is None:
            return False
        dt = np.dtype(data.dtype) if hasattr(data, "dtype") else None
        return dt in (np.float32, np.float64) and data.size >= 1024

    def _append_payload(self, item, smeta, payload, dev, fill,
                        dcodec_ok: bool, host_codec_ok: bool):
        """Route one shard payload (full data or gathered dirty blocks)
        into the write plan: device-encode, defer transfer, or keep host."""
        if dcodec_ok and isinstance(payload, jax.Array):
            q, s = self._dcodec.encode(payload)
            smeta["codec"] = {"name": self.codec_name,
                              **DeviceCodec.block_meta(payload.shape)}
            item["kind"] = "parts"
            item["parts"] = [None, None]
            for j, a in enumerate((q, s)):
                fill.append((item["parts"], j))
                dev.append(a)
        elif isinstance(payload, jax.Array):
            item["kind"] = "host"
            item["codec_ok"] = host_codec_ok
            item["data"] = None
            fill.append((item, "data"))
            dev.append(payload)
        else:
            item["kind"] = "host"
            item["codec_ok"] = host_codec_ok
            item["data"] = payload

    def _snapshot(self, named, step: int, kind: str, sid: str):
        """Device -> host: the only cost on the BSP critical path in async
        mode.  With device_codec, eligible leaves are quantized on device
        first so only int8 + scales cross the link; all device buffers move
        in one batched device_get.  In delta mode each shard's block hashes
        are computed first (on device, one batched transfer of the tiny
        hash vectors) and only dirty blocks are gathered + transferred.

        Returns (shard_plan, manifest_arrays, pending_base, dirty, total)
        where each plan item owns its manifest shard-meta dict (mutated by
        the writer jobs with codec/crc info before the manifest is dumped)
        and ``pending_base`` is the delta base to commit once the write
        lands on disk.
        """
        plan: List[Dict[str, Any]] = []
        manifest_arrays: Dict[str, Any] = {}
        rows: List[Dict[str, Any]] = []
        for name, value in named:
            shards = self._shards_of(value)
            first = shards[0][1]
            dtype = str(first.dtype if hasattr(first, "dtype")
                        else np.asarray(first).dtype)
            entry = {"shape": list(np.shape(value)), "dtype": dtype,
                     "shards": []}
            for k, (spans, data) in enumerate(shards):
                fname = f"{name}.s{self.host_id}_{k}.npy"
                smeta: Dict[str, Any] = {"file": fname, "spans": spans}
                if self.delta:
                    smeta["sid"] = sid       # lineage id delta children pin
                entry["shards"].append(smeta)
                row = {"fname": fname, "meta": smeta, "spans": spans,
                       "data": data, "dtype": dtype}
                if self.delta and data.size >= _DELTA_MIN_ELEMS:
                    if isinstance(data, jax.Array):
                        row["hash_me"] = True
                    else:
                        row["hashes"] = block_hashes_np(np.asarray(data),
                                                        self.delta_block)
                rows.append(row)
            manifest_arrays[name] = entry
        # ONE jitted dispatch hashes every device shard, ONE transfer moves
        # the (tiny) hash vectors
        pend = [r for r in rows if r.pop("hash_me", False)]
        if pend:
            hashes = batched_block_hashes([r["data"] for r in pend],
                                          self.delta_block)
            for r, h in zip(pend, jax.device_get(hashes)):
                r["hashes"] = np.asarray(h)

        pending_base: Dict[str, Dict[str, Any]] = {}
        dirty_total = blocks_total = 0
        dev: List[Any] = []          # device arrays awaiting transfer
        fill: List[Tuple[Any, Any]] = []  # (container, key) to fill per dev
        for row in rows:
            fname, smeta, data = row["fname"], row["meta"], row["data"]
            item: Dict[str, Any] = {"fname": fname, "meta": smeta}
            base = self._delta_base.get(fname)
            h = row.get("hashes")
            if h is not None:
                pending_base[fname] = {
                    "step": step, "hashes": h, "spans": row["spans"],
                    "dtype": row["dtype"], "size": int(data.size),
                    "block_steps": np.full(h.size, step, np.int64),
                    "step_sids": {step: sid}}
                blocks_total += h.size
            use_delta = (kind == "delta" and h is not None
                         and base is not None
                         and base["spans"] == row["spans"]
                         and base["dtype"] == row["dtype"]
                         and base["size"] == int(data.size))
            if use_delta:
                dirty = np.nonzero(h != base["hashes"])[0]
                if dirty.size == h.size:
                    use_delta = False       # fully dirty: plain full shard
            if not use_delta:
                if h is not None:
                    dirty_total += h.size
                self._append_payload(item, smeta, data, dev, fill,
                                     dcodec_ok=self._dcodec_ok(data),
                                     host_codec_ok=self._host_codec_ok(data))
                plan.append(item)
                continue
            dirty_total += int(dirty.size)
            block_steps = base["block_steps"].copy()
            block_steps[dirty] = step
            clean = np.nonzero(h == base["hashes"])[0]
            parents: Dict[int, List[int]] = {}
            for b in clean:
                parents.setdefault(int(base["block_steps"][b]),
                                   []).append(int(b))
            pending_base[fname]["block_steps"] = block_steps
            pending_base[fname]["step_sids"] = {
                step: sid, **{s: base["step_sids"][s] for s in parents}}
            smeta["delta"] = {
                "block": self.delta_block, "nblocks": int(h.size),
                "size": int(data.size),
                "local": [int(b) for b in dirty],
                "parents": {str(s): bs for s, bs in sorted(parents.items())},
                "parent_sids": {str(s): base["step_sids"][s]
                                for s in parents},
            }
            if dirty.size == 0:
                smeta["file"] = None     # nothing local: pure reference
                continue
            gathered = _gather_blocks(data, dirty, self.delta_block)
            self._append_payload(item, smeta, gathered, dev, fill,
                                 dcodec_ok=self._dcodec_ok(data),
                                 host_codec_ok=self._host_codec_ok(data))
            plan.append(item)
        if dev:
            for (container, key), arr in zip(fill, jax.device_get(dev)):
                container[key] = np.asarray(arr)
        return plan, manifest_arrays, pending_base, dirty_total, blocks_total

    def _write_shard(self, staging: str, item: Dict[str, Any]) -> Tuple[str, int]:
        """One writer-pool job: (host-)encode + stream one shard to disk."""
        path = os.path.join(staging, item["fname"])
        meta = item["meta"]
        per_file = self._engine.per_file_fsync
        if item["kind"] == "parts":     # device-encoded: q blocks + scales
            nbytes, crc = write_npy(path, item["parts"], fsync=per_file)
        else:
            payload = item["data"]
            if item.get("codec_ok"):
                payload, codec_meta = self.codec.encode(payload)
                meta["codec"] = {"name": self.codec_name, **codec_meta}
            nbytes, crc = write_npy(path, payload, fsync=per_file)
        meta["crc32"] = crc
        return path, nbytes

    def save(self, step: int, state, local_state: Optional[Dict] = None, *,
             local_shards: Optional[List[Dict]] = None,
             mesh_meta: Optional[Dict] = None,
             blocking: bool = True) -> SaveStats:
        """``local_state``: this host's local-scope dict (one file per host).
        ``local_shards``: finer-grained local scope — one dict per DP shard
        this host owns, each written as its OWN ``local_s<k>.json`` file so
        restore can remap them individually when the shard count changes
        (the feature the paper's FWI study could not enable).
        ``mesh_meta``: the mesh the state was sharded on when saved — e.g.
        ``{"dp": 2, "tp": 2, "ep": 2, "moe_ep": 2, "dead_experts": []}`` —
        recorded in the manifest so restore can rebuild expert placement
        (``reshard_state`` reads it back via ``manifest_meta``)."""
        self.wait()  # double-buffer: drain previous async write
        t0 = time.perf_counter()
        kind = "full"
        if (self.delta and self._delta_base
                and self._chain_len + 1 < self.full_every):
            kind = "delta"
        # fresh lineage id per save: a walk-back + resume can regenerate a
        # step NUMBER with different content; delta children pin the id so
        # restore refuses to mix generations
        sid = uuid.uuid4().hex[:16]
        named = _flatten_named(state)
        (shard_plan, manifest_arrays, pending_base, dirty,
         total) = self._snapshot(named, step, kind, sid)
        snapshot_s = time.perf_counter() - t0

        def write():
            t1 = time.perf_counter()
            staging = self._staging(step)
            self._register_staging(staging)
            try:
                os.makedirs(staging, exist_ok=True)
                total_b, paths = self._engine.run_jobs(
                    [functools.partial(self._write_shard, staging, item)
                     for item in shard_plan])
                manifest = {
                    "step": step,
                    "num_hosts": self.num_hosts,
                    "codec": self.codec_name,
                    "kind": kind,
                    "arrays": manifest_arrays,
                }
                if mesh_meta is not None:
                    manifest["mesh"] = dict(mesh_meta)
                if local_shards is not None:
                    manifest["local_shards"] = [int(sd.get("shard", k))
                                                for k, sd in
                                                enumerate(local_shards)]
                mpath = os.path.join(staging, f"manifest_h{self.host_id}.json")
                paths.append(write_json(mpath, manifest))
                if local_state is not None:
                    lpath = os.path.join(staging,
                                         f"local_h{self.host_id}.json")
                    paths.append(write_json(lpath, local_state))
                for k, sd in enumerate(local_shards or ()):
                    idx = int(sd.get("shard", k))
                    spath = os.path.join(staging, f"local_s{idx:05d}.json")
                    paths.append(write_json(spath, sd))
                apath = os.path.join(staging, f"ack_h{self.host_id}")
                open(apath, "w").close()
                paths.append(apath)
                self._engine.finalize(staging, paths)
                # commit when all hosts acked (single-process: immediately)
                acks = [os.path.exists(os.path.join(staging, f"ack_h{h}"))
                        for h in range(self.num_hosts)]
                if all(acks) and self.host_id == 0:
                    final = self._final(step)
                    if os.path.exists(final):
                        shutil.rmtree(final)
                    os.rename(staging, final)
                    self._clear_staging(staging)
                    if self._engine.fsync_mode != "none":
                        fsync_path(self.directory)  # make the rename durable
                    self._gc()
            except BaseException:
                self._unregister_staging(staging)
                raise
            # the write landed: commit the delta base (a failed write never
            # becomes a parent; hosts that don't commit the rename still
            # advance — their shards are on disk awaiting the commit)
            if self.delta:
                self._delta_base.update(pending_base)
                self._chain_len = 0 if kind == "full" else self._chain_len + 1
            return total_b, time.perf_counter() - t1

        if blocking:
            total_b, write_s = write()
            return SaveStats(step, total_b, snapshot_s, write_s, True,
                             kind=kind, dirty_blocks=dirty,
                             total_blocks=total)

        stats = SaveStats(step, 0, snapshot_s, 0.0, False, kind=kind,
                          dirty_blocks=dirty, total_blocks=total)

        def run():
            try:
                total_b, write_s = write()
                stats.bytes_written = total_b
                stats.write_seconds = write_s
            except BaseException as e:  # surfaced on next wait()
                self._writer_err = e

        self._writer = threading.Thread(target=run, daemon=True)
        self._writer.start()
        return stats

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._writer_err is not None:
            err, self._writer_err = self._writer_err, None
            raise err

    def close(self) -> None:
        """Drain the async writer and shut the I/O pool down.  Also drop
        this manager's staging registrations: a step that never committed
        (e.g. another host's ack never arrived) stays registered while the
        manager lives so co-hosted sweeps leave it alone, but must become
        sweepable once the manager is done with it."""
        self.wait()
        for path in list(self._my_staging):
            self._unregister_staging(path)
        self._engine.close()

    def _parent_steps(self, step: int) -> Set[int]:
        """Steps referenced by ``step``'s delta manifests (direct parents).
        Raises on an unreadable manifest — callers deciding what to DELETE
        must treat that conservatively, not as 'no parents'."""
        out: Set[int] = set()
        merged = self._load_manifests(step)
        for entry in merged.values():
            for sh in entry["shards"]:
                d = sh.get("delta")
                if d:
                    out.update(int(s) for s in d["parents"])
        return out

    def _gc(self) -> None:
        """Prune beyond ``keep`` — but chain-aware: a step survives while
        any retained delta checkpoint (transitively) references it.  If any
        retained manifest cannot be read (even transiently — EMFILE under
        a loaded I/O pool, say), SKIP deletion this round: deleting a
        parent that an unreadable child still references would destroy
        every retained delta, so the safe failure mode is keeping too
        much, never too little."""
        steps = self.all_steps()
        if self.keep:
            keep_set = set(steps[-self.keep:])
            frontier = list(keep_set)
            try:
                while frontier:
                    for p in self._parent_steps(frontier.pop()):
                        if p not in keep_set:
                            keep_set.add(p)
                            frontier.append(p)
            except (OSError, ValueError, json.JSONDecodeError):
                keep_set = None        # can't prove safety: delete nothing
            if keep_set is not None:
                for s in steps:
                    if s not in keep_set:
                        shutil.rmtree(self._final(s), ignore_errors=True)
        self._sweep_stale_staging()

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.directory, d,
                                                 "manifest_h0.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest_meta(self, step: int) -> Optional[Dict[str, Any]]:
        """The ``mesh_meta`` dict recorded at ``save`` time (None when the
        step predates mesh metadata or does not exist).  This is how expert
        placement survives a restart: the manifest says which (dp, tp, ep)
        grid — and which dead experts — the checkpoint was written under."""
        if step is None:
            return None
        p = os.path.join(self._final(step), "manifest_h0.json")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return json.load(f).get("mesh")

    def _load_manifests(self, step: int) -> Dict[str, Any]:
        final = self._final(step)
        merged: Dict[str, Any] = {}
        for h in range(self.num_hosts):
            p = os.path.join(final, f"manifest_h{h}.json")
            if not os.path.exists(p):
                continue
            with open(p) as f:
                man = json.load(f)
            for name, entry in man["arrays"].items():
                if name not in merged:
                    merged[name] = {"shape": entry["shape"],
                                    "dtype": entry["dtype"], "shards": []}
                merged[name]["shards"].extend(entry["shards"])
        return merged

    def _check_tiling(self, name: str, shape: Tuple[int, ...],
                      shards: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
        """Validate that shard spans EXACTLY tile the leaf and return the
        deduplicated shard list (replicated leaves legitimately appear once
        per host manifest with identical spans).  Gaps — e.g. a lost host
        manifest — or overlaps raise IOError so ``restore_latest`` walks
        back instead of returning uninitialized memory."""
        total = 1
        for d in shape:
            total *= d
        uniq: List[Dict[str, Any]] = []
        seen = set()
        for sh in shards:
            key = tuple(tuple(s) for s in sh["spans"])
            if key in seen:
                continue
            seen.add(key)
            uniq.append(sh)
        vol = 0
        norm = []
        for sh in uniq:
            spans = sh["spans"]
            if len(spans) != len(shape):
                raise IOError(f"leaf {name!r}: shard span rank "
                              f"{len(spans)} != leaf rank {len(shape)}")
            v = 1
            for (a, b), dim in zip(spans, shape):
                if not (0 <= a < b <= dim):
                    raise IOError(f"leaf {name!r}: span [{a},{b}) outside "
                                  f"dim {dim}")
                v *= b - a
            vol += v
            norm.append(spans)
        for i in range(len(norm)):
            for j in range(i + 1, len(norm)):
                if norm[i] and all(max(a1, a2) < min(b1, b2)
                                   for (a1, b1), (a2, b2)
                                   in zip(norm[i], norm[j])):
                    raise IOError(f"leaf {name!r}: overlapping shard spans "
                                  f"{norm[i]} / {norm[j]}")
        if vol != total:
            raise IOError(
                f"leaf {name!r}: shard spans cover {vol} of {total} "
                "elements — missing host manifest or corrupt checkpoint")
        return uniq

    def _decode_payload(self, final: str, sh: Dict[str, Any],
                        want: np.dtype) -> np.ndarray:
        """np.load + CRC verify + codec decode of one shard file."""
        path = os.path.join(final, sh["file"])
        try:
            payload = np.load(path)
        except Exception as e:
            # a corrupted .npy HEADER surfaces as whatever numpy's parser
            # trips over (ValueError, SyntaxError, tokenize.TokenError,
            # EOFError...); normalize to IOError so restore_latest walks
            # back like any other corruption
            raise IOError(f"unreadable shard {path}: "
                          f"{type(e).__name__}: {e}") from e
        if self.verify_crc and "crc32" in sh:
            if crc32_array(payload) != sh["crc32"]:
                raise IOError(f"CRC mismatch in {path}")
        if "codec" in sh:
            payload = CODECS[sh["codec"]["name"]].decode(payload, sh["codec"])
        if payload.dtype.kind == "V" and payload.dtype.itemsize == want.itemsize:
            # ml_dtypes customs (bf16, fp8) round-trip .npy as raw void
            # bytes; reinterpret rather than cast
            payload = payload.view(want)
        return payload

    def _find_shard(self, step: int, name: str, spans,
                    man_cache: Dict[int, Dict],
                    want_sid: Optional[str] = None) -> Dict[str, Any]:
        """The shard entry for (name, spans) in ``step``'s manifests — the
        delta chain's parent lookup.  Raises IOError when the parent step
        or the matching shard is gone (child invalidated), or when
        ``want_sid`` doesn't match the shard's lineage id: the parent step
        NUMBER was regenerated after a walk-back + resume and holds a
        different training trajectory — mixing generations would restore a
        frankenstate with every per-file CRC passing."""
        if step not in man_cache:
            if not os.path.isdir(self._final(step)):
                raise IOError(f"delta parent step {step} is missing")
            man_cache[step] = self._load_manifests(step)
        entry = man_cache[step].get(name)
        if entry is None:
            raise IOError(f"delta parent step {step} has no leaf {name!r}")
        for sh in entry["shards"]:
            if sh["spans"] == spans:
                if want_sid is not None and sh.get("sid") != want_sid:
                    raise IOError(
                        f"delta parent step {step} was regenerated "
                        f"(lineage {sh.get('sid')} != referenced "
                        f"{want_sid}) — stale chain invalidated")
                return sh
        raise IOError(f"delta parent step {step} has no shard of {name!r} "
                      f"with spans {spans}")

    def _fill_blocks(self, step: int, name: str, spans, block: int,
                     needed: Set[int], out: np.ndarray, want: np.dtype,
                     man_cache: Dict[int, Dict], depth: int = 0,
                     want_sid: Optional[str] = None) -> None:
        """Copy the requested delta blocks of shard (name, spans) at
        ``step`` into ``out`` (flat, nblocks*block elements), resolving
        parent references recursively.  Any missing/corrupt link — or a
        parent whose lineage id shows the step was regenerated — raises
        IOError: the whole chain is invalidated."""
        if depth > 64:
            raise IOError(f"delta chain deeper than 64 at step {step} "
                          f"({name!r}) — corrupt parent links")
        final = self._final(step)
        sh = self._find_shard(step, name, spans, man_cache, want_sid)
        d = sh.get("delta")
        if d is None:               # a full shard terminates the chain
            flat = self._decode_payload(final, sh, want).reshape(-1)
            for b in needed:
                seg = flat[b * block:(b + 1) * block]
                if seg.size == 0:
                    raise IOError(f"delta block {b} of {name!r} out of "
                                  f"range in full shard at step {step}")
                out[b * block:b * block + seg.size] = seg
            return
        if d["block"] != block:
            raise IOError(f"delta block size changed mid-chain for "
                          f"{name!r} at step {step}")
        pos = {int(b): j for j, b in enumerate(d["local"])}
        here = [b for b in needed if b in pos]
        if here:
            if sh.get("file") is None:
                raise IOError(f"delta shard of {name!r} at step {step} "
                              "lists local blocks but has no file")
            flat = self._decode_payload(final, sh, want).reshape(-1)
            if flat.size < len(pos) * block:
                raise IOError(f"delta shard of {name!r} at step {step} "
                              f"truncated: {flat.size} < {len(pos) * block}")
            for b in here:
                j = pos[b]
                out[b * block:(b + 1) * block] = \
                    flat[j * block:(j + 1) * block]
        rest = needed.difference(here)
        if not rest:
            return
        pmap: Dict[int, int] = {}
        for ps, bs in d["parents"].items():
            for b in bs:
                pmap[int(b)] = int(ps)
        sids = d.get("parent_sids", {})
        byp: Dict[int, Set[int]] = {}
        for b in rest:
            if b not in pmap:
                raise IOError(f"delta block {b} of {name!r} unresolved at "
                              f"step {step} — corrupt manifest")
            byp.setdefault(pmap[b], set()).add(b)
        for s, bs in sorted(byp.items()):
            self._fill_blocks(s, name, spans, block, bs, out, want,
                              man_cache, depth + 1,
                              want_sid=sids.get(str(s)))

    def _assemble_delta(self, step: int, name: str, entry: Dict[str, Any],
                        sh: Dict[str, Any],
                        man_cache: Dict[int, Dict]) -> np.ndarray:
        d = sh["delta"]
        block, nb, size = d["block"], d["nblocks"], d["size"]
        want = np.dtype(entry["dtype"])
        out = np.zeros(nb * block, dtype=want)
        self._fill_blocks(step, name, sh["spans"], block, set(range(nb)),
                          out, want, man_cache)
        return out[:size]

    def _load_shard(self, step: int, name: str, entry: Dict[str, Any],
                    sh: Dict[str, Any],
                    man_cache: Dict[int, Dict]) -> np.ndarray:
        want = np.dtype(entry["dtype"])
        if "delta" in sh:
            payload = self._assemble_delta(step, name, entry, sh, man_cache)
        else:
            payload = self._decode_payload(self._final(step), sh, want)
        return payload.astype(want, copy=False)

    def _read_leaf(self, step: int, name: str, entry: Dict[str, Any], *,
                   man_cache: Optional[Dict[int, Dict]] = None,
                   parallel: bool = True) -> np.ndarray:
        """Reassemble one leaf from its shard spans; shard loads run on the
        I/O pool unless already inside it (parallel=False avoids nesting).
        Spans are validated to exactly tile the leaf first — a gap (lost
        host manifest) or overlap raises IOError instead of leaving
        uninitialized memory in the output."""
        man_cache = {} if man_cache is None else man_cache
        shape = tuple(entry["shape"])
        shards = self._check_tiling(name, shape, entry["shards"])
        if parallel and len(shards) > 1:
            payloads = self._engine.read_many(
                [functools.partial(self._load_shard, step, name, entry, sh,
                                   man_cache) for sh in shards])
        else:
            payloads = [self._load_shard(step, name, entry, sh, man_cache)
                        for sh in shards]
        out: Optional[np.ndarray] = None
        for sh, payload in zip(shards, payloads):
            spans = sh["spans"]
            if not spans:  # scalar
                return payload.reshape(shape)
            if out is None:
                out = np.empty(shape, dtype=entry["dtype"])
            sl = tuple(slice(a, b) for a, b in spans)
            out[sl] = payload.reshape(tuple(b - a for a, b in spans))
        assert out is not None, entry
        return out.reshape(shape)

    def _fetch_leaves(self, step: int, merged: Dict[str, Any],
                      names: List[str],
                      man_cache: Dict[int, Dict]) -> Dict[str, np.ndarray]:
        """Load many leaves concurrently (leaf-level parallelism; shard-level
        kicks in instead when a single leaf dominates)."""
        if len(names) > 1:
            arrs = self._engine.read_many(
                [functools.partial(self._read_leaf, step, n, merged[n],
                                   man_cache=man_cache, parallel=False)
                 for n in names])
        else:
            arrs = [self._read_leaf(step, n, merged[n], man_cache=man_cache)
                    for n in names]
        return dict(zip(names, arrs))

    def restore(self, *, step: Optional[int] = None, like=None,
                shardings=None) -> Tuple[Any, Optional[Dict]]:
        """Returns (state, local_state).

        ``like``: template pytree (arrays or ShapeDtypeStructs) defining the
        tree structure.  ``shardings``: matching pytree of Shardings (or
        None -> numpy arrays) — may describe a DIFFERENT mesh than the one
        that saved (elastic restore: reassembled from spans).

        Restoring also resets the in-memory delta base: a restore implies a
        rollback, so the next ``save`` is always a full checkpoint (delta
        references into post-rollback steps would be meaningless).
        """
        # join (but don't consume the error of) any in-flight async writer
        # FIRST: its completion handler updates _delta_base, and running it
        # after the reset below would resurrect a pre-rollback base
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        merged = self._load_manifests(step)
        man_cache: Dict[int, Dict] = {step: merged}

        if like is None:
            # rebuild a nested dict from dotted names
            cache = self._fetch_leaves(step, merged, list(merged), man_cache)
            root: Dict[str, Any] = {}
            for name in merged:
                parts = name.split(".")
                d = root
                for p in parts[:-1]:
                    d = d.setdefault(p, {})
                d[parts[-1]] = cache[name]
            state = root
        else:
            named = _flatten_named(like)
            for name, _ in named:
                if name not in merged:
                    raise KeyError(f"leaf {name!r} missing from checkpoint "
                                   f"{self._final(step)}")
            flat_shardings = (jax.tree_util.tree_flatten_with_path(shardings)[0]
                              if shardings is not None else None)
            cache = self._fetch_leaves(step, merged, [n for n, _ in named],
                                       man_cache)
            rebuilt = []
            for i, (name, leaf) in enumerate(named):
                sh = flat_shardings[i][1] if flat_shardings is not None else None
                arr = cache[name]
                rebuilt.append(arr if sh is None else jax.device_put(arr, sh))
            state = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like), rebuilt)

        local = None
        lp = os.path.join(self._final(step), f"local_h{self.host_id}.json")
        if os.path.exists(lp):
            local = read_json(lp)
        # rollback hygiene: never let a post-restore save reference
        # pre-restore steps as delta parents
        self._delta_base = {}
        self._chain_len = 0
        return state, local

    def restore_local_shards(self, step: int) -> List[Dict]:
        """Load every per-shard local-scope file of ``step``, ordered by
        shard index (reads run on the I/O pool).  Returns [] when the
        checkpoint predates local-scope saving — callers fall back to the
        host-scope local dict."""
        final = self._final(step)
        found = []
        for fn in os.listdir(final):
            m = _LOCAL_SHARD_RE.match(fn)
            if m:
                found.append((int(m.group(1)), os.path.join(final, fn)))
        found.sort()
        return self._engine.read_many(
            [functools.partial(read_json, p) for _, p in found])

    def restore_latest(self, *, like=None, shardings=None,
                       candidates: Optional[List[int]] = None,
                       with_local_shards: bool = False
                       ) -> Tuple[Any, Optional[Dict], int, List[Tuple[int, str]]]:
        """Restore the newest checkpoint that actually verifies.

        On a corrupt checkpoint (CRC mismatch, truncated shard, unreadable
        or incomplete manifest, a broken delta chain — a corrupt parent
        invalidates every delta that references it) it walks back through
        the retained ``keep`` history instead of failing the whole restore.
        ``candidates`` overrides the try-order (first entry tried first) —
        e.g. the SDC layer passes scrub-verified steps first.
        ``with_local_shards``: also load the per-shard local-scope files as
        part of candidate verification, so a corrupt/truncated
        ``local_s<k>.json`` walks back like any other corrupt shard instead
        of killing the restore.

        Returns (state, local_state, step, skipped) — or, with
        ``with_local_shards``, (state, local_state, shard_dicts, step,
        skipped) — where ``skipped`` is [(step, reason), ...] for every
        checkpoint that had to be passed over — callers should surface it:
        each entry is lost work.
        """
        if candidates is None:
            candidates = list(reversed(self.all_steps()))
        skipped: List[Tuple[int, str]] = []
        for s in candidates:
            try:
                state, local = self.restore(step=s, like=like,
                                            shardings=shardings)
                if with_local_shards:
                    shard_dicts = self.restore_local_shards(s)
                    return state, local, shard_dicts, s, skipped
                return state, local, s, skipped
            except (IOError, ValueError, json.JSONDecodeError) as e:
                # NOT KeyError: a template leaf missing from the manifest
                # is a caller bug that affects every candidate identically
                # — walking back would silently discard all progress
                skipped.append((s, f"{type(e).__name__}: {e}"))
        detail = "; ".join(f"step {s}: {r}" for s, r in skipped)
        raise FileNotFoundError(
            f"no restorable checkpoint under {self.directory}"
            + (f" (skipped {detail})" if detail else ""))
