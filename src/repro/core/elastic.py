"""Elastic recovery: rebuild a mesh from survivors and reshard the latest
checkpoint onto it.

The checkpoint format stores global shapes + per-shard spans, so restore can
target ANY mesh (fewer hosts after a fail-stop, more after a grow event).
This implements DeLIA's "fault treatment" options (node exclusion /
reallocation) for the JAX runtime.

Meshes come in two flavors:

- 2D ``("data", "model")`` via :func:`survivor_mesh` — the original path,
  kept for dense models.
- 3D ``("data", "model", "expert")`` via :class:`MeshSpec` +
  :func:`survivor_mesh3d` — MoE configs (Mixtral, Phi-3.5-MoE, Qwen-110B)
  where one dead host removes a slice from *every* axis.  The factorization
  picks the best legal (dp, tp, ep) grid under per-axis constraints (tp must
  divide the head count and d_ff so checkpoint spans re-tile exactly; ep must
  divide the live expert count) and degrades in priority order
  **ep -> dp -> tp**: expert parallelism is folded away first, then the batch
  shrinks, and tensor parallelism — the axis a single host's memory depends
  on — is sacrificed last.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.base import ModelConfig
from repro.sharding.api import resolve
from repro.sharding.rules import legal_dp_widths, legal_tp_widths, state_specs


class NoSurvivorsError(RuntimeError):
    """Every device failed: there is nothing to rebuild a mesh from."""


class NoLegalGridError(RuntimeError):
    """No grid satisfies the per-axis constraints (see the message for the
    legal alternatives)."""


def largest_grid(n: int, model_axis: int,
                 legal: Optional[Sequence[int]] = None) -> Tuple[int, int]:
    """(data, model) grid using at most n devices, keeping the model axis.

    Picks the **largest legal divisor**: the widest model axis that is
    <= ``model_axis``, divides ``n`` evenly, and — when ``legal`` is given
    (e.g. ``sharding.rules.legal_tp_widths(cfg)``) — is a width the model
    can actually be sharded to.  Raises :class:`NoLegalGridError` listing
    the legal grids when the constraints rule every width out, instead of
    silently returning a grid the checkpoint layer cannot re-tile."""
    if n <= 0:
        raise NoSurvivorsError(
            f"cannot build a device grid from {n} surviving devices")
    allowed = None if legal is None else {int(w) for w in legal}
    if allowed is not None and not allowed:
        raise NoLegalGridError("empty set of legal model widths")
    for model in range(min(model_axis, n), 0, -1):
        if n % model == 0 and (allowed is None or model in allowed):
            return (n // model, model)
    grids = [(n // m, m) for m in range(1, n + 1)
             if n % m == 0 and m in allowed]
    raise NoLegalGridError(
        f"no legal (data, model) grid for {n} devices with "
        f"model_axis={model_axis} and legal widths {sorted(allowed)}"
        + (f"; legal grids for {n} devices: {grids}" if grids
           else f"; no legal width divides {n}"))


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Desired (data, model, expert) grid plus per-axis legality constraints.

    ``data``/``model``/``expert`` are the *target* widths (what the job was
    launched with); :func:`best_grid3d` degrades from there when fewer
    devices survive.  ``legal_model`` is the set of tp widths the model can
    be resharded to (``None`` = any divisor); ``legal_data`` likewise for
    dp widths (FSDP shards a d_model-sized dim, so dp must divide it for a
    checkpoint to re-partition exactly); ``num_experts`` is the live
    expert count ep must divide (0 = dense model, ep pinned to 1)."""

    data: int = 1
    model: int = 1
    expert: int = 1
    legal_model: Optional[Tuple[int, ...]] = None
    legal_data: Optional[Tuple[int, ...]] = None
    num_experts: int = 0
    axis_names: Tuple[str, ...] = ("data", "model", "expert")

    @classmethod
    def from_config(cls, cfg: ModelConfig, *, data: int = 1, model: int = 1,
                    expert: int = 1) -> "MeshSpec":
        """Constraints derived from the model config: legal tp widths divide
        the head count and d_ff; legal dp widths divide d_model (the FSDP
        dim); ep divides the (live) expert count."""
        return cls(data=data, model=model, expert=expert,
                   legal_model=legal_tp_widths(cfg),
                   legal_data=legal_dp_widths(cfg),
                   num_experts=cfg.num_experts)

    @property
    def size(self) -> int:
        return self.data * self.model * self.expert

    def shape(self) -> Tuple[int, int, int]:
        return (self.data, self.model, self.expert)

    def with_experts(self, num_experts: int) -> "MeshSpec":
        """Same spec with a new live expert count (after expert loss)."""
        return dataclasses.replace(self, num_experts=num_experts)


def best_grid3d(n: int, spec: MeshSpec) -> Tuple[int, int, int]:
    """Best legal (dp, tp, ep) grid on ``n`` devices for ``spec``.

    "Best" maximizes, lexicographically: devices used; tp (capped at the
    desired width — tp is the last axis sacrificed); dp *up to* the desired
    width; ep; then any leftover devices widen dp.  That realizes the
    degradation priority **ep -> dp -> tp**: expert parallelism is the
    first axis folded away, tensor parallelism the last, and a full-size
    grid is never degraded ((2,2,2) on 8 devices stays (2,2,2)).

    When ``spec.legal_data`` is set (``MeshSpec.from_config`` derives it
    from d_model — the dim FSDP shards), dp is the widest LEGAL width
    fitting the device quota, possibly idling devices: a dp the checkpoint
    layer cannot re-partition to is no grid at all.  Raises
    :class:`NoLegalGridError` when no tp width is legal,
    :class:`NoSurvivorsError` when ``n <= 0``."""
    if n <= 0:
        raise NoSurvivorsError(
            f"cannot build a device grid from {n} surviving devices")
    tps = [w for w in range(1, min(spec.model, n) + 1)
           if spec.legal_model is None or w in spec.legal_model]
    if not tps:
        raise NoLegalGridError(
            f"no legal model width <= {min(spec.model, n)} for {n} devices "
            f"(legal widths: {sorted(spec.legal_model)})")
    if spec.num_experts:
        eps = [e for e in range(1, min(spec.expert, spec.num_experts) + 1)
               if spec.num_experts % e == 0]
    else:
        eps = [1]

    def best_dp(quota: int) -> int:
        if spec.legal_data is None:
            return quota
        fits = [w for w in spec.legal_data if 1 <= w <= quota]
        return max(fits) if fits else 0

    best = best_key = None
    for tp in tps:
        for ep in eps:
            if tp * ep > n:
                continue
            dp = best_dp(n // (tp * ep))
            if dp < 1:
                continue
            key = (dp * tp * ep, tp, min(dp, spec.data), ep, dp)
            if best_key is None or key > best_key:
                best_key, best = key, (dp, tp, ep)
    if best is None:
        raise NoLegalGridError(
            f"no legal (data, model, expert) grid fits {n} devices "
            f"(tp candidates {tps}, ep candidates {eps})")
    return best


def _resolve_survivors(failed_fraction_or_devices) -> list:
    """Device list from an explicit list, a failed-device count, or a true
    fraction (0 <= f < 1) of failed devices."""
    if isinstance(failed_fraction_or_devices, (list, tuple)):
        return list(failed_fraction_or_devices)
    all_devices = list(jax.devices())
    n = len(all_devices)
    x = failed_fraction_or_devices
    if isinstance(x, (float, np.floating)):
        # a float is a FRACTION of failed devices; reinterpreting 1.0
        # (or 2.0) as a count would silently build a mesh containing
        # dead devices — make the caller say what they mean
        if not 0 <= x < 1:
            raise ValueError(
                f"failed fraction must be in [0, 1), got {x!r}; pass an "
                "int for a device count or a device list")
        failed = int(round(x * n))
    else:
        failed = int(x)
    # clamp: a miscounted failure total (failed > n) must land in the
    # no-survivors error below, not a negative slice that would build
    # a "survivor" mesh containing dead devices
    return all_devices[: max(n - failed, 0)]


def survivor_mesh(failed_fraction_or_devices, model_axis: int = 1,
                  axis_names=("data", "model"),
                  legal: Optional[Sequence[int]] = None) -> Mesh:
    """Builds a (data, model) mesh from surviving devices.

    Accepts an explicit device list, a number of failed devices to exclude
    from ``jax.devices()``, or a true fraction (0 < f < 1) of failed
    devices (``0.5`` excludes half, rounded to nearest).  Raises
    ``NoSurvivorsError`` when nothing survives."""
    devices = _resolve_survivors(failed_fraction_or_devices)
    if not devices:
        raise NoSurvivorsError(
            "no surviving devices to build a mesh from "
            f"(failed_fraction_or_devices={failed_fraction_or_devices!r})")
    d, m = largest_grid(len(devices), model_axis, legal=legal)
    grid = np.array(devices[: d * m]).reshape(d, m)
    return Mesh(grid, axis_names)


def survivor_mesh3d(failed_fraction_or_devices, spec: MeshSpec) -> Mesh:
    """Builds the best legal (data, model, expert) mesh from survivors.

    Same survivor-resolution semantics as :func:`survivor_mesh`; the grid
    is :func:`best_grid3d`, so losing a host degrades ep first, then dp,
    and tp only when nothing else is left.

    Device placement is **expert-major**: the device list is split into
    ``ep`` contiguous blocks, one per expert coordinate.  Hosts own
    contiguous device ranges (``launch.mesh.host_device_map``), so a host's
    devices land inside ONE expert slice — a host failure breaks exactly
    one slice, which is what lets the elastic loop treat an expert slice
    as the failure unit for graceful degradation."""
    devices = _resolve_survivors(failed_fraction_or_devices)
    if not devices:
        raise NoSurvivorsError(
            "no surviving devices to build a mesh from "
            f"(failed_fraction_or_devices={failed_fraction_or_devices!r})")
    dp, tp, ep = best_grid3d(len(devices), spec)
    grid = (np.array(devices[: dp * tp * ep])
            .reshape(ep, dp, tp).transpose(1, 2, 0))
    return Mesh(grid, spec.axis_names)


def mesh_axis_sizes(mesh: Mesh) -> dict:
    """{axis name: size} for ``mesh`` (missing axes simply absent)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_width(mesh: Mesh) -> int:
    """Data-parallel width of ``mesh`` — the product of the batch-sharding
    axes ("pod", "data"), NEVER the total device count: on a 3D mesh the
    "model" and "expert" axes replicate the batch, they do not split it."""
    axes = mesh_axis_sizes(mesh)
    return int(axes.get("pod", 1)) * int(axes.get("data", 1))


def reshard_state(manager, cfg: ModelConfig, mesh: Mesh, like,
                  step: Optional[int] = None,
                  moe_ep: Optional[bool] = None):
    """Restore the latest (or given) checkpoint onto ``mesh``.

    This re-*partitions*, not just re-slices: the manifest records every
    shard's index spans, ``restore`` reassembles the global leaves, and
    ``device_put`` splits them along whatever dims ``state_specs`` shards
    over the new mesh — so a checkpoint written at tp=2 restores onto tp=1
    (concat) or tp=4 (split) exactly.

    ``moe_ep=None`` auto-detects expert placement: an "expert" axis of
    width > 1 in ``mesh`` turns on 3D expert sharding; otherwise the
    checkpoint's recorded mesh metadata (``manifest_meta``) decides.
    Returns (state, local_state, step)."""
    step = manager.latest_step() if step is None else step
    axes = mesh_axis_sizes(mesh)
    tp = int(axes.get("model", 1))
    ep = int(axes.get("expert", 1))
    if moe_ep is None:
        if ep > 1:
            moe_ep = ep
        else:
            meta = getattr(manager, "manifest_meta", lambda s: None)(step)
            moe_ep = bool((meta or {}).get("moe_ep", False))
    specs = state_specs(cfg, tp, moe_ep)
    shardings = jax.tree.map(lambda s: resolve(s, mesh), specs,
                             is_leaf=lambda x: hasattr(x, "index") or
                             x.__class__.__name__ == "PartitionSpec")
    state, local = manager.restore(step=step, like=like, shardings=shardings)
    return state, local, step


def rescale_global_batch(global_batch: int, old_data_parallel: int,
                         new_data_parallel: int) -> int:
    """Keep the per-replica batch constant when the DP width changes: the
    new global batch is ``per_replica * new_dp`` (shrinks on failure, grows
    on rejoin).  Compute/memory per device stays flat; optimizer hyper-
    parameters tied to the global batch must be rescaled by the caller.

    Widths here are **dp widths only** — pass ``dp_width(mesh)``, never a
    device count: model/expert axes replicate the batch."""
    if old_data_parallel <= 0 or new_data_parallel <= 0:
        raise ValueError((old_data_parallel, new_data_parallel))
    if global_batch % old_data_parallel:
        raise ValueError(
            f"global batch {global_batch} does not divide over "
            f"{old_data_parallel} replicas")
    per_replica = global_batch // old_data_parallel
    return per_replica * new_data_parallel


def rescale_global_batch_for_mesh(global_batch: int, old_mesh: Mesh,
                                  new_mesh: Mesh) -> int:
    """``rescale_global_batch`` with the dp widths read off the meshes' own
    "data"/"pod" axes — immune to the total-device-count bug on 3D grids."""
    return rescale_global_batch(global_batch, dp_width(old_mesh),
                                dp_width(new_mesh))
