"""Elastic recovery: rebuild a mesh from survivors and reshard the latest
checkpoint onto it.

The checkpoint format stores global shapes + per-shard spans, so restore can
target ANY mesh (fewer hosts after a fail-stop, more after a grow event).
This implements DeLIA's "fault treatment" options (node exclusion /
reallocation) for the JAX runtime.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.base import ModelConfig
from repro.sharding.api import resolve
from repro.sharding.rules import state_specs


class NoSurvivorsError(RuntimeError):
    """Every device failed: there is nothing to rebuild a mesh from."""


def largest_grid(n: int, model_axis: int) -> Tuple[int, int]:
    """(data, model) grid using at most n devices, keeping the model axis."""
    if n <= 0:
        raise NoSurvivorsError(
            f"cannot build a device grid from {n} surviving devices")
    model = min(model_axis, n)
    while n % model:
        model -= 1
    return (n // model, model)


def survivor_mesh(failed_fraction_or_devices, model_axis: int = 1,
                  axis_names=("data", "model")) -> Mesh:
    """Builds a (data, model) mesh from surviving devices.

    Accepts an explicit device list, a number of failed devices to exclude
    from ``jax.devices()``, or a true fraction (0 < f < 1) of failed
    devices (``0.5`` excludes half, rounded to nearest).  Raises
    ``NoSurvivorsError`` when nothing survives."""
    if isinstance(failed_fraction_or_devices, (list, tuple)):
        devices = list(failed_fraction_or_devices)
    else:
        all_devices = list(jax.devices())
        n = len(all_devices)
        x = failed_fraction_or_devices
        if isinstance(x, (float, np.floating)):
            # a float is a FRACTION of failed devices; reinterpreting 1.0
            # (or 2.0) as a count would silently build a mesh containing
            # dead devices — make the caller say what they mean
            if not 0 <= x < 1:
                raise ValueError(
                    f"failed fraction must be in [0, 1), got {x!r}; pass an "
                    "int for a device count or a device list")
            failed = int(round(x * n))
        else:
            failed = int(x)
        # clamp: a miscounted failure total (failed > n) must land in the
        # no-survivors error below, not a negative slice that would build
        # a "survivor" mesh containing dead devices
        devices = all_devices[: max(n - failed, 0)]
    if not devices:
        raise NoSurvivorsError(
            "no surviving devices to build a mesh from "
            f"(failed_fraction_or_devices={failed_fraction_or_devices!r})")
    d, m = largest_grid(len(devices), model_axis)
    grid = np.array(devices[: d * m]).reshape(d, m)
    return Mesh(grid, axis_names)


def reshard_state(manager, cfg: ModelConfig, mesh: Mesh, like,
                  step: Optional[int] = None, moe_ep: bool = False):
    """Restore the latest (or given) checkpoint onto ``mesh``.

    Returns (state, local_state, step)."""
    step = manager.latest_step() if step is None else step
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    specs = state_specs(cfg, tp, moe_ep)
    shardings = jax.tree.map(lambda s: resolve(s, mesh), specs,
                             is_leaf=lambda x: hasattr(x, "index") or
                             x.__class__.__name__ == "PartitionSpec")
    state, local = manager.restore(step=step, like=like, shardings=shardings)
    return state, local, step


def rescale_global_batch(global_batch: int, old_data_parallel: int,
                         new_data_parallel: int) -> int:
    """Keep the per-replica batch constant when the DP width changes: the
    new global batch is ``per_replica * new_dp`` (shrinks on failure, grows
    on rejoin).  Compute/memory per device stays flat; optimizer hyper-
    parameters tied to the global batch must be rescaled by the caller."""
    if old_data_parallel <= 0 or new_data_parallel <= 0:
        raise ValueError((old_data_parallel, new_data_parallel))
    if global_batch % old_data_parallel:
        raise ValueError(
            f"global batch {global_batch} does not divide over "
            f"{old_data_parallel} replicas")
    per_replica = global_batch // old_data_parallel
    return per_replica * new_data_parallel
