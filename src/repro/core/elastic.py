"""Elastic recovery: rebuild a mesh from survivors and reshard the latest
checkpoint onto it.

The checkpoint format stores global shapes + per-shard spans, so restore can
target ANY mesh (fewer hosts after a fail-stop, more after a grow event).
This implements DeLIA's "fault treatment" options (node exclusion /
reallocation) for the JAX runtime.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.base import ModelConfig
from repro.sharding.api import resolve
from repro.sharding.rules import state_specs


def largest_grid(n: int, model_axis: int) -> Tuple[int, int]:
    """(data, model) grid using at most n devices, keeping the model axis."""
    model = min(model_axis, n)
    while n % model:
        model -= 1
    return (n // model, model)


def survivor_mesh(failed_fraction_or_devices, model_axis: int = 1,
                  axis_names=("data", "model")) -> Mesh:
    """Builds a (data, model) mesh from surviving devices.

    Accepts either an explicit device list or a number of failed devices to
    exclude from ``jax.devices()``."""
    if isinstance(failed_fraction_or_devices, (list, tuple)):
        devices = list(failed_fraction_or_devices)
    else:
        devices = list(jax.devices())[: len(jax.devices())
                                      - int(failed_fraction_or_devices)]
    d, m = largest_grid(len(devices), model_axis)
    grid = np.array(devices[: d * m]).reshape(d, m)
    return Mesh(grid, axis_names)


def reshard_state(manager, cfg: ModelConfig, mesh: Mesh, like,
                  step: Optional[int] = None, moe_ep: bool = False):
    """Restore the latest (or given) checkpoint onto ``mesh``.

    Returns (state, local_state, step)."""
    step = manager.latest_step() if step is None else step
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    specs = state_specs(cfg, tp, moe_ep)
    shardings = jax.tree.map(lambda s: resolve(s, mesh), specs,
                             is_leaf=lambda x: hasattr(x, "index") or
                             x.__class__.__name__ == "PartitionSpec")
    state, local = manager.restore(step=step, like=like, shardings=shardings)
    return state, local, step


def rescale_global_batch(global_batch: int, new_data_parallel: int) -> int:
    """Keep per-replica batch constant when the DP width changes; round down
    to a multiple of the new DP width."""
    return max((global_batch // new_data_parallel) * new_data_parallel,
               new_data_parallel)
