"""Checkpoint-interval policies: fixed interval and Young/Daly (paper eq. 1).

    T_FO = sqrt(2 (mu - D + R) C)

with mu = system MTBF (per-node MTBF / node count), D = downtime, R =
recovery time, C = checkpoint cost.

The bracket's sign convention is a documented discrepancy: the paper
prints eq. (1) as ``mu - D + R`` (formula="paper", followed verbatim by
default), while the standard Young/Daly derivation subtracts BOTH the
downtime and the recovery time from the failure-free window —
``mu - D - R`` (formula="standard").  The standard bracket is never
larger, so it yields an equal-or-shorter period (checkpoints at least as
often).  For realistic fleets mu >> D + R and the two differ by well
under a percent; both are clamped at a small positive floor.

The adaptive policy estimates C online (EMA of measured save cost) and
converts the optimal period into a step interval using the measured step
time — this is the paper's "ajuste fino" the FWI experiment skipped (it
checkpointed every iteration, giving the max-overhead bound of eq. 3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


FORMULAS = ("paper", "standard")


def young_daly_period(mtbf_seconds: float, checkpoint_cost_s: float,
                      restart_s: float = 0.0, downtime_s: float = 0.0,
                      formula: str = "paper") -> float:
    """Paper eq. (1).  Clamps the bracket at a small positive floor.

    formula="paper": bracket = mu - D + R, as the paper prints it.
    formula="standard": bracket = mu - D - R, the textbook Young/Daly
    convention (see module docstring for the discrepancy).
    """
    if formula not in FORMULAS:
        raise ValueError(f"formula {formula!r} not in {FORMULAS}")
    sign = 1.0 if formula == "paper" else -1.0
    bracket = max(mtbf_seconds - downtime_s + sign * restart_s, 1e-9)
    return math.sqrt(2.0 * bracket * checkpoint_cost_s)


@dataclasses.dataclass
class SystemModel:
    """Fleet reliability model used to size the checkpoint interval."""
    node_mtbf_seconds: float = 3.15e7   # ~1 failure/node/year
    num_nodes: int = 1
    restart_seconds: float = 120.0
    downtime_seconds: float = 60.0

    @property
    def system_mtbf(self) -> float:
        return self.node_mtbf_seconds / max(self.num_nodes, 1)


class CheckpointPolicy:
    """Decides when to checkpoint.

    mode="every_n": fixed interval (paper's FWI setting used n=1).
    mode="young_daly": adaptive interval from eq. (1) with online C/step-time
    estimates.
    mode="risk_adjusted": young_daly, but the telemetry plane's per-host
    risk score (``observe_risk``, fed by the anomaly detectors —
    docs/observability.md "Telemetry plane") deflates the effective MTBF
    by ``(1 + risk_gain * risk)``: eq. (1) with the *conditional* failure
    rate given the precursors we are currently seeing, so the interval
    contracts ahead of a predicted failure and relaxes back as risk
    decays.  With risk 0 it is exactly young_daly.
    """

    def __init__(self, mode: str = "young_daly", every_n: int = 1,
                 system: Optional[SystemModel] = None, ema: float = 0.7,
                 min_interval: int = 1, max_interval: int = 100_000,
                 formula: str = "paper", risk_gain: float = 8.0):
        assert mode in ("every_n", "young_daly", "risk_adjusted"), mode
        assert formula in FORMULAS, formula
        self.mode = mode
        self.formula = formula
        self.every_n = max(int(every_n), 1)
        self.system = system or SystemModel()
        self.risk_gain = float(risk_gain)
        self.risk = 0.0                  # latest telemetry risk in [0, 1]
        self._ema = ema
        self.step_time_s: Optional[float] = None
        self.ckpt_cost_s: Optional[float] = None
        # per-kind cost tracking (delta checkpointing makes C bimodal:
        # cheap deltas + periodic expensive fulls; a single EMA whipsaws)
        self._kind_cost: dict = {}
        self._kind_count: dict = {}
        self.min_interval = min_interval
        self.max_interval = max_interval
        self._last_ckpt_step: Optional[int] = None

    # ---- online observations ----
    def observe_step(self, seconds: float) -> None:
        self.step_time_s = seconds if self.step_time_s is None else \
            self._ema * self.step_time_s + (1 - self._ema) * seconds

    def observe_checkpoint(self, seconds: float,
                           kind: Optional[str] = None) -> None:
        """Feed one measured checkpoint cost into the C estimate.

        ``kind=None``: single EMA (the legacy full-save pipeline).  With
        ``kind`` ("full"/"delta") each kind keeps its own EMA and C becomes
        the count-weighted mean across kinds — the AMORTIZED per-checkpoint
        cost eq. (1) actually pays under a full_every cadence, instead of
        an EMA that whipsaws between the two modes."""
        if kind is None:
            self.ckpt_cost_s = seconds if self.ckpt_cost_s is None else \
                self._ema * self.ckpt_cost_s + (1 - self._ema) * seconds
            return
        prev = self._kind_cost.get(kind)
        self._kind_cost[kind] = seconds if prev is None else \
            self._ema * prev + (1 - self._ema) * seconds
        self._kind_count[kind] = self._kind_count.get(kind, 0) + 1
        total = sum(self._kind_count.values())
        self.ckpt_cost_s = sum(
            self._kind_cost[k] * self._kind_count[k]
            for k in self._kind_cost) / total

    def observe_recovery(self, restart_s: Optional[float] = None,
                         downtime_s: Optional[float] = None) -> None:
        """Feed *measured* recovery terms into the system model: R from a
        timed restore (``Dependability.restore_latest``), D from the
        heartbeat monitor's last-beat -> declaration latency.  EMA with
        the same smoothing as C/step-time, so eq. (1)'s bracket tracks
        the deployment instead of trusting config estimates (the
        telemetry layer's live Young/Daly adaptation, ISSUE 7)."""
        if restart_s is not None:
            self.system.restart_seconds = (
                self._ema * self.system.restart_seconds
                + (1 - self._ema) * float(restart_s))
        if downtime_s is not None:
            self.system.downtime_seconds = (
                self._ema * self.system.downtime_seconds
                + (1 - self._ema) * float(downtime_s))

    def observe_risk(self, risk: float) -> None:
        """Feed the telemetry plane's current max per-host risk score
        (clamped to [0, 1]).  Only mode="risk_adjusted" consumes it."""
        self.risk = min(max(float(risk), 0.0), 1.0)

    # ---- decisions ----
    def interval_steps(self) -> int:
        if self.mode == "every_n":
            return self.every_n
        if not self.step_time_s or self.ckpt_cost_s is None:
            return self.min_interval  # bootstrap: measure C asap
        mtbf = self.system.system_mtbf
        if self.mode == "risk_adjusted" and self.risk > 0.0:
            # precursors say failures are (1 + gain*risk)x more likely
            # right now -> eq. (1) on the conditional MTBF
            mtbf /= (1.0 + self.risk_gain * self.risk)
        t_opt = young_daly_period(mtbf, self.ckpt_cost_s,
                                  self.system.restart_seconds,
                                  self.system.downtime_seconds,
                                  formula=self.formula)
        steps = int(round(t_opt / max(self.step_time_s, 1e-9)))
        return max(self.min_interval, min(steps, self.max_interval))

    def should_checkpoint(self, step: int) -> bool:
        if self._last_ckpt_step is None:
            due = step > 0 and step % self.interval_steps() == 0
        else:
            due = step - self._last_ckpt_step >= self.interval_steps()
        return due

    def record_checkpoint(self, step: int) -> None:
        self._last_ckpt_step = step

    # ---- paper metrics ----
    @staticmethod
    def fault_free_overhead(t_with: float, t_base: float) -> float:
        """Paper eq. (2)/(3): (M_with - M_without) / M_with."""
        return (t_with - t_base) / t_with
