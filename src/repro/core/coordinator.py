"""BSP training coordinator: the paper's protected iterative loop.

``run_bsp`` executes supersteps with interruption detection + data
preservation at step boundaries.  ``run_with_recovery`` wraps it with
fail-stop recovery: a (simulated or real) failure triggers restore from the
last committed checkpoint and continuation — the end-to-end behaviour DeLIA
provides to its host application.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.api import Dependability
from repro.core.failures import FaultInjector, SimulatedFailure


def run_bsp(dep: Dependability, train_step: Callable, state, data,
            num_steps: int, *, fault_injector: Optional[FaultInjector] = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None,
            final_save: bool = True) -> Tuple[Any, str, List[Dict]]:
    """Runs supersteps until ``num_steps`` or interruption.

    Returns (state, status, history); status in {"done", "interrupted"}.
    """
    history: List[Dict] = []
    step = int(jax.device_get(state["step"]))
    while step < num_steps:
        if dep.interrupted():
            if final_save:
                dep.save(step, state, final=True)
            return state, "interrupted", history

        batch = data.next_batch()
        t0 = time.perf_counter()
        if fault_injector is not None:
            # fail-stop / straggle strikes DURING the superstep
            fault_injector.check(step + 1)     # may raise SimulatedFailure
        state, metrics = train_step(state, batch)
        metrics = jax.device_get(metrics)      # block: end of superstep
        dt = time.perf_counter() - t0
        step += 1

        straggler = dep.observe_step(dt, step)
        rec = {"step": step, "seconds": dt, "straggler": straggler,
               **{k: float(v) for k, v in metrics.items()}}
        history.append(rec)
        if on_metrics:
            on_metrics(step, rec)

        if dep.should_checkpoint(step):
            dep.save(step, state)
    dep.manager.wait()
    return state, "done", history


def run_with_recovery(dep: Dependability, train_step: Callable, state, data,
                      num_steps: int, *,
                      fault_injector: Optional[FaultInjector] = None,
                      max_restarts: int = 3,
                      like=None, shardings=None,
                      on_metrics=None) -> Tuple[Any, Dict]:
    """Fail-stop recovery loop: restore-from-checkpoint on failure.

    ``like``/``shardings`` describe the state pytree for restore (defaults to
    the registered global template)."""
    restarts = 0
    all_history: List[Dict] = []
    state0 = state                           # scratch-restart fallback
    local0 = (dep._local_provider.state_dict()
              if dep._local_provider is not None else None)
    while True:
        try:
            state, status, hist = run_bsp(
                dep, train_step, state, data, num_steps,
                fault_injector=fault_injector, on_metrics=on_metrics)
            all_history.extend(hist)
            return state, {"status": status, "restarts": restarts,
                           "history": all_history}
        except SimulatedFailure as e:
            all_history.append({"step": e.step, "event": f"failure:{e.kind}"})
            restarts += 1
            if restarts > max_restarts:
                raise
            dep.manager.wait()
            try:
                state, got = dep.restore_latest(like=like,
                                                shardings=shardings)
            except FileNotFoundError:
                # failed before the first checkpoint: restart from scratch
                state = state0
                if local0 is not None:
                    dep._local_provider.load_state_dict(local0)
