"""BSP training coordinator: the paper's protected iterative loop.

``run_bsp`` executes supersteps with interruption detection + data
preservation at step boundaries.  ``run_with_recovery`` wraps it with
fail-stop AND silent-data-corruption recovery: a (simulated or real)
failure triggers restore from the last committed checkpoint and
continuation; a CorruptionDetected from any SDC tier (docs/sdc.md)
triggers rollback to the last checksum-verified checkpoint — the
end-to-end behaviour DeLIA provides to its host application.

SDC hooks inside each superstep (all no-ops unless enabled):
  - ``dep.verify_state`` at the top: re-checksums the leaves the previous
    iteration's scrub recorded — the state must be bit-identical, because
    nothing legitimate touches it between supersteps.
  - ``fault_injector.apply_sdc`` right before that verify: scheduled
    bit-flips strike the state exactly where real memory corruption
    would, inside the record->verify window.
  - ``dep.scrub`` at the bottom: checksums the next rotating subset of
    the freshly-produced state.
  - ``dep.check_metrics`` after the superstep: the tier-3 loss sentinel.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.api import Dependability
from repro.core.failures import (CorruptionDetected, FaultInjector,
                                 SimulatedFailure)


def run_bsp(dep: Dependability, train_step: Callable, state, data,
            num_steps: int, *, fault_injector: Optional[FaultInjector] = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None,
            stop_check: Optional[Callable[[], Optional[str]]] = None,
            proactive: Optional[Callable[[int], Optional[str]]] = None,
            final_save: bool = True) -> Tuple[Any, str, List[Dict]]:
    """Runs supersteps until ``num_steps`` or interruption.

    Returns (state, status, history); status in {"done", "interrupted",
    "paused:<reason>"}.  ``stop_check`` is polled at each step boundary:
    a non-None reason pauses the loop exactly like an interruption (final
    save + flush) but reports the reason — the elastic layer uses it to
    stop for non-failure events (e.g. a rejoining host growing the mesh).
    ``proactive`` is the telemetry plane's precursor hook
    (``repro.obs.anomaly.make_proactive_hook``): polled after each
    superstep when the policy cadence does NOT already save; a non-None
    reason forces a checkpoint now, ahead of the failure the precursors
    predict (docs/observability.md).  Forced saves flow through
    ``dep.save`` like any other, so they re-anchor the policy cadence.
    May raise SimulatedFailure (injected fail-stop) or CorruptionDetected
    (SDC tier tripped) — run_with_recovery handles both.
    """
    history: List[Dict] = []
    step = int(jax.device_get(state["step"]))
    while step < num_steps:
        pause = stop_check() if stop_check is not None else None
        if dep.interrupted() or pause is not None:
            if final_save:
                dep.save(step, state, final=True)
            # flush: the final save may have queued behind a still-running
            # async write — do not hand back control (or exit) with the
            # checkpoint in flight
            dep.manager.wait()
            status = "interrupted" if pause is None else f"paused:{pause}"
            return state, status, history

        if fault_injector is not None:
            # SDC strikes the at-rest state inside the record->verify window
            state = fault_injector.apply_sdc(step + 1, state)
        dep.verify_state(state, step + 1)      # may raise CorruptionDetected

        batch = data.next_batch()
        t0 = time.perf_counter()
        if fault_injector is not None:
            # fail-stop / straggle strikes DURING the superstep
            fault_injector.check(step + 1)     # may raise SimulatedFailure
        state, metrics = train_step(state, batch)
        metrics = jax.device_get(metrics)      # block: end of superstep
        dt = time.perf_counter() - t0
        step += 1

        dep.scrub(state, step)                 # record the next scrub window
        straggler = dep.observe_step(dt, step)
        rec = {"step": step, "seconds": dt, "straggler": straggler,
               **{k: float(v) for k, v in metrics.items()}}
        history.append(rec)
        if dep.obs is not None:
            # one bus record per superstep — the instrumented path
            # benchmarks/bench_obs.py holds to <2% over the bare loop
            dep.obs.emit("train", "step", **rec)
            dep.obs.registry.histogram("train.step_ms").observe(dt * 1e3)
        if on_metrics:
            on_metrics(step, rec)
        dep.check_metrics(step, metrics)       # may raise CorruptionDetected

        if dep.should_checkpoint(step):
            dep.save(step, state)
        elif proactive is not None:
            why = proactive(step)
            if why is not None:
                dep.save(step, state)
                if dep.obs is not None:
                    dep.obs.emit("checkpoint", "proactive", step=step,
                                 reason=why)
                    dep.obs.registry.counter(
                        "checkpoint.proactive").inc()
    dep.manager.wait()
    return state, "done", history


def run_with_recovery(dep: Dependability, train_step: Callable, state, data,
                      num_steps: int, *,
                      fault_injector: Optional[FaultInjector] = None,
                      max_restarts: int = 3,
                      like=None, shardings=None,
                      on_metrics=None,
                      proactive: Optional[Callable[[int], Optional[str]]]
                      = None) -> Tuple[Any, Dict]:
    """Failure recovery loop: restore-from-checkpoint on fail-stop OR
    detected corruption.

    ``like``/``shardings`` describe the state pytree for restore (defaults
    to the registered global template).  Corruption rollback restores the
    newest checksum-verified checkpoint (walking back past any checkpoint
    whose CRCs no longer verify); every rollback/restart is an event in
    the returned history."""
    restarts = 0
    all_history: List[Dict] = []
    state0 = state                           # scratch-restart fallback
    local0 = (dep._local_provider.state_dict()
              if dep._local_provider is not None else None)
    corrupt_exclude: set = set()
    last_corrupt_restore = None              # (step, saves seen at restore)
    while True:
        try:
            state, status, hist = run_bsp(
                dep, train_step, state, data, num_steps,
                fault_injector=fault_injector, on_metrics=on_metrics,
                proactive=proactive)
            all_history.extend(hist)
            return state, {"status": status, "restarts": restarts,
                           "history": all_history}
        except (SimulatedFailure, CorruptionDetected) as e:
            is_corruption = isinstance(e, CorruptionDetected)
            if is_corruption:
                all_history.append({
                    "step": e.step,
                    "event": f"corruption:{e.kind}:{e.detail}"})
            else:
                all_history.append({"step": e.step,
                                    "event": f"failure:{e.kind}"})
                if dep.obs is not None:
                    # SDC tiers emit their own detection inside
                    # verify_state/check_metrics; fail-stop is raised by
                    # the injector, so record the detection here
                    dep.obs.emit("train", "interrupted", step=e.step,
                                 failure_kind=e.kind)
            restarts += 1
            if restarts > max_restarts:
                raise
            dep.manager.wait()
            if (is_corruption and last_corrupt_restore is not None
                    and len(dep.save_history) == last_corrupt_restore[1]):
                # corruption re-tripped without a single new checkpoint:
                # the checkpoint we rolled back to is itself suspect (CRC
                # can't see corruption that happened before the save) —
                # walk one further back instead of livelocking on it
                corrupt_exclude.add(last_corrupt_restore[0])
            try:
                state, got = dep.restore_latest(
                    like=like, shardings=shardings,
                    exclude=corrupt_exclude if is_corruption else None)
                if dep.last_restore_skipped:
                    all_history.append({
                        "step": got, "event": "restore:skipped:" + ",".join(
                            str(s) for s, _ in dep.last_restore_skipped)})
                if is_corruption:
                    last_corrupt_restore = (got, len(dep.save_history))
                if dep.obs is not None:
                    dep.obs.registry.histogram("train.rollback_depth").\
                        observe(max(0, e.step - got))
                    dep.obs.emit("train", "resume", step=got,
                                 rolled_back_from=e.step,
                                 restarts=restarts)
            except FileNotFoundError as fnf:
                # no (acceptable) checkpoint at all: restart from scratch
                all_history.append({"step": e.step,
                                    "event": f"restore:scratch:{fnf}"})
                state = state0
                if local0 is not None:
                    dep._local_provider.load_state_dict(local0)
                last_corrupt_restore = None
                if dep.obs is not None:
                    dep.obs.emit("train", "resume", step=0, scratch=True,
                                 restarts=restarts)
            dep.reset_sdc()
