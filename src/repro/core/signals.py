"""Termination-signal detection (paper: "deteccao de sinais de terminacao").

Schedulers (SLURM preemption, spot/preemptible VMs, kubelet eviction) send
SIGTERM/SIGUSR1 before killing a job.  ``TerminationSignal`` latches the
signal so the BSP coordinator can take a final checkpoint at the next step
boundary and exit cleanly — compiled steps are atomic w.r.t. the handler
(the flag is only read between supersteps), which sidesteps the atomicity
problem the paper hit in the FWI codebase.
"""
from __future__ import annotations

import signal
import threading
from typing import Iterable, Optional


class TerminationSignal:
    def __init__(self, signals: Iterable[int] = (signal.SIGTERM,
                                                 signal.SIGUSR1)):
        self.signals = tuple(signals)
        self._event = threading.Event()
        self._received: Optional[int] = None
        self._prev_handlers = {}
        self._installed = False

    def install(self):
        for s in self.signals:
            self._prev_handlers[s] = signal.signal(s, self._handler)
        self._installed = True
        return self

    def _handler(self, signum, frame):
        self._received = signum
        self._event.set()

    def triggered(self) -> bool:
        return self._event.is_set()

    @property
    def received(self) -> Optional[int]:
        return self._received

    def reset(self):
        self._event.clear()
        self._received = None

    def uninstall(self):
        if not self._installed:
            return
        for s, h in self._prev_handlers.items():
            signal.signal(s, h)
        self._installed = False
