"""UDP heartbeat monitoring (paper-faithful: DeLIA uses UDP for efficient
liveness signaling).

- ``HeartbeatEmitter``: thread sending ``{host_id, seq, t}`` datagrams every
  ``period`` seconds to the monitor address.
- ``HeartbeatMonitor``: thread receiving beats; declares a host FAILED when
  no beat arrives within ``timeout = k * period`` (fail-stop detection) and
  invokes ``on_failure(host_id)`` exactly once per failure.

Paper limitation honored: a heartbeat only proves the emitter thread is
alive ("garante somente o funcionamento da componente para envio dos
batimentos") — the coordinator therefore also feeds ``progress_beat`` from
the BSP loop so a wedged-but-alive process is distinguishable (beyond-paper
strengthening, recorded in DESIGN.md).
"""
from __future__ import annotations

import json
import socket
import threading
import time
from typing import Callable, Dict, Optional


class HeartbeatEmitter:
    def __init__(self, host_id: int, monitor_addr, period: float = 0.1):
        self.host_id = host_id
        self.monitor_addr = monitor_addr
        self.period = period
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self._stop = threading.Event()
        self._seq = 0
        # incarnation: stamped once per emitter lifetime, from THIS host's
        # clock only — the monitor orders (inc, seq) pairs per host, so a
        # restarted process (new inc) or resumed emitter (same inc, larger
        # seq) is distinguishable from a stale in-flight datagram without
        # ever comparing clocks across hosts
        self._inc = time.time()
        self._thread: Optional[threading.Thread] = None
        self._paused = threading.Event()
        # chaos hook (repro.chaos.driver): the "network" between emitter
        # and monitor.  When set, each datagram's payload is offered to the
        # filter and DROPPED unless it returns True — a partition drops
        # beats while the emitter keeps running (asymmetric liveness: this
        # host still believes it is connected), unlike pause(), which
        # models the process itself dying.  seq keeps advancing across the
        # partition, so healing is indistinguishable from ordinary delivery
        # under the monitor's (inc, seq) ordering.
        self.send_filter: Optional[Callable[[dict], bool]] = None

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def pause(self):
        """Simulates fail-stop (the paper's fault model): beats just stop."""
        self._paused.set()

    def resume(self):
        self._paused.clear()

    def _run(self):
        while not self._stop.is_set():
            if not self._paused.is_set():
                payload = {"host": self.host_id, "seq": self._seq,
                           "inc": self._inc, "t": time.time()}
                gate = self.send_filter
                if gate is None or gate(payload):
                    try:
                        self._sock.sendto(json.dumps(payload).encode(),
                                          self.monitor_addr)
                    except OSError:
                        pass
                self._seq += 1
            time.sleep(self.period)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
        self._sock.close()


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, period: float = 0.1,
                 timeout_factor: float = 5.0,
                 on_failure: Optional[Callable[[int], None]] = None,
                 on_rejoin: Optional[Callable[[int], None]] = None,
                 startup_grace: Optional[float] = None,
                 bind=("127.0.0.1", 0), obs=None):
        self.num_hosts = num_hosts
        # telemetry (repro.obs.Observability): failure/rejoin events plus
        # the per-host last-beat -> declared-failure latency histogram
        self.obs = obs
        self.period = period
        self.timeout = timeout_factor * period
        # extra allowance before a never-seen host counts as failed: real
        # launches skew (host k may reach start() well after host 0), so
        # the first beat gets more slack than the steady-state timeout
        self.startup_grace = (2.0 * self.timeout if startup_grace is None
                              else startup_grace)
        self.on_failure = on_failure
        self.on_rejoin = on_rejoin
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self._sock.bind(bind)
        self._sock.settimeout(period / 2)
        self.addr = self._sock.getsockname()
        self.last_seen: Dict[int, float] = {}
        self.failed: Dict[int, float] = {}
        # acknowledged failures, out of the mesh
        self.excluded: set = set()
        # newest (inc, seq) accepted per host: a datagram at or below it is
        # a stale in-flight beat, not a rejoin
        self._last_beat: Dict[int, tuple] = {}
        # host -> seconds from last accepted beat to the failure
        # declaration, for the most recent failure of that host.  This is
        # the measured detection term D the Young/Daly model otherwise
        # only estimates (bench_heartbeat recomputed it externally before).
        self.detection_latency: Dict[int, float] = {}
        self._stop = threading.Event()
        self._threads = []
        self._lock = threading.Lock()

    def start(self):
        # Seed last_seen for every expected host so one that is silent from
        # birth still trips the timeout (it has no beat to populate the dict
        # with otherwise — it would never be declared failed).  Seeded into
        # the future by startup_grace: launch skew must not read as death.
        seed = time.time() + self.startup_grace
        with self._lock:
            for h in range(self.num_hosts):
                self.last_seen.setdefault(h, seed)
        t1 = threading.Thread(target=self._recv_loop, daemon=True)
        t2 = threading.Thread(target=self._check_loop, daemon=True)
        self._threads = [t1, t2]
        t1.start()
        t2.start()
        return self

    def watch(self, host: int) -> None:
        """Begin monitoring an identity added after start() — e.g. a warm
        standby serving replica activated into the pool (replica-scoped
        registration, docs/serving.md).  Seeded with the same startup
        grace as the initial hosts: activation skew is not death."""
        with self._lock:
            self.excluded.discard(host)
            self.failed.pop(host, None)
            self.last_seen.setdefault(host,
                                      time.time() + self.startup_grace)

    def unwatch(self, host: int) -> None:
        """Stop monitoring an identity that was decommissioned on purpose
        (replica scaled away) — unlike ``acknowledge`` it forgets the
        (inc, seq) history too, so a fresh replica may reuse the id."""
        with self._lock:
            self.failed.pop(host, None)
            self.last_seen.pop(host, None)
            self.excluded.discard(host)
            self._last_beat.pop(host, None)

    def acknowledge(self, host: int) -> None:
        """The recovery layer handled this failure: stop counting the host
        as failed and stop monitoring it until it beats again (rejoin)."""
        with self._lock:
            self.failed.pop(host, None)
            self.last_seen.pop(host, None)
            self.excluded.add(host)

    def _recv_loop(self):
        while not self._stop.is_set():
            try:
                data, _ = self._sock.recvfrom(4096)
            except socket.timeout:
                continue
            except OSError:
                break
            try:
                msg = json.loads(data.decode())
            except (ValueError, UnicodeDecodeError):
                continue
            rejoined = None
            with self._lock:
                h = int(msg["host"])
                beat = (float(msg.get("inc", 0.0)), int(msg.get("seq", 0)))
                if h in self.excluded:
                    # only a beat NEWER than everything accepted before the
                    # failure is a rejoin (same emitter resumed: same inc,
                    # larger seq; restarted process: larger inc).  A stale
                    # in-flight datagram compares <= and growing the mesh
                    # back onto a dead host would just re-fail it.  Both
                    # sides of the comparison come from the same host's
                    # clock, so cross-host skew cannot break it.
                    if beat <= self._last_beat.get(h, (0.0, -1)):
                        continue
                    self.excluded.discard(h)
                    rejoined = h
                if beat > self._last_beat.get(h, (0.0, -1)):
                    self._last_beat[h] = beat
                self.last_seen[h] = time.time()
                # a failed host beating again = recovered (failover/rejoin)
                self.failed.pop(h, None)
            if rejoined is not None:
                if self.obs is not None:
                    self.obs.emit("heartbeat", "rejoin", host=rejoined)
                    self.obs.registry.counter("heartbeat.rejoins").inc()
                if self.on_rejoin:
                    self.on_rejoin(rejoined)

    def _check_loop(self):
        while not self._stop.is_set():
            now = time.time()
            newly_failed = []
            with self._lock:
                for h, seen in list(self.last_seen.items()):
                    if h in self.failed:
                        continue
                    if now - seen > self.timeout:
                        self.failed[h] = now
                        # last-beat -> declaration gap; clamped because a
                        # never-seen host's last_seen is seeded into the
                        # future by startup_grace
                        self.detection_latency[h] = max(0.0, now - seen)
                        newly_failed.append(h)
            for h in newly_failed:
                self._observe_failure(h)
            # callbacks run OUTSIDE the lock: handlers may call back into
            # the monitor (acknowledge, failed_hosts, ...) without deadlock
            if self.on_failure:
                for h in newly_failed:
                    self.on_failure(h)
            time.sleep(self.period / 2)

    def _observe_failure(self, host: int) -> None:
        if self.obs is None:
            return
        latency = self.detection_latency.get(host, 0.0)
        self.obs.emit("heartbeat", "failure", host=host,
                      detection_latency_s=latency)
        self.obs.registry.histogram("heartbeat.detection_latency_ms",
                                    host=host).observe(latency * 1e3)
        self.obs.registry.counter("heartbeat.failures").inc()

    def alive_hosts(self):
        with self._lock:
            return sorted(h for h in self.last_seen
                          if h not in self.failed and h not in self.excluded)

    def failed_hosts(self):
        with self._lock:
            return sorted(self.failed)

    def any_failure(self) -> bool:
        with self._lock:
            return bool(self.failed)

    def stop(self):
        self._stop.set()
        for t in self._threads:
            t.join(timeout=2.0)
        self._sock.close()
