"""Parallel shard I/O engine: pooled writers/readers, batched fsync,
zero-copy CRC and streamed .npy writes.

The Young/Daly cost term C is dominated by moving checkpoint bytes — first
across the device->host link, then through the page cache to disk.  This
module removes the incidental copies and serialization points the naive
implementation pays on top of that:

- ``crc32_array``: CRC32 over ``memoryview`` chunks of the array buffer —
  no ``tobytes()`` materialization (which doubled peak memory and added a
  full copy per shard on both save and restore).
- ``write_npy``: streams one or more arrays into a single ``.npy`` file
  chunk by chunk, computing the payload CRC *in the same pass* over the
  same memoryview slices — one data traversal for write+checksum, zero
  intermediate buffers.  Multiple arrays are packed as one 1-D uint8
  payload (how the int8 codec lays out q-blocks followed by scales).
- ``ShardIOEngine``: a small ThreadPoolExecutor that encodes+writes shards
  concurrently and batches durability: files are written (and flushed)
  first, then fsynced together, then the directory is fsynced once —
  instead of a per-file write->fsync lockstep that serializes the disk
  queue.  ``fsync_mode``: "batch" (default), "per_file" (legacy lockstep),
  "none" (rely on the atomic rename only; fine for tests/tmpfs).
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, List, Sequence, Tuple

import numpy as np

CHUNK = 4 << 20  # 4 MiB streaming granule

FSYNC_MODES = ("batch", "per_file", "none")


def _byte_view(arr: np.ndarray) -> memoryview:
    """Flat uint8 memoryview of an array's buffer (copy only if the input
    is non-contiguous, which device_get outputs never are).  Goes through
    ndarray.view(uint8) rather than memoryview.cast("B"): the buffer
    protocol rejects ml_dtypes customs (bfloat16, fp8) but a uint8 view of
    the same memory is always legal."""
    a = np.ascontiguousarray(arr).reshape(-1)
    return memoryview(a.view(np.uint8))


def crc32_array(arr: np.ndarray, crc: int = 0, chunk: int = CHUNK) -> int:
    """CRC32 of the array's data bytes without a tobytes() copy."""
    mv = _byte_view(arr)
    for off in range(0, len(mv), chunk):
        crc = zlib.crc32(mv[off:off + chunk], crc)
    return crc & 0xFFFFFFFF


def write_npy(path: str, arrays, *, fsync: bool = False,
              chunk: int = CHUNK) -> Tuple[int, int]:
    """Stream array(s) to a ``.npy`` file; returns (payload_bytes, crc32).

    A single ndarray keeps its dtype/shape (np.save-compatible); a sequence
    is packed back-to-back as one 1-D uint8 payload.  The CRC covers the
    payload data bytes (not the header), matching ``crc32_array`` of the
    ``np.load``-ed result.
    """
    fmt = np.lib.format
    single = isinstance(arrays, np.ndarray)
    parts: Sequence[np.ndarray] = [arrays] if single else list(arrays)
    if single:
        a0 = np.ascontiguousarray(parts[0])
        parts = [a0]
        header = {"descr": fmt.dtype_to_descr(a0.dtype),
                  "fortran_order": False, "shape": a0.shape}
    else:
        total = sum(int(a.nbytes) for a in parts)
        header = {"descr": "|u1", "fortran_order": False, "shape": (total,)}
    crc = 0
    nbytes = 0
    with open(path, "wb") as f:
        fmt.write_array_header_1_0(f, header)
        for a in parts:
            mv = _byte_view(a)
            for off in range(0, len(mv), chunk):
                piece = mv[off:off + chunk]
                f.write(piece)
                crc = zlib.crc32(piece, crc)
            nbytes += len(mv)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    return nbytes, crc & 0xFFFFFFFF


def write_json(path: str, obj: Any, *, fsync: bool = False) -> str:
    """Write one JSON sidecar (manifest, local-scope shard state); returns
    ``path`` so callers can collect it for the batched-fsync barrier."""
    with open(path, "w") as f:
        json.dump(obj, f)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    return path


def read_json(path: str) -> Any:
    with open(path) as f:
        return json.load(f)


def fsync_path(path: str) -> None:
    """fsync a file or directory by path (for batched / rename durability)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def pid_alive(pid: int) -> bool:
    """Is ``pid`` a live process?  Signal 0 probes without delivering;
    EPERM means alive-but-not-ours (a co-hosted writer under another uid).
    Used by the stale-staging sweep: a ``step_<n>.tmp.<pid>`` directory
    whose owner is dead will never be committed and can be reclaimed."""
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    except OSError:
        return False
    return True


class ShardIOEngine:
    """ThreadPoolExecutor-backed shard writer/reader with batched fsync."""

    def __init__(self, threads: int = 0, fsync_mode: str = "batch"):
        if fsync_mode not in FSYNC_MODES:
            raise ValueError(f"fsync_mode {fsync_mode!r} not in {FSYNC_MODES}")
        self.threads = int(threads) if threads else min(
            8, max(2, os.cpu_count() or 2))
        self.fsync_mode = fsync_mode
        self._pool: ThreadPoolExecutor | None = None
        self._lock = threading.Lock()

    @property
    def per_file_fsync(self) -> bool:
        return self.fsync_mode == "per_file"

    def _get_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.threads, thread_name_prefix="ckpt-io")
            return self._pool

    def run_jobs(self, jobs: List[Callable[[], Tuple[str, int]]]
                 ) -> Tuple[int, List[str]]:
        """Run write jobs (each returns (path, nbytes)) concurrently.
        Returns (total_bytes, paths); the first job exception re-raises."""
        if len(jobs) <= 1:
            results = [j() for j in jobs]
        else:
            results = list(self._get_pool().map(lambda j: j(), jobs))
        return sum(n for _, n in results), [p for p, _ in results]

    def read_many(self, fns: List[Callable[[], np.ndarray]]) -> List:
        """Run read/decode callables concurrently, preserving order."""
        if len(fns) <= 1:
            return [fn() for fn in fns]
        return list(self._get_pool().map(lambda fn: fn(), fns))

    def finalize(self, directory: str, paths: List[str]) -> None:
        """Durability barrier: fsync written files (batch mode — per_file
        already synced them inline), then the directory entry, once."""
        if self.fsync_mode == "none":
            return
        if self.fsync_mode == "batch":
            if len(paths) > 1:
                list(self._get_pool().map(fsync_path, paths))
            else:
                for p in paths:
                    fsync_path(p)
        fsync_path(directory)

    def close(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)
