"""Elastic failover loop: heartbeat-driven mesh shrink/grow around the BSP
coordinator.

This closes the loop the modules below each solve half of:

- ``core/heartbeat.py`` detects a dead host (no beats within the timeout)
  and, via the monitor's ``on_failure`` callback, flips
  ``Dependability.interrupted()`` so ``run_bsp`` pauses at the next
  superstep boundary with a final checkpoint (global state + per-shard
  local scope) flushed to disk.
- ``core/elastic.py`` rebuilds a ``(data, model)`` mesh from the survivors
  (``survivor_mesh``) and reshards any checkpoint onto it (span-based
  reassembly in ``core/checkpoint.py``).

``run_elastic`` wires them together and adds the data-plane half: the
pipeline re-partitions its shard assignment for the new DP width
(``data.repartition``) and the per-shard local state saved by the failing
configuration is remapped onto the surviving one
(``load_shard_state_dicts``).  Training then continues from the very step
the failure interrupted — shrink on failure, grow when an excluded host
starts beating again (rejoin), FTHP-MPI-style, without a relaunch.

Single-process simulation: "hosts" are groups of devices
(``launch.mesh.host_device_map``) with one ``HeartbeatEmitter`` each;
pausing an emitter is a fail-stop, resuming it is a rejoin.
"""
from __future__ import annotations

import dataclasses
import inspect
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.api import Dependability
from repro.core.coordinator import run_bsp
from repro.core.elastic import (MeshSpec, NoSurvivorsError, best_grid3d,
                                dp_width, largest_grid, mesh_axis_sizes,
                                survivor_mesh, survivor_mesh3d)
from repro.sharding.api import mesh_context


@dataclasses.dataclass
class MeshEvent:
    """One elasticity event in a run: the mesh shrank or grew."""
    kind: str                 # "shrink" | "grow"
    hosts: Tuple[int, ...]    # hosts lost (shrink) or rejoined (grow)
    step: int                 # superstep the event interrupted
    dp: int                   # data-parallel width AFTER the event
    tp: int = 1               # model width AFTER the event (3D meshes)
    ep: int = 1               # expert width AFTER the event (3D meshes)

    def as_record(self) -> Dict:
        tail = (f":tp={self.tp}:ep={self.ep}"
                if (self.tp, self.ep) != (1, 1) else "")
        return {"step": self.step, "event":
                f"{self.kind}:{','.join(map(str, self.hosts))}"
                f":dp={self.dp}{tail}"}


@dataclasses.dataclass
class DegradedExperts:
    """Graceful expert degradation: a host failure broke an expert slice
    and the router was renormalized over the survivors instead of aborting
    (see ``layers.moe.moe_apply``'s ``dead_experts``).  Emitted on the obs
    bus as ``elastic/degraded_experts``."""
    experts: Tuple[int, ...]  # expert ids newly lost (original numbering)
    step: int                 # superstep the loss interrupted
    live: int                 # experts still routable AFTER the loss

    def as_record(self) -> Dict:
        return {"step": self.step, "event":
                f"degraded_experts:{','.join(map(str, self.experts))}"
                f":live={self.live}"}


class _HostLatch:
    """Collects host notifications from the monitor's threads; drained by
    the elastic loop at superstep boundaries.  Latching at callback time
    matters: monitor state is mutable (a transient failure can self-clear
    when a late beat lands), but an event that fired must still be
    handled."""

    def __init__(self, also: Optional[Callable[[int], None]] = None):
        self._lock = threading.Lock()
        self._hosts: set = set()
        self._also = also            # pre-existing user callback, chained

    def __call__(self, host: int) -> None:
        with self._lock:
            self._hosts.add(host)
        if self._also is not None:
            self._also(host)

    def pending(self) -> List[int]:
        with self._lock:
            return sorted(self._hosts)

    def take(self) -> List[int]:
        with self._lock:
            hosts, self._hosts = sorted(self._hosts), set()
            return hosts


def run_elastic(dep: Dependability, make_step: Callable, state, data,
                num_steps: int, *,
                host_devices: Dict[int, Sequence[Any]],
                initial_hosts: Optional[Sequence[int]] = None,
                model_axis: int = 1,
                mesh_spec: Optional[MeshSpec] = None,
                degrade_experts: bool = False,
                like=None,
                shardings_fn: Optional[Callable] = None,
                allow_grow: bool = True,
                max_events: int = 8,
                fault_injector=None,
                on_metrics=None,
                on_event: Optional[Callable[[MeshEvent], None]] = None,
                proactive: Optional[Callable[[int], Optional[str]]] = None
                ) -> Tuple[Any, Dict]:
    """Train to ``num_steps`` surviving host failures and rejoins.

    - ``make_step(mesh)`` -> train_step callable compiled for that mesh.
      With ``degrade_experts`` the callable may take a second argument —
      ``make_step(mesh, dead_experts)`` — receiving the tuple of lost
      expert ids (thread it into the model config's ``dead_experts``).
      ``shardings_fn`` gets the same optional second argument.
    - ``host_devices``: host id -> the devices that host owns; a failed
      host removes its whole group from the mesh.
    - ``mesh_spec``: switches to 3D (data, model, expert) meshes — the
      survivor grid is the best legal (dp, tp, ep) factorization
      (``survivor_mesh3d``, degradation priority ep -> dp -> tp) and the
      checkpoint reshards across ALL three axes.  ``None`` keeps the
      original 2D (data, model) path.
    - ``degrade_experts``: instead of re-gathering every expert from the
      checkpoint after a failure, drop the experts whose slice the dead
      host broke and renormalize the router over the survivors (masked
      top-k, see ``layers.moe``) — continue degraded rather than pay the
      full reshard.  Each loss is a :class:`DegradedExperts` event.
    - ``like``: template pytree for elastic restore (defaults to the
      registered global template).
    - ``shardings_fn(mesh)`` -> shardings pytree for the state on that
      mesh (None: restore to unsharded arrays).
    - ``data``: pipeline; when it has ``repartition(dp)`` its shard
      assignment follows the mesh's DP width, and when it is a local-scope
      provider (``shard_state_dicts``) its per-shard cursors ride in the
      checkpoint and remap across widths.
    - ``initial_hosts``: the hosts believed alive at entry (default: all
      of ``host_devices``).  A re-entry after an out-of-loop rollback
      (e.g. the chaos driver recovering from detected corruption) passes
      the survivor set so the first mesh excludes already-dead hosts;
      those hosts can still rejoin later — membership in ``host_devices``
      is what makes a host eligible for grow events.
    - ``proactive``: the telemetry plane's precursor hook (see
      ``run_bsp``): polled each superstep; a non-None reason forces a
      checkpoint ahead of a predicted failure, so the shrink that
      follows a precursor-flagged host's death walks back (near) zero
      steps.

    Returns ``(state, info)`` with ``info["events"]`` the MeshEvent list
    and ``info["history"]`` the merged superstep history.  Raises
    ``NoSurvivorsError`` when every host is gone.
    """
    if dep.monitor is None:
        raise ValueError(
            "run_elastic requires the heartbeat monitor: construct "
            "Dependability with heartbeat=True on host 0 and start() it")
    monitor = dep.monitor
    if dep._local_provider is None and hasattr(data, "state_dict"):
        dep.register_local_state(data)
    prev_on_failure = dep.on_host_failure
    prev_on_rejoin = dep.on_host_rejoin
    fail_latch = _HostLatch(also=prev_on_failure)
    dep.on_host_failure = fail_latch
    rejoin_latch = _HostLatch(also=prev_on_rejoin)
    if allow_grow:
        dep.on_host_rejoin = rejoin_latch

    def stop_for_grow() -> Optional[str]:
        pending = rejoin_latch.pending()
        return f"rejoin:{','.join(map(str, pending))}" if pending else None

    if initial_hosts is not None:
        bad = sorted(set(initial_hosts) - set(host_devices))
        if bad:
            raise ValueError(f"initial_hosts {bad} not in host_devices "
                             f"{sorted(host_devices)}")
    try:
        return _drive(dep, make_step, state, data, num_steps, monitor,
                      fail_latch, rejoin_latch, stop_for_grow,
                      host_devices=host_devices, initial_hosts=initial_hosts,
                      model_axis=model_axis, mesh_spec=mesh_spec,
                      degrade_experts=degrade_experts,
                      like=like, shardings_fn=shardings_fn,
                      allow_grow=allow_grow, max_events=max_events,
                      fault_injector=fault_injector, on_metrics=on_metrics,
                      on_event=on_event, proactive=proactive)
    finally:
        # the latches are only meaningful inside this run: restore the
        # user's callbacks so a later run (or user assignment) does not
        # chain latch-around-latch with stale hosts inside
        dep.on_host_failure = prev_on_failure
        dep.on_host_rejoin = prev_on_rejoin


def _accepts_dead(fn) -> bool:
    """True when ``fn`` takes a second positional arg (the dead-experts
    tuple) — lets make_step/shardings_fn opt in without breaking the
    single-argument signature every existing caller uses."""
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):
        return False
    positional = [p for p in params if p.kind in
                  (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    return len(positional) >= 2 or any(p.kind == p.VAR_POSITIONAL
                                       for p in params)


def _broken_expert_slices(mesh, lost_devices) -> List[int]:
    """Expert coordinates of ``mesh`` whose device slice lost a member.
    An expert slice fails AS A UNIT: one dead device breaks the whole
    slice (the survivors hold only fragments of its experts)."""
    axes = mesh_axis_sizes(mesh)
    ep = int(axes.get("expert", 1))
    if ep <= 1:
        return []          # experts replicated or no expert axis: no loss
    grid = mesh.devices
    lost = set(lost_devices)
    return [k for k in range(ep)
            if any(d in lost for d in grid[..., k].ravel())]


def _drive(dep, make_step, state, data, num_steps, monitor, fail_latch,
           rejoin_latch, stop_for_grow, *, host_devices, initial_hosts,
           model_axis, mesh_spec, degrade_experts, like, shardings_fn,
           allow_grow, max_events, fault_injector, on_metrics,
           on_event, proactive=None) -> Tuple[Any, Dict]:
    events: List[MeshEvent] = []
    all_history: List[Dict] = []
    active = sorted(host_devices if initial_hosts is None else initial_hosts)
    first = True
    spec = mesh_spec
    total_experts = spec.num_experts if spec is not None else 0
    dead_experts: set = set()

    def grid_of(n: int) -> Tuple[int, int, int]:
        if spec is not None:
            return best_grid3d(n, spec)
        d, _m = largest_grid(n, model_axis)
        return (d, 1, 1)

    def call_meshed(fn, mesh):
        if fn is None:
            return None
        if degrade_experts and _accepts_dead(fn):
            return fn(mesh, tuple(sorted(dead_experts)))
        return fn(mesh)

    while True:
        devices = [d for h in active for d in host_devices[h]]
        if spec is not None:
            mesh = survivor_mesh3d(devices, spec)
        else:
            mesh = survivor_mesh(devices, model_axis=model_axis)
        axes = mesh_axis_sizes(mesh)
        dp = dp_width(mesh)
        tp, ep = int(axes.get("model", 1)), int(axes.get("expert", 1))
        # record the grid the next save will be sharded on, so a restart
        # (or reshard_state) can rebuild expert placement from the manifest
        dep.mesh_meta = {"dp": dp, "tp": tp, "ep": ep,
                         "moe_ep": ep if spec is not None else False,
                         "dead_experts": sorted(dead_experts)}
        if hasattr(data, "repartition"):
            data.repartition(dp)
        shardings = call_meshed(shardings_fn, mesh)
        train_step = call_meshed(make_step, mesh)
        with mesh_context(mesh):
            if first:
                if shardings is not None:
                    state = jax.device_put(state, shardings)
                first = False
            else:
                # the latest checkpoint is the final save run_bsp flushed
                # when the event interrupted it: reshard it onto the new
                # mesh; per-shard local state remaps inside restore_latest
                # (the pipeline already has its new width)
                state, got = dep.restore_latest(like=like,
                                                shardings=shardings)
                tail = (f":tp={tp}:ep={ep}" if spec is not None else "")
                all_history.append({"step": got,
                                    "event": f"resume:dp={dp}{tail}"})
                if dep.obs is not None:
                    dep.obs.emit("elastic", "resume", step=got, dp=dp,
                                 tp=tp, ep=ep)
                    dep.obs.registry.gauge("elastic.dp_width").set(dp)
                    if spec is not None:
                        dep.obs.registry.gauge("elastic.tp_width").set(tp)
                        dep.obs.registry.gauge("elastic.ep_width").set(ep)
            state, status, hist = run_bsp(
                dep, train_step, state, data, num_steps,
                fault_injector=fault_injector, on_metrics=on_metrics,
                stop_check=stop_for_grow if allow_grow else None,
                proactive=proactive)
        all_history.extend(hist)
        if status == "done":
            return state, {"status": "done", "events": events,
                           "history": all_history, "dp": dp}

        cur = int(jax.device_get(state["step"]))
        # union of latched failures (an event that fired must be handled
        # even if a late beat cleared monitor.failed meanwhile — the host
        # will rejoin properly through the excluded path) and current
        # monitor state
        failed = sorted((set(monitor.failed_hosts()) | set(fail_latch.take()))
                        & set(active))
        rejoined = [h for h in rejoin_latch.take()
                    if h in host_devices and h not in active]
        if failed:
            for h in failed:
                monitor.acknowledge(h)   # handled: stop flagging it
            if degrade_experts and spec is not None:
                # the dead host broke its expert slice: drop that slice's
                # experts (original ids; live ones split contiguously over
                # the CURRENT mesh's expert coords) and renormalize the
                # router instead of re-gathering them from the checkpoint
                lost_devs = [d for h in failed if h in host_devices
                             for d in host_devices[h]]
                broken = _broken_expert_slices(mesh, lost_devs)
                if broken:
                    live_ids = [e for e in range(total_experts)
                                if e not in dead_experts]
                    per = len(live_ids) // max(ep, 1)
                    newly = sorted(e for k in broken
                                   for e in live_ids[k * per:(k + 1) * per])
                    still = len(live_ids) - len(newly)
                    if still <= 0:
                        raise NoSurvivorsError(
                            f"every expert slice broke at step {cur}: "
                            f"experts {newly} all lost")
                    dead_experts.update(newly)
                    spec = spec.with_experts(still)
                    degraded = DegradedExperts(tuple(newly), cur, still)
                    all_history.append(degraded.as_record())
                    if dep.obs is not None:
                        dep.obs.emit("elastic", "degraded_experts",
                                     experts=list(degraded.experts),
                                     step=cur, live=still)
                        dep.obs.registry.gauge(
                            "elastic.live_experts").set(still)
            # a concurrent rejoin still counts (it just rides the same
            # mesh rebuild instead of its own grow event)
            active = sorted(set(active) | set(rejoined))
            active = [h for h in active if h not in failed]
            survivors = [d for h in active for d in host_devices[h]]
            if not survivors:
                raise NoSurvivorsError(
                    f"all hosts failed at step {cur}: {sorted(failed)}")
            event = MeshEvent("shrink", tuple(failed), cur,
                              *grid_of(len(survivors)))
        elif rejoined:
            active = sorted(set(active) | set(rejoined))
            grown = [d for h in active for d in host_devices[h]]
            event = MeshEvent("grow", tuple(rejoined), cur,
                              *grid_of(len(grown)))
        elif status.startswith("paused:"):
            # stale rejoin notification (host already active): keep going
            continue
        else:
            # a termination signal, not an elasticity event: propagate the
            # pause — the final checkpoint is already flushed
            return state, {"status": "interrupted", "events": events,
                           "history": all_history, "dp": dp}
        events.append(event)
        if dep.obs is not None:
            dep.obs.emit("elastic", event.kind, hosts=list(event.hosts),
                         step=event.step, dp=event.dp, tp=event.tp,
                         ep=event.ep)
            dep.obs.registry.counter(f"elastic.{event.kind}s").inc()
        if len(events) > max_events:
            # over the cap: record the event but do NOT process it (no
            # on_event, no restore cycle) — a flapping host must not buy
            # extra reshard work past the budget
            raise RuntimeError(
                f"mesh changed {len(events)} times (> max_events="
                f"{max_events}); giving up: {events}")
        all_history.append(event.as_record())
        if on_event is not None:
            on_event(event)
