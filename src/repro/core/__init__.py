"""repro.core — the paper's contribution: DeLIA-style dependability for
iterative JAX applications (interruption detection + data preservation +
fail-stop recovery around BSP supersteps)."""
from repro.core.api import Dependability, DependabilityConfig
from repro.core.checkpoint import CheckpointManager, SaveStats
from repro.core.codec import CODECS, DeviceCodec, Int8BlockCodec
from repro.core.coordinator import run_bsp, run_with_recovery
from repro.core.io_engine import ShardIOEngine, crc32_array, write_npy
from repro.core.elastic import (
    MeshSpec,
    NoLegalGridError,
    NoSurvivorsError,
    best_grid3d,
    dp_width,
    largest_grid,
    rescale_global_batch,
    rescale_global_batch_for_mesh,
    reshard_state,
    survivor_mesh,
    survivor_mesh3d,
)
from repro.core.elastic_loop import DegradedExperts, MeshEvent, run_elastic
from repro.core.failures import (CorruptionDetected, FaultInjector,
                                 SimulatedFailure, StragglerWatchdog, flip_bit)
from repro.core.heartbeat import HeartbeatEmitter, HeartbeatMonitor
from repro.core.policy import CheckpointPolicy, SystemModel, young_daly_period
from repro.core.signals import TerminationSignal

__all__ = [
    "Dependability",
    "DependabilityConfig",
    "CheckpointManager",
    "SaveStats",
    "CODECS",
    "DeviceCodec",
    "Int8BlockCodec",
    "ShardIOEngine",
    "crc32_array",
    "write_npy",
    "run_bsp",
    "run_with_recovery",
    "run_elastic",
    "MeshEvent",
    "DegradedExperts",
    "NoSurvivorsError",
    "NoLegalGridError",
    "MeshSpec",
    "survivor_mesh",
    "survivor_mesh3d",
    "best_grid3d",
    "dp_width",
    "reshard_state",
    "rescale_global_batch",
    "rescale_global_batch_for_mesh",
    "largest_grid",
    "CorruptionDetected",
    "FaultInjector",
    "SimulatedFailure",
    "StragglerWatchdog",
    "flip_bit",
    "HeartbeatEmitter",
    "HeartbeatMonitor",
    "CheckpointPolicy",
    "SystemModel",
    "young_daly_period",
    "TerminationSignal",
]
