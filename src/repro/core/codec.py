"""Checkpoint codecs: lossless passthrough and int8 block quantization.

int8 halves (vs bf16) / quarters (vs fp32) checkpoint bytes -> the Young/Daly
cost C drops by the same factor -> the optimal period shrinks by sqrt(ratio)
and more checkpoints fit the same overhead budget (DESIGN.md S3/S4).

Two encode paths share one payload layout (int8 q-blocks followed by fp32
per-block scales), so the manifest records codec "int8" either way and
restore is identical:

- ``Int8BlockCodec``: numpy-side, runs in the writer pool off the BSP
  critical path.  Decode side for both paths.
- ``DeviceCodec``: quantizes *on device before device_get*, so the
  device->host link and the disk see ~3.9x fewer bytes.  Backend: the
  Pallas kernel (repro/kernels/ckpt_codec) on TPU, its jnp twin
  (repro/optim/compress.py) elsewhere — both are layout- and bit-identical
  to this file's numpy reference.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np

BLOCK = 256


def validate_delta_block(block_elems: int) -> int:
    """Delta checkpointing slices shards into ``block_elems``-element
    blocks and encodes the dirty ones standalone; the result is
    bit-identical to the matching slice of a full-save encode ONLY when
    the delta block aligns with the codec's 256-element quantization
    blocks (each q-block is self-contained: own amax, own scale).  Guard
    the invariant at construction instead of diverging at restore."""
    block_elems = int(block_elems)
    if block_elems <= 0 or block_elems % BLOCK:
        raise ValueError(
            f"delta_block must be a positive multiple of the codec block "
            f"({BLOCK} elements) so per-block int8 encodes compose "
            f"bit-identically with full-save encodes; got {block_elems}")
    return block_elems


class Codec:
    name = "base"

    def encode(self, arr: np.ndarray) -> Tuple[np.ndarray, Dict[str, Any]]:
        raise NotImplementedError

    def decode(self, payload: np.ndarray, meta: Dict[str, Any]) -> np.ndarray:
        raise NotImplementedError


class Int8BlockCodec(Codec):
    name = "int8"

    def encode(self, arr: np.ndarray):
        shape = arr.shape
        flat = np.ascontiguousarray(arr, dtype=np.float32).reshape(-1)
        pad = (-flat.size) % BLOCK
        if pad:
            flat = np.pad(flat, (0, pad))
        blocks = flat.reshape(-1, BLOCK)
        scale = np.abs(blocks).max(axis=1) / 127.0
        safe = np.maximum(scale, 1e-12)
        q = np.clip(np.rint(blocks / safe[:, None]), -127, 127).astype(np.int8)
        # payload layout: int8 data blocks followed by fp32 scales (as bytes)
        payload = np.concatenate(
            [q.reshape(-1).view(np.uint8),
             scale.astype(np.float32).view(np.uint8)])
        return payload, {"shape": list(shape), "pad": int(pad),
                         "blocks": int(blocks.shape[0])}

    def decode(self, payload: np.ndarray, meta: Dict[str, Any]) -> np.ndarray:
        nb = meta["blocks"]
        q = payload[: nb * BLOCK].view(np.int8).reshape(nb, BLOCK)
        scale = payload[nb * BLOCK:].view(np.float32)
        flat = (q.astype(np.float32) * scale[:, None]).reshape(-1)
        if meta["pad"]:
            flat = flat[: -meta["pad"]]
        return flat.reshape(meta["shape"])


class DeviceCodec:
    """On-device int8 encoder producing Int8BlockCodec-compatible payloads.

    ``encode`` returns *device* arrays (q int8 blocks + fp32 scales): the
    caller transfers those instead of the fp32 leaf, then streams them
    back-to-back into one .npy payload (see io_engine.write_npy) — no host
    concatenation copy.  ``use_kernel=None`` auto-selects the Pallas kernel
    on TPU and the jnp twin elsewhere (interpret-mode Pallas is only for
    tests; it is far too slow for multi-MB leaves on CPU).
    """

    name = "int8"

    def __init__(self, use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self.use_kernel = use_kernel
        self.interpret = interpret

    def _kernel(self) -> bool:
        if self.use_kernel is None:
            import jax
            return jax.default_backend() == "tpu"
        return self.use_kernel

    def encode(self, x):
        """x: device array, any shape/float dtype -> (q (NB, BLOCK) int8,
        scales (NB,) f32), both still on device."""
        if self._kernel():
            from repro.kernels.ckpt_codec.ops import quantize
            return quantize(x, interpret=self.interpret)
        return _jnp_encode(x)

    def decode(self, q, scales, shape):
        """Device-side inverse (tests/debug; restore uses the numpy path)."""
        if self._kernel():
            from repro.kernels.ckpt_codec.ops import dequantize
            return dequantize(q, scales, tuple(shape),
                              interpret=self.interpret)
        return _jnp_decode(q, scales, tuple(shape))

    @staticmethod
    def block_meta(shape) -> Dict[str, Any]:
        """Manifest metadata for a leaf shape (matches Int8BlockCodec's)."""
        from repro.kernels.ckpt_codec.ops import block_meta
        pad, blocks = block_meta(tuple(shape))
        return {"shape": list(shape), "pad": pad, "blocks": blocks}


@functools.lru_cache(maxsize=1)
def _jnp_encode_jit():
    import jax
    from repro.optim.compress import quantize_int8
    return jax.jit(lambda x: quantize_int8(x)[:2])


def _jnp_encode(x):
    return _jnp_encode_jit()(x)


def _jnp_decode(q, scales, shape):
    from repro.kernels.ckpt_codec.ops import block_meta
    from repro.optim.compress import dequantize_int8
    pad, _ = block_meta(tuple(shape))
    return dequantize_int8(q, scales, (shape, pad))


CODECS: Dict[str, Codec] = {"int8": Int8BlockCodec()}
