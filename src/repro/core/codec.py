"""Checkpoint codecs: lossless passthrough and int8 block quantization.

int8 halves (vs bf16) / quarters (vs fp32) checkpoint bytes -> the Young/Daly
cost C drops by the same factor -> the optimal period shrinks by sqrt(ratio)
and more checkpoints fit the same overhead budget (DESIGN.md S3/S4).

Encoding is numpy-side (it runs in the writer thread, off the BSP critical
path).  The Pallas kernel (repro/kernels/ckpt_codec) implements the same
block layout for on-device quantization (gradient compression / snapshot
shrinking before device_get); repro/optim/compress.py is its jnp twin.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

BLOCK = 256


class Codec:
    name = "base"

    def encode(self, arr: np.ndarray) -> Tuple[np.ndarray, Dict[str, Any]]:
        raise NotImplementedError

    def decode(self, payload: np.ndarray, meta: Dict[str, Any]) -> np.ndarray:
        raise NotImplementedError


class Int8BlockCodec(Codec):
    name = "int8"

    def encode(self, arr: np.ndarray):
        shape = arr.shape
        flat = np.ascontiguousarray(arr, dtype=np.float32).reshape(-1)
        pad = (-flat.size) % BLOCK
        if pad:
            flat = np.pad(flat, (0, pad))
        blocks = flat.reshape(-1, BLOCK)
        scale = np.abs(blocks).max(axis=1) / 127.0
        safe = np.maximum(scale, 1e-12)
        q = np.clip(np.rint(blocks / safe[:, None]), -127, 127).astype(np.int8)
        # payload layout: int8 data blocks followed by fp32 scales (as bytes)
        payload = np.concatenate(
            [q.reshape(-1).view(np.uint8),
             scale.astype(np.float32).view(np.uint8)])
        return payload, {"shape": list(shape), "pad": int(pad),
                         "blocks": int(blocks.shape[0])}

    def decode(self, payload: np.ndarray, meta: Dict[str, Any]) -> np.ndarray:
        nb = meta["blocks"]
        q = payload[: nb * BLOCK].view(np.int8).reshape(nb, BLOCK)
        scale = payload[nb * BLOCK:].view(np.float32)
        flat = (q.astype(np.float32) * scale[:, None]).reshape(-1)
        if meta["pad"]:
            flat = flat[: -meta["pad"]]
        return flat.reshape(meta["shape"])


CODECS: Dict[str, Codec] = {"int8": Int8BlockCodec()}
