"""Fault injection (fail-stop, straggle, SDC) + straggler watchdog.

``FaultInjector`` simulates the paper's fault model for tests/examples: a
scheduled fail-stop raises ``SimulatedFailure`` at a step boundary (the
process "dies"); the harness then restarts from the last checkpoint exactly
like a scheduler would relaunch the job.  ``schedule_bitflip`` is the
silent-data-corruption counterpart: instead of killing the process it flips
one bit inside a named state leaf — the run keeps going with a wrong answer
until an SDC tier (docs/sdc.md) notices.

``CorruptionDetected`` is the signal those tiers raise; the recovery loop
treats it like a failure whose cure is rollback rather than restart.

``StragglerWatchdog`` addresses slow-node ("fail-stutter") behaviour: it
tracks step durations and flags steps slower than ``factor`` x the running
median so the elastic layer can treat persistent stragglers as failures.

The replica-scoped injectors (``schedule_replica_kill``,
``schedule_latency_spike`` / ``check_replica``) are the serving-engine
counterparts (docs/serving.md): they drive failover and tail-latency
scenarios in the serving tests and benchmarks with the same tooling the
training E2E tests use.
"""
from __future__ import annotations

import statistics
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int, host_id: int = 0, kind: str = "fail-stop"):
        super().__init__(f"{kind} at step {step} on host {host_id}")
        self.step = step
        self.host_id = host_id
        self.kind = kind


class CorruptionDetected(RuntimeError):
    """An SDC tier found corrupted state/output.

    kind: "scrub" (tier 2, ``detail`` names the corrupted leaves),
    "sentinel" (tier 3, ``detail`` is the trip reason), or "abft"
    (tier 1 uncorrectable).  Recovery: roll back to the last
    checksum-verified checkpoint (core/coordinator.run_with_recovery).
    """

    def __init__(self, step: int, kind: str, detail: str = ""):
        super().__init__(f"corruption detected at step {step} "
                         f"[{kind}] {detail}")
        self.step = step
        self.kind = kind
        self.detail = detail


def flip_bit(leaf, bit: int):
    """Return a copy of ``leaf`` with absolute ``bit`` of its buffer
    flipped (bit // 8 = byte offset, little-endian within the byte)."""
    import jax
    import numpy as np

    arr = np.array(jax.device_get(leaf))     # writable, contiguous host copy
    flat = arr.reshape(-1).view(np.uint8)    # aliases arr's buffer
    if not 0 <= bit < flat.size * 8:
        raise IndexError(f"bit {bit} out of range for {flat.size}-byte leaf")
    flat[bit // 8] ^= np.uint8(1 << (bit % 8))
    if isinstance(leaf, jax.Array):
        return jax.device_put(arr, leaf.sharding)
    return arr


class FaultInjector:
    """Deterministic fault scheduler for tests, examples, and the chaos
    scenario engine (repro.chaos).

    Every ``schedule_*`` call returns an integer event id; pending events
    are inspectable (``pending``), cancellable (``cancel``), and bulk-
    clearable (``reset``) — a chaos driver compiling a scenario can
    therefore re-arm an injector between runs and assert exactly what is
    still scheduled.  Duplicate schedules at the same step are kept as
    distinct events (e.g. two replica kills at one engine step model a
    correlated rack loss)."""

    def __init__(self, obs=None):
        self._events: Dict[int, Dict] = {}    # eid -> event record
        self._next_eid = 0
        self.triggered: List[int] = []
        self.sdc_injected: List[Tuple[int, str, int]] = []
        self.replica_kills: List[Tuple[int, int]] = []   # (step, replica)
        # telemetry: fired injections land on the bus as ground truth to
        # hold the detectors' events against (injected vs detected)
        self.obs = obs

    def _emit(self, kind: str, **data) -> None:
        if self.obs is not None:
            self.obs.emit("injector", kind, **data)

    # ------------------------------------------------------------------
    # event bookkeeping
    # ------------------------------------------------------------------
    def _add(self, kind: str, step: int, **args) -> int:
        eid = self._next_eid
        self._next_eid += 1
        self._events[eid] = {"id": eid, "kind": kind, "step": int(step),
                             **args}
        return eid

    def _match(self, kind: str):
        """Pending events of ``kind`` in deterministic (step, id) order."""
        return sorted((e for e in self._events.values()
                       if e["kind"] == kind),
                      key=lambda e: (e["step"], e["id"]))

    def pending(self) -> List[Dict]:
        """Snapshot of every not-yet-fired event, (step, id)-ordered."""
        return sorted((dict(e) for e in self._events.values()),
                      key=lambda e: (e["step"], e["id"]))

    def cancel(self, event_id: int) -> bool:
        """Remove one pending event; False if it already fired/was
        cancelled."""
        return self._events.pop(event_id, None) is not None

    def reset(self) -> None:
        """Drop every pending event (fired-event logs are kept)."""
        self._events.clear()

    # ------------------------------------------------------------------
    # scheduling (each returns the event id)
    # ------------------------------------------------------------------
    def schedule_failstop(self, step: int, host_id: int = 0) -> int:
        return self._add("failstop", step, host=host_id)

    def schedule_straggle(self, step: int, extra_seconds: float) -> int:
        return self._add("straggle", step, extra=float(extra_seconds))

    def schedule_bitflip(self, step: int, leaf: str, bit: int) -> int:
        """Flip ``bit`` of state leaf ``leaf`` (dotted name, checkpoint-
        manifest convention: e.g. "params.blocks.l0.mlp.w_in") just before
        superstep ``step`` executes.  Deterministic SDC for tests."""
        return self._add("bitflip", step, leaf=leaf, bit=int(bit))

    def schedule_replica_kill(self, step: int, replica_id: int = 0) -> int:
        """Kill serving replica ``replica_id`` at engine step ``step``:
        ``check_replica`` raises ``SimulatedFailure(kind="replica-kill")``
        the first time that replica is dispatched to at or past the step.
        The serving engine treats it exactly like a heartbeat-detected
        death — drain, retry on survivors (docs/serving.md)."""
        return self._add("replica-kill", step, replica=replica_id)

    def schedule_latency_spike(self, step: int, extra_seconds: float,
                               replica_id: Optional[int] = None) -> int:
        """Inject a latency spike at engine step ``step``: the dispatched
        replica (or only ``replica_id`` when given) sleeps
        ``extra_seconds`` before its work — the serving fail-stutter
        counterpart of ``schedule_straggle``, drivable from latency
        benchmarks (p99) and straggler tests."""
        return self._add("latency-spike", step, replica=replica_id,
                         extra=float(extra_seconds))

    def schedule_replica_sdc(self, step: int, replica_id: int = 0,
                             detail: str = "injected") -> int:
        """Corrupt serving replica ``replica_id`` at or past engine step
        ``step``: ``check_replica`` raises ``CorruptionDetected`` the next
        time the replica is dispatched to — the deterministic serve-side
        counterpart of ``schedule_bitflip`` (an SDC storm hitting a
        replica's decode path).  The engine takes the sentinel path:
        discard the step, fail the replica, retry its streams."""
        return self._add("replica-sdc", step, replica=replica_id,
                         detail=detail)

    # ------------------------------------------------------------------
    # firing
    # ------------------------------------------------------------------
    def check_replica(self, step: int, replica_id: int):
        """Call before dispatching work to a replica at an engine step."""
        for ev in self._match("latency-spike"):
            if ev["step"] == step and (ev["replica"] is None
                                       or ev["replica"] == replica_id):
                del self._events[ev["id"]]
                time.sleep(ev["extra"])
                break
        for ev in self._match("replica-sdc"):
            if step >= ev["step"] and ev["replica"] == replica_id:
                del self._events[ev["id"]]
                self._emit("replica_sdc", step=step, replica=replica_id,
                           detail=ev["detail"])
                raise CorruptionDetected(step, "injected-sdc",
                                         ev["detail"])
        for ev in self._match("replica-kill"):
            # ">= step": the victim may not be dispatched at the exact step
            # (empty pool, already draining) — the kill must still land
            if step >= ev["step"] and ev["replica"] == replica_id:
                del self._events[ev["id"]]
                self.replica_kills.append((step, replica_id))
                self._emit("replica_kill", step=step, replica=replica_id)
                raise SimulatedFailure(step, replica_id, kind="replica-kill")

    def check(self, step: int):
        """Call at each BSP step boundary."""
        for ev in self._match("straggle"):
            if ev["step"] == step:
                del self._events[ev["id"]]
                self._emit("straggle", step=step, extra=ev["extra"])
                time.sleep(ev["extra"])
        for ev in self._match("failstop"):
            if ev["step"] == step:
                del self._events[ev["id"]]
                self.triggered.append(step)
                self._emit("failstop", step=step, host=ev["host"])
                raise SimulatedFailure(step, ev["host"])

    def apply_sdc(self, step: int, state):
        """Return ``state`` with any bit-flips scheduled for ``step``
        applied (the identity when none are due).  Unlike ``check`` this
        corrupts silently — nothing raises."""
        flips = [ev for ev in self._match("bitflip") if ev["step"] == step]
        if not flips:
            return state
        from repro.sdc.checksum import named_leaves
        import jax

        names = [n for n, _ in named_leaves(state)]
        leaves = [v for _, v in named_leaves(state)]
        for ev in flips:
            del self._events[ev["id"]]
            leaf_name, bit = ev["leaf"], ev["bit"]
            if leaf_name not in names:
                raise KeyError(f"no state leaf {leaf_name!r}; have "
                               f"{names[:8]}...")
            i = names.index(leaf_name)
            leaves[i] = flip_bit(leaves[i], bit)
            self.sdc_injected.append((step, leaf_name, bit))
            self._emit("bitflip", step=step, leaf=leaf_name, bit=bit)
        treedef = jax.tree_util.tree_structure(state)
        return jax.tree_util.tree_unflatten(treedef, leaves)


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_samples: int = 5):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        # bounded at exactly ``window`` samples: a week-long run observes
        # millions of steps and the median only ever looks at the newest
        # window anyway
        self.durations: Deque[float] = deque(maxlen=window)
        self.flagged_steps: List[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.durations) >= self.min_samples:
            med = statistics.median(self.durations)
            if seconds > self.factor * med:
                is_straggler = True
                self.flagged_steps.append(step)
                # observability tail, same bounding discipline: keep the
                # newest 4x window flags, not every flag since launch
                if len(self.flagged_steps) > 4 * self.window:
                    del self.flagged_steps[:-2 * self.window]
        self.durations.append(seconds)
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        if not self.durations:
            return None
        return statistics.median(self.durations)
