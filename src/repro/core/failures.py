"""Fault injection (fail-stop, straggle, SDC) + straggler watchdog.

``FaultInjector`` simulates the paper's fault model for tests/examples: a
scheduled fail-stop raises ``SimulatedFailure`` at a step boundary (the
process "dies"); the harness then restarts from the last checkpoint exactly
like a scheduler would relaunch the job.  ``schedule_bitflip`` is the
silent-data-corruption counterpart: instead of killing the process it flips
one bit inside a named state leaf — the run keeps going with a wrong answer
until an SDC tier (docs/sdc.md) notices.

``CorruptionDetected`` is the signal those tiers raise; the recovery loop
treats it like a failure whose cure is rollback rather than restart.

``StragglerWatchdog`` addresses slow-node ("fail-stutter") behaviour: it
tracks step durations and flags steps slower than ``factor`` x the running
median so the elastic layer can treat persistent stragglers as failures.

The replica-scoped injectors (``schedule_replica_kill``,
``schedule_latency_spike`` / ``check_replica``) are the serving-engine
counterparts (docs/serving.md): they drive failover and tail-latency
scenarios in the serving tests and benchmarks with the same tooling the
training E2E tests use.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List, Optional, Set, Tuple


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int, host_id: int = 0, kind: str = "fail-stop"):
        super().__init__(f"{kind} at step {step} on host {host_id}")
        self.step = step
        self.host_id = host_id
        self.kind = kind


class CorruptionDetected(RuntimeError):
    """An SDC tier found corrupted state/output.

    kind: "scrub" (tier 2, ``detail`` names the corrupted leaves),
    "sentinel" (tier 3, ``detail`` is the trip reason), or "abft"
    (tier 1 uncorrectable).  Recovery: roll back to the last
    checksum-verified checkpoint (core/coordinator.run_with_recovery).
    """

    def __init__(self, step: int, kind: str, detail: str = ""):
        super().__init__(f"corruption detected at step {step} "
                         f"[{kind}] {detail}")
        self.step = step
        self.kind = kind
        self.detail = detail


def flip_bit(leaf, bit: int):
    """Return a copy of ``leaf`` with absolute ``bit`` of its buffer
    flipped (bit // 8 = byte offset, little-endian within the byte)."""
    import jax
    import numpy as np

    arr = np.array(jax.device_get(leaf))     # writable, contiguous host copy
    flat = arr.reshape(-1).view(np.uint8)    # aliases arr's buffer
    if not 0 <= bit < flat.size * 8:
        raise IndexError(f"bit {bit} out of range for {flat.size}-byte leaf")
    flat[bit // 8] ^= np.uint8(1 << (bit % 8))
    if isinstance(leaf, jax.Array):
        return jax.device_put(arr, leaf.sharding)
    return arr


class FaultInjector:
    def __init__(self):
        self._fail_at: Dict[int, int] = {}     # step -> host
        self._slow_at: Dict[int, float] = {}   # step -> extra seconds
        self._flip_at: Dict[int, List[Tuple[str, int]]] = {}  # step -> flips
        # replica-scoped (serving, docs/serving.md): engine step -> replica
        self._kill_replica_at: Dict[int, int] = {}
        self._spike_at: Dict[int, Tuple[Optional[int], float]] = {}
        self.triggered: List[int] = []
        self.sdc_injected: List[Tuple[int, str, int]] = []
        self.replica_kills: List[Tuple[int, int]] = []   # (step, replica)

    def schedule_failstop(self, step: int, host_id: int = 0):
        self._fail_at[step] = host_id
        return self

    def schedule_straggle(self, step: int, extra_seconds: float):
        self._slow_at[step] = extra_seconds
        return self

    def schedule_bitflip(self, step: int, leaf: str, bit: int):
        """Flip ``bit`` of state leaf ``leaf`` (dotted name, checkpoint-
        manifest convention: e.g. "params.blocks.l0.mlp.w_in") just before
        superstep ``step`` executes.  Deterministic SDC for tests."""
        self._flip_at.setdefault(step, []).append((leaf, bit))
        return self

    def schedule_replica_kill(self, step: int, replica_id: int = 0):
        """Kill serving replica ``replica_id`` at engine step ``step``:
        ``check_replica`` raises ``SimulatedFailure(kind="replica-kill")``
        the first time that replica is dispatched to at or past the step.
        The serving engine treats it exactly like a heartbeat-detected
        death — drain, retry on survivors (docs/serving.md)."""
        self._kill_replica_at[step] = replica_id
        return self

    def schedule_latency_spike(self, step: int, extra_seconds: float,
                               replica_id: Optional[int] = None):
        """Inject a latency spike at engine step ``step``: the dispatched
        replica (or only ``replica_id`` when given) sleeps
        ``extra_seconds`` before its work — the serving fail-stutter
        counterpart of ``schedule_straggle``, drivable from latency
        benchmarks (p99) and straggler tests."""
        self._spike_at[step] = (replica_id, extra_seconds)
        return self

    def check_replica(self, step: int, replica_id: int):
        """Call before dispatching work to a replica at an engine step."""
        if step in self._spike_at:
            target, extra = self._spike_at[step]
            if target is None or target == replica_id:
                del self._spike_at[step]
                time.sleep(extra)
        for at in sorted(self._kill_replica_at):
            # ">= at": the victim may not be dispatched at the exact step
            # (empty pool, already draining) — the kill must still land
            if step >= at and self._kill_replica_at[at] == replica_id:
                del self._kill_replica_at[at]
                self.replica_kills.append((step, replica_id))
                raise SimulatedFailure(step, replica_id, kind="replica-kill")

    def check(self, step: int):
        """Call at each BSP step boundary."""
        if step in self._slow_at:
            time.sleep(self._slow_at.pop(step))
        if step in self._fail_at:
            host = self._fail_at.pop(step)
            self.triggered.append(step)
            raise SimulatedFailure(step, host)

    def apply_sdc(self, step: int, state):
        """Return ``state`` with any bit-flips scheduled for ``step``
        applied (the identity when none are due).  Unlike ``check`` this
        corrupts silently — nothing raises."""
        flips = self._flip_at.pop(step, None)
        if not flips:
            return state
        from repro.sdc.checksum import named_leaves
        import jax

        names = [n for n, _ in named_leaves(state)]
        leaves = [v for _, v in named_leaves(state)]
        for leaf_name, bit in flips:
            if leaf_name not in names:
                raise KeyError(f"no state leaf {leaf_name!r}; have "
                               f"{names[:8]}...")
            i = names.index(leaf_name)
            leaves[i] = flip_bit(leaves[i], bit)
            self.sdc_injected.append((step, leaf_name, bit))
        treedef = jax.tree_util.tree_structure(state)
        return jax.tree_util.tree_unflatten(treedef, leaves)


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_samples: int = 5):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.durations: List[float] = []
        self.flagged_steps: List[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.durations) >= self.min_samples:
            med = statistics.median(self.durations[-self.window:])
            if seconds > self.factor * med:
                is_straggler = True
                self.flagged_steps.append(step)
        self.durations.append(seconds)
        if len(self.durations) > 4 * self.window:
            self.durations = self.durations[-2 * self.window:]
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        if not self.durations:
            return None
        return statistics.median(self.durations[-self.window:])
