"""Fail-stop fault injection + straggler watchdog.

``FaultInjector`` simulates the paper's fault model for tests/examples: a
scheduled fail-stop raises ``SimulatedFailure`` at a step boundary (the
process "dies"); the harness then restarts from the last checkpoint exactly
like a scheduler would relaunch the job.

``StragglerWatchdog`` addresses slow-node ("fail-stutter") behaviour: it
tracks step durations and flags steps slower than ``factor`` x the running
median so the elastic layer can treat persistent stragglers as failures.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List, Optional, Set


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int, host_id: int = 0, kind: str = "fail-stop"):
        super().__init__(f"{kind} at step {step} on host {host_id}")
        self.step = step
        self.host_id = host_id
        self.kind = kind


class FaultInjector:
    def __init__(self):
        self._fail_at: Dict[int, int] = {}     # step -> host
        self._slow_at: Dict[int, float] = {}   # step -> extra seconds
        self.triggered: List[int] = []

    def schedule_failstop(self, step: int, host_id: int = 0):
        self._fail_at[step] = host_id
        return self

    def schedule_straggle(self, step: int, extra_seconds: float):
        self._slow_at[step] = extra_seconds
        return self

    def check(self, step: int):
        """Call at each BSP step boundary."""
        if step in self._slow_at:
            time.sleep(self._slow_at.pop(step))
        if step in self._fail_at:
            host = self._fail_at.pop(step)
            self.triggered.append(step)
            raise SimulatedFailure(step, host)


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_samples: int = 5):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.durations: List[float] = []
        self.flagged_steps: List[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.durations) >= self.min_samples:
            med = statistics.median(self.durations[-self.window:])
            if seconds > self.factor * med:
                is_straggler = True
                self.flagged_steps.append(step)
        self.durations.append(seconds)
        if len(self.durations) > 4 * self.window:
            self.durations = self.durations[-2 * self.window:]
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        if not self.durations:
            return None
        return statistics.median(self.durations[-self.window:])
