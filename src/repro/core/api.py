"""The Dependability facade — the DeLIAP/DeLIAJ-style interface, in JAX.

Mirrors the paper's library surface:
  register_global_state / register_local_state   (save-pointer registration)
  should_checkpoint / save / restore_latest      (data preservation)
  heartbeat monitoring + termination-signal detection (interruption
  detection), exposed through ``interrupted()``.

Typical BSP loop (see core/coordinator.py for the full runner)::

    dep = Dependability(DependabilityConfig(checkpoint_dir=...)).start()
    dep.register_local_state(data)
    for step in ...:
        if dep.interrupted():
            dep.save(step, state, final=True); break
        state, _ = train_step(state, batch)
        dep.observe_step(dt)
        if dep.should_checkpoint(step):
            dep.save(step, state)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

from repro.core.checkpoint import CheckpointManager, SaveStats
from repro.core.failures import CorruptionDetected, StragglerWatchdog
from repro.core.heartbeat import HeartbeatEmitter, HeartbeatMonitor
from repro.core.policy import CheckpointPolicy, SystemModel
from repro.core.signals import TerminationSignal
from repro.sdc import LossSentinel, StateScrubber


@dataclasses.dataclass
class DependabilityConfig:
    """Knobs for the dependability facade.

    Checkpoint pipeline (the Young/Daly C term):
    - ``codec``: "int8" block-quantizes float leaves >= 1 KiB in the writer
      pool (~3.9x fewer bytes on disk); None stores raw fp32.
    - ``device_codec``: quantize *on device before* the device->host
      transfer (Pallas kernel on TPU, jnp twin elsewhere), shrinking the
      snapshot critical path as well as the disk bytes; implies the int8
      layout.  Restore is identical either way.
    - ``io_threads``: shard writer/reader pool size (0 = auto, ~cpu count
      capped at 8).  Shards encode+write and restore-load concurrently.
    - ``fsync``: "batch" (default — write everything, fsync files together,
      then the directory once), "per_file" (legacy write->fsync lockstep),
      or "none" (no fsync; atomic rename only — tests/tmpfs).
    - ``async_save``: hand serialization to a writer thread; only the
      device->host snapshot stays on the BSP critical path.
    - ``delta_checkpoint``: incremental saves — per-block hashes computed
      on device (block_hash kernel) pick out the blocks that changed since
      the last committed checkpoint; only those cross the device->host
      link and hit disk.  ``delta_block`` elements per block;
      ``full_every`` bounds the reference-chain depth with periodic full
      saves.  The policy's measured C shrinks accordingly (and is tracked
      per save kind, so the Young/Daly interval sizes to the amortized
      cost).  See docs/checkpointing.md.

    Interruption detection:
    - ``heartbeat``: host 0 runs the UDP monitor; other hosts MUST set
      ``monitor_addr`` to host 0's advertised ``(ip, port)`` — there is no
      silent fallback address.

    Silent-data-corruption detection (docs/sdc.md):
    - ``scrub``: run the tier-2 StateScrubber — each superstep checksums a
      rotating ``scrub_fraction`` of the state leaves and re-verifies them
      before the next update; a mismatch raises CorruptionDetected naming
      the corrupted leaf.  Checkpoints taken while scrubbing is clean are
      recorded as *verified* and preferred by corruption rollback.
    - ``sentinel``: the tier-3 end-to-end guard — non-finite loss/grad-norm
      and loss > ``sentinel_spike_factor`` x a running EMA.
    - tier 1 (ABFT matmuls) is enabled per-model via ``impl="abft"`` in
      make_train_step / forward, not here.
    - ``policy_formula``: Young/Daly bracket convention, "paper"
      (mu - D + R, the paper's printed eq. 1) or "standard" (mu - D - R).
    """
    checkpoint_dir: str
    policy_mode: str = "young_daly"          # or "every_n"
    every_n: int = 1
    async_save: bool = False                  # paper-faithful default: sync
    codec: Optional[str] = None               # "int8" for compressed ckpts
    device_codec: bool = False                # quantize before device_get
    io_threads: int = 0                       # shard I/O pool size (0=auto)
    fsync: str = "batch"                      # "batch" | "per_file" | "none"
    delta_checkpoint: bool = False            # write only dirty blocks
    delta_block: int = 65536                  # elements per delta block
    full_every: int = 8                       # full save every N saves
    keep: int = 3
    verify_crc: bool = True
    heartbeat: bool = False
    heartbeat_period: float = 0.05
    heartbeat_timeout_factor: float = 5.0
    monitor_addr: Optional[Tuple[str, int]] = None  # monitor addr, hosts > 0
    # heartbeat identities to watch when they differ from the number of
    # checkpoint-writing hosts — single-process elastic simulations run one
    # writer (this process) but several emitters (one per simulated host)
    monitor_hosts: Optional[int] = None
    signal_detection: bool = True
    straggler_factor: float = 3.0
    system: SystemModel = dataclasses.field(default_factory=SystemModel)
    policy_formula: str = "paper"             # Young/Daly bracket convention
    scrub: bool = False                       # tier-2 SDC: state scrubber
    scrub_fraction: float = 0.25              # leaves checksummed per step
    sentinel: bool = False                    # tier-3 SDC: loss sentinel
    sentinel_spike_factor: float = 10.0
    sentinel_warmup: int = 5


class Dependability:
    def __init__(self, config: DependabilityConfig, host_id: int = 0,
                 num_hosts: int = 1):
        self.config = config
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.manager = CheckpointManager(
            config.checkpoint_dir, host_id=host_id, num_hosts=num_hosts,
            codec=config.codec, device_codec=config.device_codec,
            io_threads=config.io_threads, fsync=config.fsync,
            verify_crc=config.verify_crc, keep=config.keep,
            delta=config.delta_checkpoint, delta_block=config.delta_block,
            full_every=config.full_every)
        self.policy = CheckpointPolicy(
            mode=config.policy_mode, every_n=config.every_n,
            system=config.system, formula=config.policy_formula)
        self.stragglers = StragglerWatchdog(factor=config.straggler_factor)
        self.scrubber: Optional[StateScrubber] = (
            StateScrubber(fraction=config.scrub_fraction)
            if config.scrub else None)
        self.sentinel: Optional[LossSentinel] = (
            LossSentinel(spike_factor=config.sentinel_spike_factor,
                         warmup=config.sentinel_warmup)
            if config.sentinel else None)
        self.verified_steps: set = set()      # saved while scrub-clean
        self.last_restore_skipped: list = []
        # the (dp, tp, ep) grid the state is currently sharded on; recorded
        # into every manifest (run_elastic keeps it current across resizes)
        self.mesh_meta: Optional[dict] = None
        self.signals: Optional[TerminationSignal] = None
        self.monitor: Optional[HeartbeatMonitor] = None
        self.emitter: Optional[HeartbeatEmitter] = None
        # host-failure / host-rejoin callbacks handed to the heartbeat
        # monitor at start() — the elastic layer sets these to drive mesh
        # shrink/grow (core/elastic_loop.py)
        self.on_host_failure = None
        self.on_host_rejoin = None
        self._local_provider = None
        self._global_template = None
        self._global_shardings = None
        self.save_history: list = []
        # telemetry handle (repro.obs.Observability); attach_obs threads it
        # through the monitor and turns on event/metric emission everywhere
        self.obs = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def attach_obs(self, obs) -> "Dependability":
        """Wire a ``repro.obs.Observability`` through this facade: saves,
        restores, SDC detections, and heartbeat failures/rejoins all emit
        onto its bus, and the measured R/D terms flow into the policy via
        ``observe_recovery``.  Call before or after ``start()`` — the
        monitor picks the handle up either way."""
        self.obs = obs
        if self.monitor is not None:
            self.monitor.obs = obs
        return self

    def start(self) -> "Dependability":
        if self.config.signal_detection:
            self.signals = TerminationSignal().install()
        if self.config.heartbeat:
            if self.host_id == 0:
                self.monitor = HeartbeatMonitor(
                    self.config.monitor_hosts or self.num_hosts,
                    period=self.config.heartbeat_period,
                    timeout_factor=self.config.heartbeat_timeout_factor,
                    on_failure=lambda h: (self.on_host_failure or
                                          (lambda _: None))(h),
                    on_rejoin=lambda h: (self.on_host_rejoin or
                                         (lambda _: None))(h),
                    obs=self.obs,
                ).start()
            addr = (self.monitor.addr if self.monitor
                    else self.config.monitor_addr)
            if addr is None:
                raise ValueError(
                    f"heartbeat enabled on host {self.host_id} but no "
                    "monitor address is known: host 0 runs the monitor; "
                    "other hosts must set DependabilityConfig.monitor_addr "
                    "to its (ip, port)")
            self.emitter = HeartbeatEmitter(
                self.host_id, tuple(addr), period=self.config.heartbeat_period
            ).start()
        return self

    def stop(self) -> None:
        self.manager.close()
        if self.emitter:
            self.emitter.stop()
        if self.monitor:
            self.monitor.stop()
        if self.signals:
            self.signals.uninstall()

    # ------------------------------------------------------------------
    # registration (paper: save-pointer registration)
    # ------------------------------------------------------------------
    def register_global_state(self, template, shardings=None) -> None:
        self._global_template = template
        self._global_shardings = shardings

    def register_local_state(self, provider) -> None:
        """provider: object with state_dict() / load_state_dict().

        Local-SCOPE providers additionally expose shard_state_dicts() /
        load_shard_state_dicts(dicts): one dict per DP shard, each saved as
        its own checkpoint file and remapped by the provider on restore
        when the shard count changed (elastic shrink/grow)."""
        self._local_provider = provider

    # ------------------------------------------------------------------
    # interruption detection
    # ------------------------------------------------------------------
    def interrupted(self) -> bool:
        if self.signals is not None and self.signals.triggered():
            return True
        if self.monitor is not None and self.monitor.any_failure():
            return True
        return False

    def interruption_cause(self) -> Optional[str]:
        if self.signals is not None and self.signals.triggered():
            return f"signal:{self.signals.received}"
        if self.monitor is not None and self.monitor.any_failure():
            return f"heartbeat:{self.monitor.failed_hosts()}"
        return None

    # ------------------------------------------------------------------
    # SDC detection (docs/sdc.md; no-ops unless scrub/sentinel enabled)
    # ------------------------------------------------------------------
    def scrub(self, state, step: int) -> list:
        """Tier-2 scrub pass: checksum the next rotating subset of state
        leaves.  Call right after ``train_step`` produces the state;
        returns the leaf names covered this step."""
        if self.scrubber is None:
            return []
        return self.scrubber.record(state, step)

    def verify_state(self, state, step: int) -> None:
        """Re-verify the leaves the last ``scrub`` recorded — the state
        must not have legitimately changed in between (call at the top of
        the superstep, before ``train_step`` consumes it).  Raises
        CorruptionDetected naming the corrupted leaves on mismatch."""
        if self.scrubber is None:
            return
        bad = self.scrubber.verify(state)
        if bad:
            self._emit_sdc(step, "scrub", ",".join(bad))
            raise CorruptionDetected(step, "scrub", ",".join(bad))

    def check_metrics(self, step: int, metrics: Dict) -> None:
        """Tier-3 sentinel over one superstep's metrics; raises
        CorruptionDetected when the loss looks corrupted."""
        if self.sentinel is None:
            return
        reason = self.sentinel.observe(
            step, float(metrics.get("loss", 0.0)),
            grad_norm=(float(metrics["grad_norm"])
                       if "grad_norm" in metrics else None),
            nonfinite=(float(metrics["nonfinite"])
                       if "nonfinite" in metrics else None))
        if reason is not None:
            self._emit_sdc(step, "sentinel", reason)
            raise CorruptionDetected(step, "sentinel", reason)

    def _emit_sdc(self, step: int, tier: str, detail: str) -> None:
        if self.obs is None:
            return
        self.obs.emit("sdc", "corruption", step=step, tier=tier,
                      detail=detail)
        self.obs.registry.counter("sdc.detected", tier=tier).inc()

    def reset_sdc(self) -> None:
        """Call after a rollback: the restored state is a different set of
        buffers than the recorded scrub window."""
        if self.scrubber is not None:
            self.scrubber.reset()

    # ------------------------------------------------------------------
    # data preservation
    # ------------------------------------------------------------------
    def observe_step(self, seconds: float, step: Optional[int] = None) -> bool:
        self.policy.observe_step(seconds)
        if step is not None:
            return self.stragglers.observe(step, seconds)
        return False

    def should_checkpoint(self, step: int) -> bool:
        return self.policy.should_checkpoint(step)

    def save(self, step: int, state, *, blocking: Optional[bool] = None,
             final: bool = False) -> SaveStats:
        blocking = (not self.config.async_save) if blocking is None else blocking
        if final:
            blocking = True
        local = (self._local_provider.state_dict()
                 if self._local_provider is not None else None)
        shards = (self._local_provider.shard_state_dicts()
                  if hasattr(self._local_provider, "shard_state_dicts")
                  else None)
        t0 = time.perf_counter()
        # mesh_meta: set by run_elastic (or the caller) so the manifest
        # records the (dp, tp, ep) grid + expert placement the state was
        # sharded on — restore onto a different grid reads it back
        stats = self.manager.save(step, state, local, local_shards=shards,
                                  mesh_meta=getattr(self, "mesh_meta", None),
                                  blocking=blocking)
        cost = time.perf_counter() - t0  # on-critical-path cost
        # delta mode: feed the kind along so the policy amortizes cheap
        # deltas against periodic fulls instead of whipsawing one EMA
        self.policy.observe_checkpoint(
            cost, kind=stats.kind if self.config.delta_checkpoint else None)
        self.policy.record_checkpoint(step)
        self.save_history.append(stats)
        if self.scrubber is not None:
            # scrubbing was clean up to this step, else CorruptionDetected
            # would have unwound the loop before the save
            self.verified_steps.add(step)
        if self.obs is not None:
            self.obs.emit("checkpoint", "save", step=step,
                          save_kind=stats.kind,
                          final=final, bytes=stats.bytes_written,
                          critical_path_s=cost, blocking=blocking,
                          dirty_blocks=stats.dirty_blocks,
                          total_blocks=stats.total_blocks)
            reg = self.obs.registry
            reg.histogram("checkpoint.critical_path_ms").observe(cost * 1e3)
            reg.counter("checkpoint.saves", kind=stats.kind).inc()
            reg.counter("checkpoint.bytes").inc(stats.bytes_written)
            if stats.total_blocks:
                reg.histogram("checkpoint.dirty_block_ratio").observe(
                    stats.dirty_blocks / stats.total_blocks)
        return stats

    def restore_latest(self, like=None, shardings=None,
                       step: Optional[int] = None, exclude=None):
        """Returns (state, step).  Reloads the registered local state.

        With ``step=None`` this walks back through the retained history on
        a corrupt checkpoint (CRC mismatch etc.) instead of failing, and
        prefers scrub-verified steps when scrubbing is on; any skipped
        steps land in ``self.last_restore_skipped`` — surface them.
        ``exclude``: steps not to consider (recovery passes checkpoints
        that already failed to get training past a corruption)."""
        like = like if like is not None else self._global_template
        shardings = (shardings if shardings is not None
                     else self._global_shardings)
        self.last_restore_skipped = []
        t0 = time.perf_counter()
        wants_shards = hasattr(self._local_provider, "load_shard_state_dicts")
        if step is not None:
            state, local = self.manager.restore(step=step, like=like,
                                                shardings=shardings)
            shard_dicts = (self.manager.restore_local_shards(step)
                           if wants_shards else [])
            got_step = step
        else:
            have = [s for s in self.manager.all_steps()
                    if s not in set(exclude or ())]
            verified = sorted(self.verified_steps.intersection(have),
                              reverse=True)
            rest = sorted(set(have) - self.verified_steps, reverse=True)
            if wants_shards:
                # load the shard files inside the walk-back, so a corrupt
                # local_s<k>.json skips to an older checkpoint instead of
                # failing the whole restore
                (state, local, shard_dicts, got_step,
                 skipped) = self.manager.restore_latest(
                    like=like, shardings=shardings,
                    candidates=verified + rest, with_local_shards=True)
            else:
                shard_dicts = []
                state, local, got_step, skipped = self.manager.restore_latest(
                    like=like, shardings=shardings,
                    candidates=verified + rest)
            self.last_restore_skipped = skipped
        if self._local_provider is not None:
            if shard_dicts:
                # per-shard local scope wins: the provider remaps the shard
                # dicts onto its CURRENT width (which may differ from the
                # width that saved them — elastic shrink/grow)
                self._local_provider.load_shard_state_dicts(shard_dicts)
            elif local is not None:
                self._local_provider.load_state_dict(local)
        restore_s = time.perf_counter() - t0
        if self.obs is not None:
            # live Young/Daly (telemetry opt-in): the measured restore IS
            # the R term; the monitor's last declaration latency is the D
            # term (when heartbeat is on)
            detect_s = None
            if self.monitor is not None and self.monitor.detection_latency:
                detect_s = max(self.monitor.detection_latency.values())
            self.policy.observe_recovery(restart_s=restore_s,
                                         downtime_s=detect_s)
            self.obs.emit("checkpoint", "restore", step=got_step,
                          restore_s=restore_s,
                          skipped=list(self.last_restore_skipped))
            self.obs.registry.histogram("checkpoint.restore_ms").observe(
                restore_s * 1e3)
            self.obs.registry.counter("checkpoint.restores").inc()
        return state, got_step
