"""The Dependability facade — the DeLIAP/DeLIAJ-style interface, in JAX.

Mirrors the paper's library surface:
  register_global_state / register_local_state   (save-pointer registration)
  should_checkpoint / save / restore_latest      (data preservation)
  heartbeat monitoring + termination-signal detection (interruption
  detection), exposed through ``interrupted()``.

Typical BSP loop (see core/coordinator.py for the full runner)::

    dep = Dependability(DependabilityConfig(checkpoint_dir=...)).start()
    dep.register_local_state(data)
    for step in ...:
        if dep.interrupted():
            dep.save(step, state, final=True); break
        state, _ = train_step(state, batch)
        dep.observe_step(dt)
        if dep.should_checkpoint(step):
            dep.save(step, state)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

from repro.core.checkpoint import CheckpointManager, SaveStats
from repro.core.failures import StragglerWatchdog
from repro.core.heartbeat import HeartbeatEmitter, HeartbeatMonitor
from repro.core.policy import CheckpointPolicy, SystemModel
from repro.core.signals import TerminationSignal


@dataclasses.dataclass
class DependabilityConfig:
    """Knobs for the dependability facade.

    Checkpoint pipeline (the Young/Daly C term):
    - ``codec``: "int8" block-quantizes float leaves >= 1 KiB in the writer
      pool (~3.9x fewer bytes on disk); None stores raw fp32.
    - ``device_codec``: quantize *on device before* the device->host
      transfer (Pallas kernel on TPU, jnp twin elsewhere), shrinking the
      snapshot critical path as well as the disk bytes; implies the int8
      layout.  Restore is identical either way.
    - ``io_threads``: shard writer/reader pool size (0 = auto, ~cpu count
      capped at 8).  Shards encode+write and restore-load concurrently.
    - ``fsync``: "batch" (default — write everything, fsync files together,
      then the directory once), "per_file" (legacy write->fsync lockstep),
      or "none" (no fsync; atomic rename only — tests/tmpfs).
    - ``async_save``: hand serialization to a writer thread; only the
      device->host snapshot stays on the BSP critical path.

    Interruption detection:
    - ``heartbeat``: host 0 runs the UDP monitor; other hosts MUST set
      ``monitor_addr`` to host 0's advertised ``(ip, port)`` — there is no
      silent fallback address.
    """
    checkpoint_dir: str
    policy_mode: str = "young_daly"          # or "every_n"
    every_n: int = 1
    async_save: bool = False                  # paper-faithful default: sync
    codec: Optional[str] = None               # "int8" for compressed ckpts
    device_codec: bool = False                # quantize before device_get
    io_threads: int = 0                       # shard I/O pool size (0=auto)
    fsync: str = "batch"                      # "batch" | "per_file" | "none"
    keep: int = 3
    verify_crc: bool = True
    heartbeat: bool = False
    heartbeat_period: float = 0.05
    heartbeat_timeout_factor: float = 5.0
    monitor_addr: Optional[Tuple[str, int]] = None  # monitor addr, hosts > 0
    signal_detection: bool = True
    straggler_factor: float = 3.0
    system: SystemModel = dataclasses.field(default_factory=SystemModel)


class Dependability:
    def __init__(self, config: DependabilityConfig, host_id: int = 0,
                 num_hosts: int = 1):
        self.config = config
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.manager = CheckpointManager(
            config.checkpoint_dir, host_id=host_id, num_hosts=num_hosts,
            codec=config.codec, device_codec=config.device_codec,
            io_threads=config.io_threads, fsync=config.fsync,
            verify_crc=config.verify_crc, keep=config.keep)
        self.policy = CheckpointPolicy(
            mode=config.policy_mode, every_n=config.every_n,
            system=config.system)
        self.stragglers = StragglerWatchdog(factor=config.straggler_factor)
        self.signals: Optional[TerminationSignal] = None
        self.monitor: Optional[HeartbeatMonitor] = None
        self.emitter: Optional[HeartbeatEmitter] = None
        self._local_provider = None
        self._global_template = None
        self._global_shardings = None
        self.save_history: list = []

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "Dependability":
        if self.config.signal_detection:
            self.signals = TerminationSignal().install()
        if self.config.heartbeat:
            if self.host_id == 0:
                self.monitor = HeartbeatMonitor(
                    self.num_hosts, period=self.config.heartbeat_period,
                    timeout_factor=self.config.heartbeat_timeout_factor
                ).start()
            addr = (self.monitor.addr if self.monitor
                    else self.config.monitor_addr)
            if addr is None:
                raise ValueError(
                    f"heartbeat enabled on host {self.host_id} but no "
                    "monitor address is known: host 0 runs the monitor; "
                    "other hosts must set DependabilityConfig.monitor_addr "
                    "to its (ip, port)")
            self.emitter = HeartbeatEmitter(
                self.host_id, tuple(addr), period=self.config.heartbeat_period
            ).start()
        return self

    def stop(self) -> None:
        self.manager.close()
        if self.emitter:
            self.emitter.stop()
        if self.monitor:
            self.monitor.stop()
        if self.signals:
            self.signals.uninstall()

    # ------------------------------------------------------------------
    # registration (paper: save-pointer registration)
    # ------------------------------------------------------------------
    def register_global_state(self, template, shardings=None) -> None:
        self._global_template = template
        self._global_shardings = shardings

    def register_local_state(self, provider) -> None:
        """provider: object with state_dict() / load_state_dict()."""
        self._local_provider = provider

    # ------------------------------------------------------------------
    # interruption detection
    # ------------------------------------------------------------------
    def interrupted(self) -> bool:
        if self.signals is not None and self.signals.triggered():
            return True
        if self.monitor is not None and self.monitor.any_failure():
            return True
        return False

    def interruption_cause(self) -> Optional[str]:
        if self.signals is not None and self.signals.triggered():
            return f"signal:{self.signals.received}"
        if self.monitor is not None and self.monitor.any_failure():
            return f"heartbeat:{self.monitor.failed_hosts()}"
        return None

    # ------------------------------------------------------------------
    # data preservation
    # ------------------------------------------------------------------
    def observe_step(self, seconds: float, step: Optional[int] = None) -> bool:
        self.policy.observe_step(seconds)
        if step is not None:
            return self.stragglers.observe(step, seconds)
        return False

    def should_checkpoint(self, step: int) -> bool:
        return self.policy.should_checkpoint(step)

    def save(self, step: int, state, *, blocking: Optional[bool] = None,
             final: bool = False) -> SaveStats:
        blocking = (not self.config.async_save) if blocking is None else blocking
        if final:
            blocking = True
        local = (self._local_provider.state_dict()
                 if self._local_provider is not None else None)
        t0 = time.perf_counter()
        stats = self.manager.save(step, state, local, blocking=blocking)
        cost = time.perf_counter() - t0  # on-critical-path cost
        self.policy.observe_checkpoint(cost)
        self.policy.record_checkpoint(step)
        self.save_history.append(stats)
        return stats

    def restore_latest(self, like=None, shardings=None,
                       step: Optional[int] = None):
        """Returns (state, step).  Reloads the registered local state."""
        like = like if like is not None else self._global_template
        shardings = (shardings if shardings is not None
                     else self._global_shardings)
        state, local = self.manager.restore(step=step, like=like,
                                            shardings=shardings)
        if local is not None and self._local_provider is not None:
            self._local_provider.load_state_dict(local)
        got_step = step if step is not None else self.manager.latest_step()
        return state, got_step
