"""Online anomaly detection over the merged telemetry stream
(docs/observability.md, "Telemetry plane").

Detectors watch the event stream the :class:`~repro.obs.collector.
Collector` merges (or a local :class:`~repro.obs.bus.EventBus`, for the
single-process plane) and emit ``precursor/*`` events when a host
starts *looking* like it is about to fail — before the heartbeat
monitor or sentinel declares it dead.  FTHP-MPI's argument (PAPERS.md)
is that fault tolerance should act ahead of the failure's arrival on
the critical path; the precursors here are the triggers for that
proactive action: a risk-adjusted Young/Daly interval
(``CheckpointPolicy(mode="risk_adjusted")``), a forced checkpoint
(:func:`make_proactive_hook` -> ``run_elastic(proactive=...)``), and a
serve-replica pre-drain (``ServeEngine(risk_source=...)``).

Three detectors, one per failure precursor the chaos engine can stage:

* :class:`StepTimeDriftDetector` — EWMA baseline of per-host step
  seconds (``train/step`` and ``telemetry/replica_step`` events); a run
  of ``consecutive`` samples above ``factor`` x the baseline fires.
  Catches stragglers (thermal throttling, a dying NIC) ahead of the
  fail-stop they often precede.
* :class:`BeatJitterDetector` — EWMA baseline of datagram inter-arrival
  per host; sustained inter-arrival blowup fires before the heartbeat
  monitor's hard timeout does (the monitor needs ``timeout_factor``
  missed periods; jitter shows up earlier).
* :class:`ScrubRateDetector` — trailing-window count of SDC detections
  (``sdc/*`` events) per host; an accelerating hit rate means a memory/
  logic path is degrading, not a one-off flip.

:class:`AnomalyEngine` multiplexes events to the detectors and folds
their firings into one per-host risk score in [0, 1]: firings max-merge
in, healthy step samples decay it (``decay`` per sample).  The score is
what downstream consumers read — they never see individual detectors.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

from .bus import Event, EventBus

__all__ = ["AnomalyEngine", "BeatJitterDetector", "ScrubRateDetector",
           "StepTimeDriftDetector", "make_proactive_hook"]


class StepTimeDriftDetector:
    """EWMA step-time drift: fires when ``consecutive`` successive step
    durations from one host exceed ``factor`` x that host's EWMA
    baseline.  The baseline only absorbs *healthy* samples — anomalous
    ones are excluded so a sustained straggle cannot normalize itself
    into the mean."""

    kind = "step_time_drift"

    #: event (subsystem, kind) pairs that carry a step duration
    WATCHED = (("train", "step"), ("telemetry", "replica_step"))

    def __init__(self, factor: float = 2.0, consecutive: int = 3,
                 alpha: float = 0.2, warmup: int = 3):
        if factor <= 1.0:
            raise ValueError(f"factor must be > 1, got {factor}")
        self.factor = factor
        self.consecutive = consecutive
        self.alpha = alpha
        self.warmup = warmup
        self._mean: Dict[int, float] = {}
        self._n: Dict[int, int] = {}
        self._streak: Dict[int, int] = {}

    def observe(self, origin: int, ev: Event) -> Optional[float]:
        if (ev.subsystem, ev.kind) not in self.WATCHED:
            return None
        dt = ev.data.get("seconds")
        if dt is None:
            return None
        host = int(ev.data.get("host", origin))
        n = self._n.get(host, 0)
        mean = self._mean.get(host, float(dt))
        if n >= self.warmup and dt > self.factor * mean:
            streak = self._streak.get(host, 0) + 1
            self._streak[host] = streak
            if streak >= self.consecutive:
                self._streak[host] = 0     # refractory: re-arm from zero
                excess = dt / (self.factor * mean) - 1.0
                return min(1.0, 0.5 + 0.5 * excess)
            return None
        self._streak[host] = 0
        self._mean[host] = (1 - self.alpha) * mean + self.alpha * float(dt)
        self._n[host] = n + 1
        return None


class BeatJitterDetector:
    """Datagram inter-arrival jitter: fires when ``consecutive``
    successive inter-arrival gaps from one host exceed ``factor`` x
    that host's EWMA inter-arrival baseline.  Fed by the collector's
    receive loop (``observe_arrival``), not by events — loss and delay
    both stretch the gap, and both are precursors."""

    kind = "beat_jitter"

    def __init__(self, factor: float = 3.0, consecutive: int = 2,
                 alpha: float = 0.2, warmup: int = 3):
        if factor <= 1.0:
            raise ValueError(f"factor must be > 1, got {factor}")
        self.factor = factor
        self.consecutive = consecutive
        self.alpha = alpha
        self.warmup = warmup
        self._last: Dict[int, float] = {}
        self._mean: Dict[int, float] = {}
        self._n: Dict[int, int] = {}
        self._streak: Dict[int, int] = {}

    def observe_arrival(self, host: int, t: float) -> Optional[float]:
        last = self._last.get(host)
        self._last[host] = t
        if last is None:
            return None
        gap = t - last
        n = self._n.get(host, 0)
        mean = self._mean.get(host, gap)
        if n >= self.warmup and gap > self.factor * mean:
            streak = self._streak.get(host, 0) + 1
            self._streak[host] = streak
            if streak >= self.consecutive:
                self._streak[host] = 0
                excess = gap / (self.factor * mean) - 1.0
                return min(1.0, 0.5 + 0.5 * excess)
            return None
        self._streak[host] = 0
        self._mean[host] = (1 - self.alpha) * mean + self.alpha * gap
        self._n[host] = n + 1
        return None

    def observe(self, origin: int, ev: Event) -> Optional[float]:
        return None                      # arrival-driven, not event-driven


class ScrubRateDetector:
    """SDC hit-rate acceleration: keeps each host's last ``window``
    detection timestamps (any ``sdc/*`` event); fires once the window
    fills AND spans less than ``max_span`` seconds — i.e. detections
    are arriving fast, not trickling.  A single flip never fires."""

    kind = "scrub_rate"

    def __init__(self, window: int = 3, max_span: float = 60.0):
        if window < 2:
            raise ValueError(f"window must be >= 2, got {window}")
        self.window = window
        self.max_span = max_span
        self._hits: Dict[int, List[float]] = {}

    def observe(self, origin: int, ev: Event) -> Optional[float]:
        if ev.subsystem != "sdc":
            return None
        host = int(ev.data.get("host", origin))
        hits = self._hits.setdefault(host, [])
        hits.append(ev.t_mono)
        if len(hits) > self.window:
            del hits[:-self.window]
        if len(hits) == self.window:
            span = hits[-1] - hits[0]
            if span < self.max_span:
                self._hits[host] = []    # refractory
                return min(1.0, 0.5 + 0.5 *
                           (1.0 - span / max(self.max_span, 1e-9)))
        return None


class AnomalyEngine:
    """Multiplexes a telemetry stream to the detectors and folds their
    firings into per-host risk scores in [0, 1].

    * a detector firing with score ``s`` max-merges: ``risk = max(risk,
      s)`` — a fresh, stronger signal always wins;
    * every *healthy* step-like sample from a host decays its risk by
      ``decay`` — risk is a leaky accumulator, quiet hosts drift back
      to 0.

    ``emit`` (if given) receives ``("precursor", <detector.kind>,
    host=..., score=..., risk=...)`` on each firing — wire it to an
    ``EventBus.emit`` (local plane) or the collector's merge hook
    (cross-host plane) so precursors land in the same stream they were
    detected from.  ``on_precursor(host, kind, risk)`` is the low-
    latency callback path for the proactive hooks."""

    def __init__(self, detectors: Optional[List[Any]] = None,
                 decay: float = 0.9,
                 on_precursor: Optional[Callable[[int, str, float],
                                                 None]] = None,
                 emit: Optional[Callable[..., Any]] = None):
        self.detectors = (list(detectors) if detectors is not None else
                          [StepTimeDriftDetector(), BeatJitterDetector(),
                           ScrubRateDetector()])
        self.decay = decay
        self.on_precursor = on_precursor
        self.emit = emit
        self._risk: Dict[int, float] = {}
        self._lock = threading.Lock()
        self.precursors = 0              # total firings, for quick asserts

    # -- stream input --------------------------------------------------
    def observe_event(self, origin: int, ev: Event) -> None:
        if ev.subsystem == "precursor":
            return                       # our own output: never re-ingest
        fired = []
        with self._lock:
            for det in self.detectors:
                score = det.observe(origin, ev)
                if score is not None:
                    fired.append((det.kind, score))
            host = int(ev.data.get("host", origin))
            if not fired and (ev.subsystem, ev.kind) in \
                    StepTimeDriftDetector.WATCHED:
                if host in self._risk:
                    self._risk[host] *= self.decay
            for _, score in fired:
                self._risk[host] = max(self._risk.get(host, 0.0), score)
            risk = self._risk.get(host, 0.0)
        for det_kind, score in fired:
            self._fire(host, det_kind, score, risk)

    def observe_arrival(self, host: int, t: float) -> None:
        """Feed a datagram arrival (collector receive loop)."""
        fired = None
        with self._lock:
            for det in self.detectors:
                fn = getattr(det, "observe_arrival", None)
                if fn is None:
                    continue
                score = fn(host, t)
                if score is not None:
                    self._risk[host] = max(self._risk.get(host, 0.0),
                                           score)
                    fired = (det.kind, score)
            risk = self._risk.get(host, 0.0)
        if fired is not None:
            self._fire(host, fired[0], fired[1], risk)

    def _fire(self, host: int, det_kind: str, score: float,
              risk: float) -> None:
        self.precursors += 1
        if self.emit is not None:
            self.emit("precursor", det_kind, host=host, score=score,
                      risk=risk)
        if self.on_precursor is not None:
            self.on_precursor(host, det_kind, risk)

    # -- risk output ---------------------------------------------------
    def risk(self, host: int) -> float:
        with self._lock:
            return self._risk.get(host, 0.0)

    def risk_scores(self) -> Dict[int, float]:
        with self._lock:
            return dict(self._risk)

    # -- local (single-process) plane ----------------------------------
    def attach(self, bus: EventBus, origin: int = 0) -> Callable:
        """Subscribe to a local bus: events flow straight into the
        detectors and precursors are emitted back onto the same bus —
        the in-process degenerate case of the agent->collector plane."""
        if self.emit is None:
            self.emit = bus.emit

        def _on_event(ev: Event) -> None:
            self.observe_event(origin, ev)

        return bus.subscribe(_on_event)


def make_proactive_hook(source: Callable[[], Dict[int, float]],
                        threshold: float = 0.5,
                        cooldown_steps: int = 10,
                        policy: Optional[Any] = None
                        ) -> Callable[[int], Optional[str]]:
    """Build the ``proactive=`` hook ``run_bsp``/``run_elastic`` call
    once per superstep: reads ``source()`` (host -> risk, e.g.
    ``engine.risk_scores`` or ``collector.risk_scores``), feeds the max
    into ``policy.observe_risk`` (if a risk-adjusted policy is given),
    and returns a reason string — forcing a checkpoint — when any
    host's risk crosses ``threshold``.  ``cooldown_steps`` rate-limits
    forced saves so a persistently risky host doesn't checkpoint every
    step."""
    last_fired = [-10**9]

    def hook(step: int) -> Optional[str]:
        scores = source()
        if policy is not None:
            policy.observe_risk(max(scores.values(), default=0.0))
        if step - last_fired[0] < cooldown_steps:
            return None
        hot = [(r, h) for h, r in scores.items() if r >= threshold]
        if not hot:
            return None
        r, h = max(hot)
        last_fired[0] = step
        return f"risk:{h}:{r:.2f}"

    return hook
