"""Per-incident failure timelines assembled from the event bus
(docs/observability.md).

An *incident* opens at a detection event (heartbeat declared a host dead,
the serving router failed a replica, an SDC tier tripped), collects every
repair-phase event that follows (final-save flush, drain/requeue,
restore, mesh shrink/grow, standby activation), and closes at the resume
event — training re-entered on the new mesh, recovery resumed the loop,
or a drained request's retry produced its first client-visible token.
Detections arriving while an incident is open *merge into it*: a rack
loss during an SDC storm is one compound incident, not three.

From the closed incidents the timeline derives the classic dependability
numbers:

- **MTTR**: mean detect -> resume duration.
- **MTBF**: mean gap between successive incident *starts* (>= 2 needed).
- **availability**: 1 - (repair time / observed span).

These are the measured counterparts of the ``SystemModel`` estimates the
Young/Daly policy is configured with — ``CheckpointPolicy
.observe_recovery`` lets the measured values displace the configured
ones live.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.bus import Event

#: (subsystem, kind) pairs that OPEN (or merge into) an incident
DETECT_KINDS = {
    ("heartbeat", "failure"),
    ("serve", "replica_failed"),
    ("sdc", "corruption"),
}

#: pairs that CLOSE the open incident (service restored)
RESUME_KINDS = {
    ("elastic", "resume"),
    ("train", "resume"),
    ("serve", "retry_first_token"),
}

#: pairs recorded as repair phases while an incident is open
PHASE_KINDS = {
    ("checkpoint", "save"),
    ("checkpoint", "restore"),
    ("elastic", "shrink"),
    ("elastic", "grow"),
    ("serve", "standby_activated"),
    ("heartbeat", "rejoin"),
    ("train", "interrupted"),
}


@dataclasses.dataclass
class Incident:
    """One detect -> ... -> resume episode."""
    t_detect: float                    # t_mono of the first detection
    cause: str                         # "subsystem.kind" of that detection
    detections: List[Event] = dataclasses.field(default_factory=list)
    phases: List[Event] = dataclasses.field(default_factory=list)
    t_resume: Optional[float] = None   # t_mono of the closing event
    resume_kind: Optional[str] = None

    @property
    def closed(self) -> bool:
        return self.t_resume is not None

    @property
    def duration(self) -> Optional[float]:
        """Repair time in seconds (None while open)."""
        if self.t_resume is None:
            return None
        return self.t_resume - self.t_detect

    def phase_offsets_ms(self) -> List[Tuple[float, str]]:
        """[(ms after detection, "subsystem.kind"), ...] — the repair
        critical path, human- and trace-readable."""
        out = []
        for ev in self.detections[1:] + self.phases:
            out.append(((ev.t_mono - self.t_detect) * 1e3,
                        f"{ev.subsystem}.{ev.kind}"))
        if self.t_resume is not None:
            out.append(((self.t_resume - self.t_detect) * 1e3,
                        f"resume:{self.resume_kind}"))
        return sorted(out)

    def to_dict(self) -> Dict:
        return {"t_detect": self.t_detect, "cause": self.cause,
                "detections": len(self.detections),
                "phases": [k for _, k in self.phase_offsets_ms()],
                "duration_s": self.duration,
                "resume": self.resume_kind}


class Timeline:
    """Incident list + derived MTTR / MTBF / availability."""

    def __init__(self, incidents: List[Incident],
                 span_seconds: float = 0.0,
                 t_end: Optional[float] = None):
        self.incidents = incidents
        self.span_seconds = span_seconds
        self.t_end = t_end                 # t_mono of the last event seen

    @classmethod
    def from_events(cls, events: Sequence[Event]) -> "Timeline":
        events = sorted(events, key=lambda e: (e.t_mono, e.seq))
        incidents: List[Incident] = []
        open_inc: Optional[Incident] = None
        for ev in events:
            key = (ev.subsystem, ev.kind)
            if key in DETECT_KINDS:
                if open_inc is None:
                    open_inc = Incident(t_detect=ev.t_mono,
                                        cause=f"{ev.subsystem}.{ev.kind}")
                    incidents.append(open_inc)
                open_inc.detections.append(ev)
            elif open_inc is not None and key in RESUME_KINDS:
                open_inc.t_resume = ev.t_mono
                open_inc.resume_kind = f"{ev.subsystem}.{ev.kind}"
                open_inc = None
            elif open_inc is not None and key in PHASE_KINDS:
                open_inc.phases.append(ev)
        span = (events[-1].t_mono - events[0].t_mono) if events else 0.0
        t_end = events[-1].t_mono if events else None
        return cls(incidents, span_seconds=span, t_end=t_end)

    # ------------------------------------------------------------------
    # derived dependability numbers
    # ------------------------------------------------------------------
    @property
    def closed(self) -> List[Incident]:
        return [i for i in self.incidents if i.closed]

    def mttr(self) -> Optional[float]:
        """Mean time to repair (seconds) over closed incidents."""
        ds = [i.duration for i in self.closed]
        return sum(ds) / len(ds) if ds else None

    def mtbf(self) -> Optional[float]:
        """Mean gap (seconds) between successive incident starts."""
        starts = sorted(i.t_detect for i in self.incidents)
        if len(starts) < 2:
            return None
        gaps = [b - a for a, b in zip(starts, starts[1:])]
        return sum(gaps) / len(gaps)

    def downtime(self) -> float:
        """Total repair seconds (open incidents count as down from their
        detection to the end of the log)."""
        total = 0.0
        for i in self.incidents:
            if i.closed:
                total += i.duration
            elif self.t_end is not None:
                total += max(0.0, self.t_end - i.t_detect)
        return total

    def availability(self) -> float:
        """1 - downtime/span over the observed window (1.0 for an empty
        or incident-free log)."""
        if self.span_seconds <= 0:
            return 1.0
        return max(0.0, 1.0 - self.downtime() / self.span_seconds)

    def summary(self) -> Dict:
        return {
            "incidents": len(self.incidents),
            "closed": len(self.closed),
            "mttr_s": self.mttr(),
            "mtbf_s": self.mtbf(),
            "availability": self.availability(),
            "span_s": self.span_seconds,
            "causes": sorted({i.cause for i in self.incidents}),
        }
