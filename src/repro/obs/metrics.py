"""Counters / gauges / histograms with a registry, Prometheus-style text
export, and a JSON snapshot (docs/observability.md).

Everything is in-process and lock-cheap: one registry lock guards
instrument *creation*; each instrument guards its own updates.  The
histogram keeps a bounded window of recent observations (plus running
count/sum/min/max over the full stream), and its ``percentile`` follows
numpy's default linear-interpolation convention exactly — the test suite
holds it to ``np.percentile`` as the oracle.

``Span`` is the timing primitive: a context manager that observes its
elapsed milliseconds into a histogram on exit.  The dependability layers
use spans to *measure* the Young/Daly terms (checkpoint cost C, restore
cost R, detection downtime D) instead of trusting configured estimates —
``CheckpointPolicy.observe_recovery`` consumes them.
"""
from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple


def _label_key(name: str, labels: Dict[str, Any]) -> Tuple:
    return (name,) + tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape_label(v: Any) -> str:
    """Prometheus label-value escaping: backslash, newline, quote."""
    return (str(v).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _label_str(labels: Dict[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotonically increasing count (events, tokens, bytes...)."""

    def __init__(self, name: str, labels: Optional[Dict] = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value (queue depth, alive hosts, dp width...)."""

    def __init__(self, name: str, labels: Optional[Dict] = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Distribution over a bounded window of recent observations.

    count/sum/min/max run over the whole stream; percentiles run over the
    newest ``window`` samples (steady-state tail behaviour, bounded
    memory — the same discipline as ``StragglerWatchdog.durations``).
    """

    def __init__(self, name: str, labels: Optional[Dict] = None,
                 window: int = 2048):
        self.name = name
        self.labels = dict(labels or {})
        self.window = window
        self._lock = threading.Lock()
        self._samples: deque = deque(maxlen=window)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._samples.append(v)
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100], numpy's default linear interpolation: the rank
        is ``q/100 * (n-1)`` and fractional ranks interpolate between the
        two nearest order statistics (oracle: ``np.percentile``)."""
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"q must be in [0, 100], got {q}")
        with self._lock:
            xs = sorted(self._samples)
        if not xs:
            return 0.0
        rank = (q / 100.0) * (len(xs) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] + frac * (xs[hi] - xs[lo])

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            count, total = self._count, self._sum
            mn = self._min if self._count else 0.0
            mx = self._max if self._count else 0.0
        return {"count": count, "sum": total, "min": mn, "max": mx,
                "mean": (total / count if count else 0.0),
                "p50": self.percentile(50.0), "p99": self.percentile(99.0)}


class Span:
    """``with registry.span("checkpoint.critical_path_ms"): ...`` —
    observes elapsed milliseconds into the named histogram on exit.
    ``seconds`` holds the raw duration afterwards (the policy feedback
    path wants seconds, not ms)."""

    def __init__(self, hist: Histogram):
        self.hist = hist
        self.seconds: Optional[float] = None
        self._t0: Optional[float] = None

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self._t0
        self.hist.observe(self.seconds * 1e3)


class MetricsRegistry:
    """name (+ labels) -> instrument.  Asking twice returns the same
    instrument; asking with a different type for an existing name raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple, Any] = {}

    def _get(self, cls, name: str, labels: Dict, **kw):
        key = _label_key(name, labels)
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, labels, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r}{_label_str(labels)} already "
                    f"registered as {type(inst).__name__}, not "
                    f"{cls.__name__}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, window: int = 2048,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, window=window)

    def span(self, name: str, **labels) -> Span:
        return Span(self.histogram(name, **labels))

    def instruments(self) -> List[Any]:
        with self._lock:
            return list(self._instruments.values())

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready dict: metric name (+labels) -> value / histogram
        summary."""
        out: Dict[str, Any] = {}
        for inst in self.instruments():
            key = inst.name + _label_str(inst.labels)
            if isinstance(inst, (Counter, Gauge)):
                out[key] = inst.value
            else:
                out[key] = inst.snapshot()
        return dict(sorted(out.items()))

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.snapshot(), indent=2, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    def to_prometheus(self, quantiles: Tuple[float, ...] = (0.5, 0.99)
                      ) -> str:
        """Prometheus text exposition (untyped beyond counter/gauge;
        histograms export _count/_sum plus quantile gauges — precomputed
        client-side quantiles, the summary-metric idiom).  ``quantiles``
        are fractions in [0, 1]; the default (0.5, 0.99) keeps the
        long-standing p50/p99 output byte-identical."""
        for q in quantiles:
            if not 0.0 <= q <= 1.0:
                raise ValueError(f"quantile must be in [0, 1], got {q}")
        lines: List[str] = []
        seen_types: Dict[str, str] = {}
        for inst in sorted(self.instruments(), key=lambda i: i.name):
            base = inst.name.replace(".", "_").replace("-", "_")
            ls = _label_str(inst.labels)
            if isinstance(inst, Counter):
                if seen_types.setdefault(base, "counter") == "counter":
                    if f"# TYPE {base} counter" not in lines:
                        lines.append(f"# TYPE {base} counter")
                lines.append(f"{base}{ls} {inst.value:g}")
            elif isinstance(inst, Gauge):
                if f"# TYPE {base} gauge" not in lines:
                    lines.append(f"# TYPE {base} gauge")
                lines.append(f"{base}{ls} {inst.value:g}")
            else:
                snap = inst.snapshot()
                if f"# TYPE {base} summary" not in lines:
                    lines.append(f"# TYPE {base} summary")
                for q in quantiles:
                    qls = dict(inst.labels, quantile=f"{q:g}")
                    lines.append(
                        f"{base}{_label_str(qls)} "
                        f"{inst.percentile(q * 100.0):g}")
                lines.append(f"{base}_count{ls} {snap['count']:g}")
                lines.append(f"{base}_sum{ls} {snap['sum']:g}")
        return "\n".join(lines) + ("\n" if lines else "")
