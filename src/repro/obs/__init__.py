"""Dependability telemetry: structured events, live metrics, failure
timelines, record-and-replay (docs/observability.md).

``Observability`` bundles the event bus and the metrics registry behind
one handle that every layer shares::

    obs = Observability(jsonl_path="telemetry/events.jsonl")
    dep.attach_obs(obs)              # training plane
    engine = ServeEngine(..., obs=obs)   # serving plane

    obs.emit("heartbeat", "failure", host=3)
    obs.registry.counter("sdc.detected", tier="abft").inc()

    obs.timeline().summary()         # {"mttr_s": ..., "availability": ...}
    obs.to_scenario()                # recorded log -> replayable Scenario
    obs.dump("out/telemetry")        # events.jsonl + trace.json +
                                     # metrics.json + metrics.prom
"""
from __future__ import annotations

import os
from typing import Any, List, Optional

from repro.obs.bus import DEFAULT_CAPACITY, Event, EventBus, load_jsonl
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               Span)
from repro.obs.timeline import Incident, Timeline
from repro.obs.export import (to_chrome_trace, to_scenario,
                              write_chrome_trace)
from repro.obs.anomaly import (AnomalyEngine, BeatJitterDetector,
                               ScrubRateDetector, StepTimeDriftDetector,
                               make_proactive_hook)
from repro.obs.agent import TelemetryAgent
from repro.obs.collector import Collector

__all__ = [
    "Observability", "EventBus", "Event", "DEFAULT_CAPACITY",
    "load_jsonl", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "Span", "Timeline", "Incident", "to_chrome_trace",
    "write_chrome_trace", "to_scenario", "AnomalyEngine",
    "BeatJitterDetector", "ScrubRateDetector", "StepTimeDriftDetector",
    "make_proactive_hook", "TelemetryAgent", "Collector",
]


class Observability:
    """Event bus + metrics registry, one per deployment (process)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 jsonl_path: Optional[str] = None):
        self.bus = EventBus(capacity=capacity)
        self.registry = MetricsRegistry()
        if jsonl_path is not None:
            self.bus.attach_jsonl(jsonl_path)

    # -- producing -----------------------------------------------------
    def emit(self, subsystem: str, kind: str, **data: Any) -> Event:
        return self.bus.emit(subsystem, kind, **data)

    # -- derived views -------------------------------------------------
    def events(self, subsystem: Optional[str] = None,
               kind: Optional[str] = None) -> List[Event]:
        return self.bus.events(subsystem=subsystem, kind=kind)

    def timeline(self) -> Timeline:
        return Timeline.from_events(self.bus.events())

    def to_scenario(self, name: Optional[str] = None):
        return to_scenario(self.bus.events(), name=name)

    def snapshot(self) -> dict:
        """Metrics + timeline summary, JSON-ready."""
        return {"metrics": self.registry.snapshot(),
                "timeline": self.timeline().summary(),
                "events": {"retained": len(self.bus),
                           "emitted": self.bus.total_emitted,
                           "dropped": self.bus.dropped}}

    # -- persistence ---------------------------------------------------
    def dump(self, out_dir: str) -> dict:
        """Write the full telemetry bundle under ``out_dir``; returns the
        path map.  If no JSONL sink was attached, the retained ring is
        written out instead (bounded history)."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {}
        evs = self.bus.events()
        if self.bus._jsonl_path is None:
            jsonl = os.path.join(out_dir, "events.jsonl")
            self.bus.attach_jsonl(jsonl)
            # back-fill the retained ring into the fresh sink
            import json as _json
            with self.bus._lock:
                sink = self.bus._jsonl
            for ev in evs:
                sink.write(_json.dumps(ev.to_dict()) + "\n")
            paths["events"] = jsonl
        else:
            paths["events"] = self.bus._jsonl_path
        self.bus.flush()
        paths["trace"] = write_chrome_trace(
            os.path.join(out_dir, "trace.json"), evs, self.timeline())
        paths["metrics_json"] = os.path.join(out_dir, "metrics.json")
        self.registry.to_json(paths["metrics_json"])
        paths["metrics_prom"] = os.path.join(out_dir, "metrics.prom")
        with open(paths["metrics_prom"], "w") as f:
            f.write(self.registry.to_prometheus())
        return paths

    def close(self) -> None:
        self.bus.close()
