"""Collector: the global half of the telemetry plane
(docs/observability.md, "Telemetry plane").

One collector per deployment receives the
:class:`~repro.obs.agent.TelemetryAgent` datagrams from every host and
merges them into a single, globally ordered event stream a
:class:`~repro.obs.timeline.Timeline` can fold — without ever comparing
one host's clock to another's:

* **(inc, seq) acceptance** — per host, a datagram is accepted iff its
  ``(inc, seq)`` exceeds the last accepted pair (heartbeat idiom: a
  restarted agent's fresh ``inc`` supersedes; duplicates and stale
  reordered datagrams are counted as ``stale`` and dropped).
* **skew-tolerant merge** — per host the collector maintains
  ``offset = min over datagrams of (t_recv - t_send)``: the minimum
  observed one-way delay, in collector-clock terms, including any agent
  clock skew.  Merged events get ``t_mono = host t_mono + offset``.
  Same-host differences are preserved *exactly* (one constant per
  host), so MTTR/MTBF math over the merged stream matches the
  single-host oracle; cross-host ordering is correct to within the
  (small, bounded) one-way-delay estimation error.
* **gap accounting** — a seq jump means lost datagrams; the collector
  counts the missing span per host and synthesizes a ``telemetry/gap``
  event into the merged stream, so downstream consumers *see* the hole
  instead of silently reading a thinner stream.

Every merged event is tagged ``origin=<host>`` (unless the payload
already names a host).  The optional
:class:`~repro.obs.anomaly.AnomalyEngine` rides the receive path:
datagram arrivals feed the jitter detector, merged events feed the
drift/scrub detectors, and emitted ``precursor/*`` events land in the
same merged stream — making the collector the risk source for
proactive checkpointing and serve pre-drains.
"""
from __future__ import annotations

import json
import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from .bus import Event
from .timeline import Timeline

__all__ = ["Collector"]

#: merged-stream bound — same discipline as the EventBus ring
DEFAULT_CAPACITY = 50_000


class _HostState:
    __slots__ = ("inc", "last_seq", "offset", "datagrams", "missed",
                 "stale")

    def __init__(self) -> None:
        self.inc = 0.0
        self.last_seq = -1
        self.offset: Optional[float] = None
        self.datagrams = 0
        self.missed = 0                  # datagrams lost to seq gaps
        self.stale = 0                   # duplicates / reordered stragglers


class Collector:
    def __init__(self, bind: Tuple[str, int] = ("127.0.0.1", 0),
                 anomaly: Optional[Any] = None,
                 capacity: int = DEFAULT_CAPACITY):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self._sock.bind(bind)
        self._sock.settimeout(0.1)
        self.addr = self._sock.getsockname()
        self.anomaly = anomaly
        if anomaly is not None and anomaly.emit is None:
            anomaly.emit = self._emit_merged
        self.capacity = capacity
        #: (host clock-domain or None for collector-clock, local t_mono,
        #: event) — the offset is applied at *snapshot* time, so every
        #: event from a host always maps through that host's latest
        #: (best) offset estimate and same-host differences stay exact
        self._events: List[Tuple[Optional[int], float, Event]] = []
        self._seq = 0                    # collector-local merge order tag
        self._hosts: Dict[int, _HostState] = {}
        self._counters: Dict[int, Dict[str, float]] = {}
        self._gauges: Dict[int, Dict[str, float]] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- ingest (the whole merge protocol; directly callable) ----------
    def ingest(self, payload: Dict[str, Any],
               t_recv: Optional[float] = None) -> bool:
        """Merge one agent datagram; returns False if it was stale.
        ``t_recv`` defaults to now (collector clock) — tests and the
        throughput bench pass explicit values."""
        if t_recv is None:
            t_recv = time.perf_counter()
        host = int(payload["host"])
        inc = float(payload["inc"])
        seq = int(payload["seq"])
        merged: List[Event] = []
        with self._lock:
            st = self._hosts.setdefault(host, _HostState())
            if inc > st.inc:             # restarted agent supersedes
                st.inc, st.last_seq, st.offset = inc, -1, None
            elif inc < st.inc or seq <= st.last_seq:
                st.stale += 1
                return False
            if seq > st.last_seq + 1:    # lost datagrams: account + mark
                n = seq - st.last_seq - 1
                st.missed += n
                merged.append(self._make_event(
                    t_recv, "telemetry", "gap",
                    {"origin": host, "missed_datagrams": n,
                     "after_seq": st.last_seq}))
            st.last_seq = seq
            st.datagrams += 1
            # min one-way delay = the host->collector clock mapping
            delay = t_recv - float(payload["t_send"])
            st.offset = delay if st.offset is None else min(st.offset,
                                                            delay)
            for d in payload.get("events", ()):
                ev = Event.from_dict(d)
                data = dict(ev.data)
                data.setdefault("origin", host)
                merged.append(self._stamp(Event(
                    seq=0, t_mono=ev.t_mono, t_wall=ev.t_wall,
                    subsystem=ev.subsystem, kind=ev.kind, data=data),
                    domain=host))
            for k, v in payload.get("counters", {}).items():
                c = self._counters.setdefault(host, {})
                c[k] = c.get(k, 0.0) + float(v)
            if payload.get("gauges"):
                self._gauges.setdefault(host, {}).update(
                    payload["gauges"])
        # detectors run OUTSIDE the lock: they may emit back into us
        if self.anomaly is not None:
            self.anomaly.observe_arrival(host, t_recv)
            for ev in merged:
                self.anomaly.observe_event(host, ev)
        return True

    def _make_event(self, t_mono: float, subsystem: str, kind: str,
                    data: Dict[str, Any]) -> Event:
        return self._stamp(Event(seq=0, t_mono=t_mono,
                                 t_wall=time.time(),
                                 subsystem=subsystem, kind=kind,
                                 data=data))

    def _stamp(self, ev: Event, domain: Optional[int] = None) -> Event:
        """Append under the lock (caller holds it), tagging a collector-
        local seq so equal-t_mono events keep arrival order.  ``domain``
        names the host clock domain ``t_mono`` lives in (None =
        collector clock)."""
        ev = Event(seq=self._seq, t_mono=ev.t_mono, t_wall=ev.t_wall,
                   subsystem=ev.subsystem, kind=ev.kind, data=ev.data)
        self._seq += 1
        self._events.append((domain, ev.t_mono, ev))
        if len(self._events) > self.capacity:
            del self._events[:len(self._events) - self.capacity]
        return ev

    def _emit_merged(self, subsystem: str, kind: str,
                     **data: Any) -> Event:
        """AnomalyEngine's emit target: precursors join the merged
        stream, stamped with the collector's own clock."""
        with self._lock:
            return self._make_event(time.perf_counter(), subsystem,
                                    kind, data)

    # -- merged-stream output ------------------------------------------
    def events(self, subsystem: Optional[str] = None,
               kind: Optional[str] = None) -> List[Event]:
        """Snapshot of the merged stream in global (t_mono, seq) order,
        every host-domain timestamp mapped through that host's current
        offset estimate."""
        with self._lock:
            offs = {h: (st.offset or 0.0)
                    for h, st in self._hosts.items()}
            evs = [Event(seq=ev.seq,
                         t_mono=t + (offs.get(dom, 0.0)
                                     if dom is not None else 0.0),
                         t_wall=ev.t_wall, subsystem=ev.subsystem,
                         kind=ev.kind, data=ev.data)
                   for dom, t, ev in self._events]
        evs.sort(key=lambda e: (e.t_mono, e.seq))
        return [e for e in evs
                if (subsystem is None or e.subsystem == subsystem)
                and (kind is None or e.kind == kind)]

    def timeline(self) -> Timeline:
        return Timeline.from_events(self.events())

    def gap_report(self) -> Dict[int, Dict[str, int]]:
        """Per-host wire accounting: datagrams merged, datagrams lost
        (seq gaps), stale drops."""
        with self._lock:
            return {h: {"datagrams": st.datagrams, "missed": st.missed,
                        "stale": st.stale}
                    for h, st in sorted(self._hosts.items())}

    def host_metrics(self) -> Dict[int, Dict[str, Dict[str, float]]]:
        """Per-host merged metrics: accumulated counter deltas and
        last-seen gauges."""
        with self._lock:
            return {h: {"counters": dict(self._counters.get(h, {})),
                        "gauges": dict(self._gauges.get(h, {}))}
                    for h in sorted(set(self._counters)
                                    | set(self._gauges))}

    # -- risk passthrough (the proactive hooks' source) ----------------
    def risk_scores(self) -> Dict[int, float]:
        return (self.anomaly.risk_scores() if self.anomaly is not None
                else {})

    def risk(self, host: int) -> float:
        return (self.anomaly.risk(host) if self.anomaly is not None
                else 0.0)

    # -- lifecycle -----------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                data, _ = self._sock.recvfrom(65536)
            except socket.timeout:
                continue
            except OSError:
                break
            try:
                payload = json.loads(data.decode())
            except (ValueError, UnicodeDecodeError):
                continue                 # garbage datagram: drop
            try:
                self.ingest(payload)
            except (KeyError, TypeError, ValueError):
                continue                 # malformed payload: drop

    def start(self) -> "Collector":
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="telemetry-collector")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        self._sock.close()
