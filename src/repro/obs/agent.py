"""TelemetryAgent: the per-host half of the telemetry plane
(docs/observability.md, "Telemetry plane").

Each host runs one agent.  It subscribes to the host's local
:class:`~repro.obs.bus.EventBus`, buffers event records, and ships them
— together with metric *deltas* from the host's
:class:`~repro.obs.metrics.MetricsRegistry` — as UDP datagrams to the
:class:`~repro.obs.collector.Collector`.

The wire discipline is the heartbeat emitter's, applied to bulk data:

* **(inc, seq) ordering** — ``inc`` is stamped once per agent lifetime
  (``time.time()``), ``seq`` increments per datagram.  The collector
  orders pairs *per host* and never compares clocks across hosts; a
  restarted agent (new ``inc``) supersedes its past self exactly like a
  restarted heartbeat emitter does.
* **loss-tolerant** — fire-and-forget UDP; a seq gap at the collector
  becomes per-host gap accounting (a ``telemetry/gap`` event), never a
  stall.  The agent keeps a bounded buffer and counts what it sheds.
* **no cross-host clock comparison** — each datagram carries the
  host-local ``t_send`` (``perf_counter``); the collector maps it into
  its own clock domain with a per-host offset (min one-way delay), so
  same-host time *differences* — the inputs to MTTR/MTBF math — survive
  the merge exactly.

``skew_seconds`` offsets every timestamp the agent puts on the wire
(event ``t_mono`` and ``t_send`` alike), simulating a host whose
monotonic clock domain disagrees with the collector's — the skew the
offset mapping must cancel.  Tests and the chaos engine use it; real
deployments leave it 0.

Metric shipping is delta-based for counters (the collector accumulates,
so a lost datagram loses a delta — bounded error, no double count) and
last-value for gauges.
"""
from __future__ import annotations

import json
import socket
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from .bus import Event, EventBus
from .metrics import Counter, Gauge, MetricsRegistry, _label_str

__all__ = ["TelemetryAgent"]

#: events buffered while waiting for the next ship (bounded: the agent
#: sheds oldest-first under backpressure and counts what it dropped)
BUFFER_CAP = 4096

#: max event records per datagram — keeps each JSON payload well under
#: typical UDP limits
CHUNK = 100


class TelemetryAgent:
    """Ships one host's telemetry to the collector.

    ``send_filter(host_id, payload) -> bool`` gates every datagram the
    same way the heartbeat emitter's does — the chaos engine's
    partition hook drops telemetry and heartbeats with one knob."""

    def __init__(self, host_id: int, collector_addr: Tuple[str, int],
                 bus: EventBus,
                 registry: Optional[MetricsRegistry] = None,
                 period: float = 0.05, chunk: int = CHUNK,
                 buffer_cap: int = BUFFER_CAP,
                 skew_seconds: float = 0.0,
                 send_filter: Optional[Callable[[int, Dict], bool]]
                 = None):
        self.host_id = host_id
        self.collector_addr = collector_addr
        self.bus = bus
        self.registry = registry
        self.period = period
        self.chunk = chunk
        self.skew_seconds = skew_seconds
        self.send_filter = send_filter
        self._inc = time.time()          # lifetime tag (heartbeat idiom)
        self._seq = 0
        self._buf: deque = deque(maxlen=buffer_cap)
        self.shed = 0                    # events dropped to the buffer cap
        self.sent_datagrams = 0
        self._counters_last: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._flush_lock = threading.Lock()   # serializes whole flushes
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._sub: Optional[Callable] = None

    # -- event intake (bus subscriber, runs on emitting threads) -------
    def _on_event(self, ev: Event) -> None:
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.shed += 1
            d = ev.to_dict()
            d["t_mono"] = ev.t_mono + self.skew_seconds
            self._buf.append(d)

    # -- shipping ------------------------------------------------------
    def _metric_payload(self) -> Tuple[Dict[str, float], Dict[str, float]]:
        """(counter deltas since last ship, gauge last-values)."""
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        if self.registry is None:
            return counters, gauges
        for inst in self.registry.instruments():
            key = inst.name + _label_str(inst.labels)
            if isinstance(inst, Counter):
                v = inst.value
                delta = v - self._counters_last.get(key, 0.0)
                if delta:
                    counters[key] = delta
                self._counters_last[key] = v
            elif isinstance(inst, Gauge):
                gauges[key] = inst.value
        return counters, gauges

    def flush(self) -> int:
        """Ship everything buffered now (plus one metrics snapshot);
        returns the number of datagrams sent.  Called by the background
        thread each period and directly by tests/shutdown."""
        with self._flush_lock:
            return self._flush_locked()

    def _flush_locked(self) -> int:
        with self._lock:
            events: List[Dict[str, Any]] = list(self._buf)
            self._buf.clear()
            counters, gauges = self._metric_payload()
        sent = 0
        chunks: List[List[Dict[str, Any]]] = [
            events[i:i + self.chunk]
            for i in range(0, len(events), self.chunk)] or [[]]
        if not counters and not gauges and not events:
            return 0                     # nothing to say: stay silent
        for i, part in enumerate(chunks):
            payload = {"host": self.host_id, "inc": self._inc,
                       "seq": self._seq,
                       "t_send": time.perf_counter() + self.skew_seconds,
                       "events": part}
            if i == 0:                   # metrics ride the first chunk
                payload["counters"] = counters
                payload["gauges"] = gauges
            self._seq += 1
            if (self.send_filter is not None
                    and not self.send_filter(self.host_id, payload)):
                continue                 # chaos-dropped: seq gap downstream
            try:
                self._sock.sendto(json.dumps(payload).encode(),
                                  self.collector_addr)
                sent += 1
            except OSError:
                pass                     # fire-and-forget: loss-tolerant
        self.sent_datagrams += sent
        return sent

    def _run(self) -> None:
        while not self._stop.wait(self.period):
            self.flush()

    # -- lifecycle -----------------------------------------------------
    def start(self) -> "TelemetryAgent":
        self._sub = self.bus.subscribe(self._on_event)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"telemetry-agent-"
                                             f"{self.host_id}")
        self._thread.start()
        return self

    def stop(self, final_flush: bool = True) -> None:
        if self._sub is not None:
            self.bus.unsubscribe(self._sub)
            self._sub = None
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        if final_flush:
            self.flush()
        self._sock.close()
