"""Exporters for recorded event logs (docs/observability.md).

Two consumers of a recorded bus:

- ``to_chrome_trace`` / ``write_chrome_trace`` — Chrome ``about:tracing``
  / Perfetto JSON.  Each subsystem becomes a named track of instant
  events; closed incidents from the ``Timeline`` become duration bars on
  an "incidents" track, so a compound failure reads as one shaded span
  with the detect/drain/restore/resume marks inside it.

- ``to_scenario`` — convert a recorded event log back into a replayable
  chaos ``Scenario``, closing the record-and-replay loop the ROADMAP
  asks for.  Two paths:

  1. **Declarative** (exact): the chaos drivers emit one
     ``chaos/<kind>`` event per compiled scenario event, carrying the
     original ``at``/``until``/args, plus a ``chaos/scenario`` meta
     event with name/clock/seed.  Reconstruction is lossless — the
     round-trip scenario replays bit-identically (same seed, same
     storm draws).

  2. **Derived** (production logs): with no declarative events the
     converter falls back to the raw detection stream — heartbeat
     failures/rejoins and serve replica failures become
     kill/rejoin events, injected bit-flips become an ``sdc_storm``
     window — on a ``clock="time"`` axis relative to the first event.
     That is the "replay recorded production failure logs" path: the
     reconstructed scenario drives ``ControlPlaneSim`` or a fresh
     elastic run even though no scenario ever existed.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

from repro.chaos.scenario import KINDS, WINDOW_KINDS, Scenario
from repro.obs.bus import Event
from repro.obs.timeline import Timeline

# ----------------------------------------------------------------------
# Chrome trace (catapult JSON) export
# ----------------------------------------------------------------------
_PID = 1
_INCIDENT_TID = 0


def to_chrome_trace(events: Sequence[Event],
                    timeline: Optional[Timeline] = None) -> Dict[str, Any]:
    """Build a ``chrome://tracing`` / Perfetto-loadable trace dict.

    Timestamps are microseconds relative to the first event; one thread
    track per subsystem; incidents (if a timeline is given, else built
    here) render as duration ("X") bars on track 0.
    """
    events = sorted(events, key=lambda e: (e.t_mono, e.seq))
    if timeline is None:
        timeline = Timeline.from_events(events)
    t0 = events[0].t_mono if events else 0.0
    tids: Dict[str, int] = {}
    trace: List[Dict[str, Any]] = [
        {"name": "thread_name", "ph": "M", "pid": _PID,
         "tid": _INCIDENT_TID, "args": {"name": "incidents"}},
    ]
    for ev in events:
        tid = tids.setdefault(ev.subsystem, len(tids) + 1)
        trace.append({
            "name": f"{ev.subsystem}.{ev.kind}",
            "ph": "i", "s": "t",                 # thread-scoped instant
            "ts": (ev.t_mono - t0) * 1e6,
            "pid": _PID, "tid": tid,
            "args": dict(ev.data),
        })
    for sub, tid in tids.items():
        trace.append({"name": "thread_name", "ph": "M", "pid": _PID,
                      "tid": tid, "args": {"name": sub}})
    for inc in timeline.incidents:
        end = inc.t_resume if inc.closed else timeline.t_end
        if end is None:
            continue
        trace.append({
            "name": f"incident:{inc.cause}",
            "ph": "X",
            "ts": (inc.t_detect - t0) * 1e6,
            "dur": max(0.0, (end - inc.t_detect)) * 1e6,
            "pid": _PID, "tid": _INCIDENT_TID,
            "args": {"closed": inc.closed, "resume": inc.resume_kind,
                     "detections": len(inc.detections)},
        })
    return {"traceEvents": trace, "displayTimeUnit": "ms",
            "otherData": {"summary": timeline.summary()}}


def write_chrome_trace(path: str, events: Sequence[Event],
                       timeline: Optional[Timeline] = None) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events, timeline), f, indent=2)
        f.write("\n")
    return path


# ----------------------------------------------------------------------
# event log -> Scenario (replay side of record-and-replay)
# ----------------------------------------------------------------------
def to_scenario(events: Sequence[Event],
                name: Optional[str] = None) -> Scenario:
    """Convert a recorded event stream back into a chaos ``Scenario``.

    Prefers the declarative ``chaos/*`` events the drivers emit at
    scenario compile time (lossless round trip, including the storm
    seed); falls back to deriving a fail-stop/SDC timeline from the raw
    detection events when the log came from an uninstrumented-by-chaos
    run (a "production" log).  The result is validated — it replays
    through ``run_scenario_elastic`` or ``ControlPlaneSim`` directly.
    """
    events = sorted(events, key=lambda e: (e.t_mono, e.seq))
    chaos_evs = [e for e in events if e.subsystem == "chaos"]
    declarative = [e for e in chaos_evs if e.kind in KINDS]
    if declarative:
        return _from_declarative(chaos_evs, declarative, name)
    return _from_detections(events, name)


def _from_declarative(chaos_evs: Sequence[Event],
                      declarative: Sequence[Event],
                      name: Optional[str]) -> Scenario:
    meta: Dict[str, Any] = {}
    for e in chaos_evs:
        if e.kind == "scenario":
            meta = dict(e.data)
            break
    ev_dicts: List[Dict[str, Any]] = []
    for e in declarative:
        d = dict(e.data)
        d.pop("plane", None)                 # driver tag, not a field
        at = d.pop("at")
        until = d.pop("until", None)
        d["kind"] = e.kind
        if e.kind in WINDOW_KINDS and until is not None:
            d["window"] = [at, until]
        else:
            d["at"] = at
        ev_dicts.append(d)
    return Scenario.from_dict({
        "name": name or meta.get("name", "replay"),
        "clock": meta.get("clock", "step"),
        "seed": meta.get("seed", 0),
        "events": ev_dicts,
    })


def _host_of(ev: Event) -> Optional[int]:
    for key in ("host", "replica", "rid"):
        if key in ev.data:
            try:
                return int(ev.data[key])
            except (TypeError, ValueError):
                return None
    return None


def _from_detections(events: Sequence[Event],
                     name: Optional[str]) -> Scenario:
    """Derive a time-clock scenario from raw detection events."""
    t0 = events[0].t_mono if events else 0.0
    sc = Scenario(name or "derived-replay", clock="time")
    dead: set = set()
    flips: List[Event] = []
    for ev in events:
        rel = round(ev.t_mono - t0, 6)
        key = (ev.subsystem, ev.kind)
        host = _host_of(ev)
        if key in (("heartbeat", "failure"), ("serve", "replica_failed")):
            if host is not None and host not in dead:
                sc.kill_hosts([host], at=rel)
                dead.add(host)
        elif key == ("heartbeat", "rejoin"):
            if host is not None and host in dead:
                sc.rejoin(host, at=rel)
                dead.discard(host)
        elif ev.subsystem == "injector" and ev.kind == "bitflip":
            flips.append(ev)
    if flips:
        start = round(flips[0].t_mono - t0, 6)
        end = round(flips[-1].t_mono - t0, 6)
        width = max(end - start, 1e-3)
        if end <= start:
            end = start + width
        rate = min(1.0, max(1e-6, len(flips) / width))
        leaves = sorted({e.data["leaf"] for e in flips if "leaf" in e.data})
        sc.sdc_storm(rate=rate, window=(start, end),
                     leaves=leaves or None)
    return sc.validate()
