"""Structured event bus: the one stream every dependability layer emits
into (docs/observability.md).

``EventBus`` is a thread-safe bounded ring buffer of ``Event`` records.
Producers — the heartbeat monitor, ``CheckpointManager`` saves/restores,
the SDC tiers, the elastic loop, the serving engine, and the chaos
drivers — call ``emit(subsystem, kind, **data)``; the bus stamps both a
monotonic timestamp (``t_mono``, for ordering and latency math) and a
wall-clock one (``t_wall``, for correlating with external logs), assigns
a global sequence number, and appends.  Consumers either poll
(``events()`` returns a snapshot) or subscribe (``subscribe(fn)`` — the
callback runs on the *emitting* thread, outside the bus lock, so a slow
subscriber delays its producer but can never deadlock the bus).

The ring is bounded (default ``DEFAULT_CAPACITY`` = the serving layer's
long-standing 10k observability cap): under sustained traffic old events
fall off the front and ``dropped`` counts them — the bus trades history
for a hard memory bound, the same discipline ``Scheduler.reap`` applies
to request records.

A JSONL sink (``attach_jsonl``) persists every event as one JSON line at
emit time — the durable record ``repro.obs.export.to_scenario`` converts
back into a replayable chaos ``Scenario`` (record-and-replay).  The sink
is size-bounded the same way the ring is count-bounded: past
``max_bytes`` the live file rotates to ``<path>.1..N`` (ascending =
chronological) and at most ``max_segments`` rotated segments are kept —
under sustained traffic the on-disk log can no longer grow without
limit.  ``load_jsonl`` reads the rotated segments in order, then the
live file, so replay sees one continuous stream.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

#: ring-buffer bound, shared convention with serve.scheduler's
#: OBSERVABILITY_CAP (the serving engine asserts its .events back-compat
#: view stays under this via the bus)
DEFAULT_CAPACITY = 10_000

#: payload keys that would collide with Event's own fields when the
#: event is flattened to one JSON object (to_dict / the JSONL sink) —
#: emit rejects them up front so the collision is an immediate error,
#: not a silently corrupted log
RESERVED_KEYS = frozenset({"seq", "t_mono", "t_wall", "subsystem",
                           "kind"})


@dataclasses.dataclass(frozen=True)
class Event:
    """One structured event.  ``data`` carries the subsystem-specific
    payload (host/replica/step/leaf ids, durations, byte counts...)."""
    seq: int
    t_mono: float          # time.perf_counter() at emit — ordering/latency
    t_wall: float          # time.time() at emit — external correlation
    subsystem: str         # "heartbeat" | "checkpoint" | "sdc" | ...
    kind: str              # subsystem-specific event name
    data: Dict[str, Any]

    def to_dict(self) -> Dict[str, Any]:
        return {"seq": self.seq, "t_mono": self.t_mono,
                "t_wall": self.t_wall, "subsystem": self.subsystem,
                "kind": self.kind, **self.data}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Event":
        d = dict(d)
        return cls(seq=int(d.pop("seq", 0)),
                   t_mono=float(d.pop("t_mono", 0.0)),
                   t_wall=float(d.pop("t_wall", 0.0)),
                   subsystem=str(d.pop("subsystem", "")),
                   kind=str(d.pop("kind", "")), data=d)


class EventBus:
    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self.dropped = 0                   # events evicted off the ring
        self._subscribers: List[Callable[[Event], None]] = []
        self._jsonl: Optional[io.TextIOBase] = None
        self._jsonl_path: Optional[str] = None
        self._jsonl_max_bytes: Optional[int] = None
        self._jsonl_max_segments = 8
        self._jsonl_bytes = 0
        self._jsonl_indices: List[int] = []   # live rotated-segment indices

    # ------------------------------------------------------------------
    # producing
    # ------------------------------------------------------------------
    def emit(self, subsystem: str, kind: str, **data: Any) -> Event:
        bad = RESERVED_KEYS & data.keys()
        if bad:
            raise ValueError(
                f"event payload keys {sorted(bad)} collide with Event "
                f"fields; rename them (e.g. kind -> save_kind)")
        ev = Event(seq=0, t_mono=time.perf_counter(), t_wall=time.time(),
                   subsystem=subsystem, kind=kind, data=data)
        with self._lock:
            ev = dataclasses.replace(ev, seq=self._seq)
            self._seq += 1
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(ev)
            subscribers = list(self._subscribers)
            # sink write INSIDE the lock: rotation (close + rename + reopen)
            # must be atomic against concurrent emitters
            if self._jsonl is not None:
                try:
                    self._sink_write(json.dumps(ev.to_dict()) + "\n")
                except ValueError:
                    pass                   # sink closed under the emitter
        # callbacks OUTSIDE the lock: a subscriber may emit (re-entrancy)
        # or inspect the bus without deadlocking
        for fn in subscribers:
            fn(ev)
        return ev

    # ------------------------------------------------------------------
    # consuming
    # ------------------------------------------------------------------
    def events(self, subsystem: Optional[str] = None,
               kind: Optional[str] = None) -> List[Event]:
        """Snapshot of the retained ring, oldest first, optionally
        filtered."""
        with self._lock:
            evs = list(self._ring)
        return [e for e in evs
                if (subsystem is None or e.subsystem == subsystem)
                and (kind is None or e.kind == kind)]

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    @property
    def total_emitted(self) -> int:
        with self._lock:
            return self._seq

    def subscribe(self, fn: Callable[[Event], None]) -> Callable:
        """Register a hook invoked (on the emitting thread) for every
        subsequent event; returns ``fn`` so it can be unsubscribed."""
        with self._lock:
            self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[Event], None]) -> None:
        with self._lock:
            if fn in self._subscribers:
                self._subscribers.remove(fn)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    # ------------------------------------------------------------------
    # JSONL sink (record side of record-and-replay)
    # ------------------------------------------------------------------
    def attach_jsonl(self, path: str, max_bytes: Optional[int] = None,
                     max_segments: int = 8) -> str:
        """Persist every subsequent event as one JSON line at ``path``
        (append mode: re-attaching resumes the log).

        ``max_bytes`` bounds the LIVE file: a write that would push it
        past the cap first rotates it to ``<path>.<i>`` (``i`` ascending,
        so ``.1`` is the oldest segment) and keeps at most
        ``max_segments`` rotated segments, deleting older ones — total
        disk is bounded by ~``(max_segments + 1) * max_bytes``.
        ``max_bytes=None`` (default) keeps the unbounded legacy
        behaviour."""
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
            self._jsonl = open(path, "a")
            self._jsonl_path = path
            self._jsonl_max_bytes = max_bytes
            self._jsonl_max_segments = max(int(max_segments), 1)
            self._jsonl_bytes = self._jsonl.tell()
            self._jsonl_indices = _segment_indices(path)
        return path

    def _sink_write(self, line: str) -> None:
        """Write one line to the sink, rotating first if it would push
        the live file past ``max_bytes``.  Caller holds the lock."""
        if (self._jsonl_max_bytes is not None and self._jsonl_bytes > 0
                and self._jsonl_bytes + len(line) > self._jsonl_max_bytes):
            self._rotate_locked()
        self._jsonl.write(line)
        self._jsonl_bytes += len(line)

    def _rotate_locked(self) -> None:
        self._jsonl.close()
        idx = (self._jsonl_indices[-1] + 1) if self._jsonl_indices else 1
        os.replace(self._jsonl_path, f"{self._jsonl_path}.{idx}")
        self._jsonl_indices.append(idx)
        while len(self._jsonl_indices) > self._jsonl_max_segments:
            doomed = self._jsonl_indices.pop(0)
            try:
                os.remove(f"{self._jsonl_path}.{doomed}")
            except FileNotFoundError:
                pass
        self._jsonl = open(self._jsonl_path, "a")
        self._jsonl_bytes = 0

    def flush(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.flush()

    def close(self) -> None:
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None


def _segment_indices(path: str) -> List[int]:
    """Indices of existing rotated segments ``<path>.<i>``, ascending."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    base = os.path.basename(path) + "."
    idxs = []
    try:
        names = os.listdir(d)
    except FileNotFoundError:
        return []
    for name in names:
        if name.startswith(base) and name[len(base):].isdigit():
            idxs.append(int(name[len(base):]))
    return sorted(idxs)


def load_jsonl(path: str) -> List[Event]:
    """Read a recorded event log back (replay side); skips blank lines.

    Rotated segments (``<path>.1..N``, oldest = lowest index) are read
    first, then the live file, so a rotated log replays as one
    continuous stream."""
    out = []
    paths = [f"{path}.{i}" for i in _segment_indices(path)]
    if os.path.exists(path) or not paths:
        paths.append(path)        # missing live file still raises below
    for p in paths:
        with open(p) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(Event.from_dict(json.loads(line)))
    return out
