from repro.data.pipeline import (ShardedPipeline, SyntheticLMData,
                                 make_pipeline)

__all__ = ["ShardedPipeline", "SyntheticLMData", "make_pipeline"]
