"""Deterministic, checkpointable, per-host-sharded synthetic data pipeline.

Every batch is a pure function of ``(seed, step, shard)`` via counter-based
threefry keys — no stateful iterators.  The *local state* in DeLIA terms is
therefore a tiny cursor ``{"step": int}`` per shard: O(1) save/restore with
exact resume, which directly fixes the local-save limitation the paper hit
with Julia's Distributed module (DESIGN.md S2).

Two shard modes:

- ``"fold"`` (legacy): each host folds its id into the RNG key, so the
  global batch *content* depends on how many hosts there are.  Exact resume
  requires the same width.
- ``"slice"`` (elastic): the GLOBAL batch is a pure function of
  ``(seed, step)`` alone and each shard takes a contiguous row slice.  The
  merged batch is identical for any DP width, so a checkpoint taken at
  width W restores onto width W' with the loss trajectory unchanged — the
  property the elastic failover loop (core/elastic_loop.py) relies on.

``ShardedPipeline`` models local-SCOPE state: one cursor + RNG record per
DP shard, saved as its own checkpoint file (core/checkpoint.py
``local_shards``) and remapped onto the current width on restore.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig

SHARD_MODES = ("fold", "slice")


def _shard_rng(seed: int, shard: int):
    """Shard ``shard``'s derived RNG key — pure in (seed, shard), so a
    restore can recompute it and verify the saved record."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), shard)


def even_spans(n: int, width: int):
    """``width`` near-equal contiguous spans tiling ``[0, n)`` — the one
    partition formula every local-scope provider shares (rows here, shots
    in apps/fwi), so the rounding and its tiling invariant cannot drift
    between copies."""
    assert 1 <= width <= n, (width, n)
    bounds = [round(k * n / width) for k in range(width + 1)]
    return list(zip(bounds[:-1], bounds[1:]))


@dataclasses.dataclass
class SyntheticLMData:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0
    shard_mode: str = "fold"

    def __post_init__(self):
        assert self.shard_mode in SHARD_MODES, self.shard_mode
        self._rng_record = None            # (seed, shard)-pure; lazy cache
        if self.shard_mode == "slice":
            self._set_span()
        else:
            assert self.global_batch % self.num_hosts == 0, \
                (self.global_batch, self.num_hosts)
            self.host_batch = self.global_batch // self.num_hosts

    def _set_span(self) -> None:
        """Near-equal contiguous row span for (host_id, num_hosts); widths
        that don't divide the global batch are fine (elastic shrink to any
        survivor count), the spans just differ by one row."""
        self.row_lo, self.row_hi = even_spans(
            self.global_batch, self.num_hosts)[self.host_id]
        self.host_batch = self.row_hi - self.row_lo
        self._rng_record = None            # (seed, shard)-pure; lazy cache

    # ---- DeLIA local state ----
    def shard_rng(self):
        """This shard's derived RNG key (pure: (seed, shard))."""
        return _shard_rng(self.seed, self.host_id)

    def state_dict(self) -> Dict:
        if self._rng_record is None:       # once per shard lifetime, not
            self._rng_record = np.asarray(  # per save (it's on that path)
                self.shard_rng()).tolist()
        return {"step": int(self.step), "seed": int(self.seed),
                "host_id": int(self.host_id), "shard": int(self.host_id),
                "width": int(self.num_hosts), "mode": self.shard_mode,
                "rng": self._rng_record}

    def load_state_dict(self, state: Dict) -> None:
        assert int(state["seed"]) == self.seed, "seed mismatch on restore"
        mode = state.get("mode", "fold")
        assert mode == self.shard_mode, (mode, self.shard_mode)
        width = int(state.get("width", self.num_hosts))
        if self.shard_mode == "fold":
            # fold mode bakes the width into the batch content: resuming at
            # a different width would silently change the data stream
            assert width == self.num_hosts, \
                f"fold-mode restore across widths ({width}->{self.num_hosts})"
        if (state.get("rng") is not None
                and int(state.get("shard", self.host_id)) == self.host_id):
            # the recorded key is pure in (seed, shard): a mismatch means
            # the saved dict was corrupted or belongs to another stream
            assert list(state["rng"]) == \
                np.asarray(self.shard_rng()).tolist(), "shard RNG mismatch"
        self.step = int(state["step"])

    def repartition(self, host_id: int, num_hosts: int) -> None:
        """Reassign this pipeline's shard for a new DP width (slice mode:
        the merged global batch is unchanged, only the row span moves)."""
        assert self.shard_mode == "slice", \
            "repartition requires shard_mode='slice'"
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._set_span()

    # ---- batches ----
    def _key(self, step: int):
        k = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        if self.shard_mode == "fold":
            k = jax.random.fold_in(k, self.host_id)
        return k

    def _full_rows(self) -> int:
        """Rows generated per batch: the host rows (fold) or the full
        global batch that slice mode cuts its span from."""
        return self.host_batch if self.shard_mode == "fold" \
            else self.global_batch

    def peek_global_batch(self, step: Optional[int] = None) -> Dict:
        """Slice mode only: the full width-independent global batch (what
        the shard slices its span from).  One generation serves the whole
        DP group — ShardedPipeline uses this instead of paying the
        generation cost once per shard."""
        assert self.shard_mode == "slice"
        return self._generate(self.step if step is None else step)

    def peek_batch(self, step: Optional[int] = None) -> Dict:
        """Batch for an arbitrary step (pure; does not advance the cursor)."""
        step = self.step if step is None else step
        batch = self._generate(step)
        if self.shard_mode == "slice":
            lo, hi = self.row_lo, self.row_hi
            batch = {k: (v[:, lo:hi] if k == "positions" else v[lo:hi])
                     for k, v in batch.items()}
        return batch

    def _generate(self, step: int) -> Dict:
        key = self._key(step)
        cfg = self.cfg
        B, S = self._full_rows(), self.seq_len
        batch: Dict = {}
        if cfg.embedding_inputs:
            k1, k2 = jax.random.split(key)
            batch["embeddings"] = jax.random.normal(
                k1, (B, S, cfg.d_model), cfg.dtype) * 0.02
            batch["targets"] = jax.random.randint(
                k2, (B, S), 0, cfg.vocab_size, jnp.int32)
        else:
            toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size,
                                      jnp.int32)
            batch["tokens"] = toks[:, :-1]
            batch["targets"] = toks[:, 1:]
        if cfg.mrope_sections:
            pos = jnp.arange(S, dtype=jnp.int32)[None, :]
            pos = jnp.broadcast_to(pos, (B, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
        return batch

    def next_batch(self) -> Dict:
        b = self.peek_batch()
        self.step += 1
        return b


class ShardedPipeline:
    """``dp_width`` slice-mode shards driven in lockstep (one process
    simulating the whole DP group, as the elastic tests do).

    ``next_batch`` merges the shard slices back into the width-independent
    global batch; the DeLIA *local scope* is one dict per shard
    (``shard_state_dicts``), each persisted as its own file and remapped
    onto the pipeline's CURRENT width on restore (``load_shard_state_dicts``)
    — the shard count may have changed in between (elastic shrink/grow).
    """

    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 dp_width: int = 1, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.remapped_from: Optional[int] = None
        self._build(dp_width, step=0)

    def _build(self, width: int, step: int) -> None:
        assert 1 <= width <= self.global_batch, (width, self.global_batch)
        self.dp_width = width
        self.shards = [
            SyntheticLMData(self.cfg, self.seq_len, self.global_batch,
                            seed=self.seed, host_id=i, num_hosts=width,
                            step=step, shard_mode="slice")
            for i in range(width)
        ]

    @property
    def step(self) -> int:
        return self.shards[0].step

    def repartition(self, dp_width: int) -> None:
        if dp_width != self.dp_width:
            self._build(dp_width, step=self.step)

    def next_batch(self) -> Dict:
        steps = {s.step for s in self.shards}
        assert len(steps) == 1, f"shard cursors diverged: {steps}"  # BSP
        # the merged batch IS the width-independent global batch, so
        # generate it once rather than once per shard; the shards'
        # contribution is their cursors (local scope), advanced in lockstep
        batch = self.shards[0].peek_global_batch()
        for s in self.shards:
            s.step += 1
        return batch

    # ---- DeLIA local scope ----
    def state_dict(self) -> Dict:
        return {"step": int(self.step), "seed": int(self.seed),
                "width": int(self.dp_width), "scope": "sharded"}

    def load_state_dict(self, state: Dict) -> None:
        assert int(state["seed"]) == self.seed, "seed mismatch on restore"
        self._build(self.dp_width, step=int(state["step"]))

    def shard_state_dicts(self) -> List[Dict]:
        return [s.state_dict() for s in self.shards]

    def load_shard_state_dicts(self, dicts: List[Dict]) -> None:
        """Remap saved per-shard cursors onto the current width.  The saved
        width may differ; slice mode guarantees the merged stream is
        identical, so only the (agreed, BSP-synchronous) cursor carries
        over while the row spans are recomputed for ``self.dp_width``."""
        assert dicts, "no shard state to load"
        steps = {int(d["step"]) for d in dicts}
        assert len(steps) == 1, f"saved shard cursors diverged: {steps}"
        for d in dicts:
            assert int(d["seed"]) == self.seed, "seed mismatch on restore"
            assert d.get("mode", "slice") == "slice", d
            if d.get("rng") is not None:
                # per-shard RNG is pure in (seed, shard): recompute and
                # verify the record round-tripped intact
                exp = _shard_rng(self.seed, int(d["shard"]))
                assert list(d["rng"]) == np.asarray(exp).tolist(), \
                    f"shard {d['shard']} RNG record corrupted"
        saved_width = int(dicts[0].get("width", len(dicts)))
        assert len(dicts) == saved_width, (len(dicts), saved_width)
        self.remapped_from = saved_width
        self._build(self.dp_width, step=steps.pop())


def make_pipeline(cfg: ModelConfig, seq_len: int, global_batch: int,
                  seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                  shard_mode: str = "fold") -> SyntheticLMData:
    return SyntheticLMData(cfg, seq_len, global_batch, seed=seed,
                           host_id=host_id, num_hosts=num_hosts,
                           shard_mode=shard_mode)
