"""Deterministic, checkpointable, per-host-sharded synthetic data pipeline.

Every batch is a pure function of ``(seed, step, host_id)`` via counter-based
threefry keys — no stateful iterators.  The *local state* in DeLIA terms is
therefore a tiny cursor ``{"step": int}`` per host: O(1) save/restore with
exact resume, which directly fixes the local-save limitation the paper hit
with Julia's Distributed module (DESIGN.md S2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig


@dataclasses.dataclass
class SyntheticLMData:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0, \
            (self.global_batch, self.num_hosts)
        self.host_batch = self.global_batch // self.num_hosts

    # ---- DeLIA local state ----
    def state_dict(self) -> Dict:
        return {"step": int(self.step), "seed": int(self.seed),
                "host_id": int(self.host_id)}

    def load_state_dict(self, state: Dict) -> None:
        assert int(state["seed"]) == self.seed, "seed mismatch on restore"
        self.step = int(state["step"])

    # ---- batches ----
    def _key(self, step: int):
        k = jax.random.PRNGKey(self.seed)
        return jax.random.fold_in(jax.random.fold_in(k, step), self.host_id)

    def peek_batch(self, step: Optional[int] = None) -> Dict:
        """Batch for an arbitrary step (pure; does not advance the cursor)."""
        step = self.step if step is None else step
        key = self._key(step)
        cfg = self.cfg
        B, S = self.host_batch, self.seq_len
        batch: Dict = {}
        if cfg.embedding_inputs:
            k1, k2 = jax.random.split(key)
            batch["embeddings"] = jax.random.normal(
                k1, (B, S, cfg.d_model), cfg.dtype) * 0.02
            batch["targets"] = jax.random.randint(
                k2, (B, S), 0, cfg.vocab_size, jnp.int32)
        else:
            toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size,
                                      jnp.int32)
            batch["tokens"] = toks[:, :-1]
            batch["targets"] = toks[:, 1:]
        if cfg.mrope_sections:
            pos = jnp.arange(S, dtype=jnp.int32)[None, :]
            pos = jnp.broadcast_to(pos, (B, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
        return batch

    def next_batch(self) -> Dict:
        b = self.peek_batch()
        self.step += 1
        return b


def make_pipeline(cfg: ModelConfig, seq_len: int, global_batch: int,
                  seed: int = 0, host_id: int = 0, num_hosts: int = 1
                  ) -> SyntheticLMData:
    return SyntheticLMData(cfg, seq_len, global_batch, seed=seed,
                           host_id=host_id, num_hosts=num_hosts)
