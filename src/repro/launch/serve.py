"""Serving driver: the dependable serving engine (docs/serving.md).

Thin CLI over ``repro.serve.ServeEngine`` — continuous batching over a
slot cache pool, N replicas with heartbeat failover, decode-path SDC
sentinel.  The old fixed-batch demo is what examples/serve_lm.py still
shows; this driver serves a request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --tiny \
        --requests 8 --prompt-len 32 --gen 32 \
        --replicas 2 --slots 4 --fault-tolerant --kill-replica-at 5
"""
from __future__ import annotations

import argparse
import statistics
import sys
import tempfile
import time

import jax

from repro.configs import ALL_ARCHS
from repro.core import CheckpointManager, FaultInjector
from repro.models import get_config, init_params
from repro.serve import ServeEngine, make_standby_source, pctl


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ALL_ARCHS)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=1,
                    help="model replicas in the serving pool")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache slots per replica (max in-flight "
                    "requests each)")
    ap.add_argument("--fault-tolerant", action="store_true",
                    help="heartbeat monitoring + decode sentinel + "
                    "failover (re-execute drained requests on survivors)")
    ap.add_argument("--standbys", type=int, default=0,
                    help="warm standbys restored from a params checkpoint "
                    "on failure (implies --fault-tolerant)")
    ap.add_argument("--kill-replica-at", type=int, default=-1,
                    help="inject a replica kill at this engine step "
                    "(drives the failover path end to end)")
    ap.add_argument("--telemetry-dir", default="",
                    help="record the run's telemetry bundle here "
                         "(events.jsonl + trace.json + metrics, "
                         "docs/observability.md)")
    ap.add_argument("--metrics-snapshot", default="",
                    help="write a JSON metrics snapshot to this path at "
                         "the end of the run")
    ap.add_argument("--pre-drain", action="store_true",
                    help="telemetry plane: run the anomaly detectors over "
                         "the engine's event stream and pre-drain a "
                         "replica whose host risk crosses "
                         "--risk-threshold (docs/observability.md)")
    ap.add_argument("--risk-threshold", type=float, default=0.8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, tiny=args.tiny)
    if not cfg.has_decode:
        print(f"{args.arch} is encoder-only; no decode loop")
        return 1
    if cfg.embedding_inputs:
        print(f"{args.arch} takes embedding inputs; the engine serves "
              "token prompts")
        return 1

    params = init_params(cfg, jax.random.PRNGKey(0))
    injector = None
    if args.kill_replica_at >= 0:
        injector = FaultInjector()
        injector.schedule_replica_kill(args.kill_replica_at,
                                       replica_id=args.replicas - 1)
    fault_tolerant = args.fault_tolerant or args.standbys > 0

    obs = None
    if args.telemetry_dir or args.metrics_snapshot or args.pre_drain:
        import os as _os
        from repro.obs import Observability
        obs = Observability(
            jsonl_path=(_os.path.join(args.telemetry_dir, "events.jsonl")
                        if args.telemetry_dir else None))

    anomaly = None
    risk_source = None
    if args.pre_drain:
        from repro.obs import AnomalyEngine
        anomaly = AnomalyEngine()
        anomaly.attach(obs.bus)
        risk_source = anomaly.risk_scores

    engine = ServeEngine(cfg, params, num_replicas=args.replicas,
                         slots_per_replica=args.slots,
                         max_len=args.prompt_len + args.gen,
                         fault_tolerant=fault_tolerant,
                         fault_injector=injector, obs=obs,
                         risk_source=risk_source,
                         pre_drain_threshold=args.risk_threshold)
    ckpt_dir = None
    if args.standbys > 0:
        # warm-standby params come back through restore_latest — the same
        # walk-back-past-corruption path training recovery uses
        ckpt_dir = tempfile.mkdtemp(prefix="serve_standby_")
        manager = CheckpointManager(ckpt_dir, fsync="none")
        manager.save(0, {"params": params})
        like = jax.eval_shape(lambda: params)
        for _ in range(args.standbys):
            engine.add_standby(make_standby_source(manager, like))

    for i in range(args.requests):
        prompt = jax.random.randint(jax.random.PRNGKey(100 + i),
                                    (args.prompt_len,), 0, cfg.vocab_size)
        engine.submit([int(t) for t in prompt], args.gen)

    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0

    lat = engine.request_latencies()
    ttft = sorted(t for _, t, _ in lat)
    total = sorted(t for _, _, t in lat)
    done_tokens = sum(len(v) for v in results.values())
    prefill_tokens = args.prompt_len * len(lat)
    print(f"served {len(results)}/{args.requests} requests "
          f"({done_tokens} tokens) in {wall:.2f}s on {args.replicas} "
          f"replica(s) x {args.slots} slots "
          f"-> {done_tokens / wall:.0f} tok/s decode, "
          f"{prefill_tokens / wall:.0f} tok/s prefill-amortized")
    if total:
        print(f"latency  p50={statistics.median(total) * 1e3:.0f}ms "
              f"p99={pctl(total, 0.99) * 1e3:.0f}ms "
              f"ttft p50={statistics.median(ttft) * 1e3:.0f}ms")
    for ev in engine.events:
        print(f"event step={ev['step']}: {ev['event']} "
              + " ".join(f"{k}={v}" for k, v in ev.items()
                         if k not in ("t", "step", "event")))
    retried = len(engine.scheduler.retried_rids)
    if retried:
        print(f"failover: {retried} request(s) drained and re-executed, "
              f"{len(engine.scheduler.failed_rids)} dropped")
    if obs is not None:
        summary = obs.timeline().summary()
        mttr = summary["mttr_s"]
        mttr_txt = f"MTTR={mttr:.3f}s, " if mttr is not None else ""
        print(f"telemetry: {summary['incidents']} incidents, "
              f"{mttr_txt}availability={summary['availability']:.4f} "
              f"over {summary['span_s']:.1f}s observed")
        if args.telemetry_dir:
            paths = obs.dump(args.telemetry_dir)
            print(f"telemetry bundle: {sorted(paths.values())}")
        if args.metrics_snapshot:
            obs.registry.to_json(args.metrics_snapshot)
            print(f"metrics snapshot: {args.metrics_snapshot}")
        obs.close()
    engine.shutdown()
    return 0 if len(results) == args.requests else 1


if __name__ == "__main__":
    sys.exit(main())
