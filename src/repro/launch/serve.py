"""Serving driver: batched prefill + greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --tiny \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS
from repro.launch.mesh import make_host_mesh
from repro.models import get_config, init_cache, init_params
from repro.sharding.api import mesh_context
from repro.train import make_decode_step, make_prefill_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ALL_ARCHS)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, tiny=args.tiny)
    if not cfg.has_decode:
        print(f"{args.arch} is encoder-only; no decode loop")
        return 1
    mesh = make_host_mesh(args.data_par, args.model_par)
    with mesh_context(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        cache = init_cache(cfg, args.batch, args.prompt_len + args.gen)
        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

        def make_batch(toks):
            b = {"tokens": toks}
            if cfg.mrope_sections:
                S = toks.shape[1]
                pos = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None, None],
                    (3, toks.shape[0], S))
                b["positions"] = pos
            if cfg.embedding_inputs:
                b = {"embeddings": jax.random.normal(
                    jax.random.PRNGKey(2),
                    (toks.shape[0], toks.shape[1], cfg.d_model), cfg.dtype)}
                if cfg.mrope_sections:
                    b["positions"] = pos
            return b

        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        t0 = time.perf_counter()
        tok, cache = prefill(params, make_batch(prompts), cache)
        jax.block_until_ready(tok)
        t_pre = time.perf_counter() - t0

        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            tok, cache = decode(params, make_batch(tok[:, None]), cache)
        jax.block_until_ready(tok)
        t_dec = time.perf_counter() - t0

    print(f"prefill {args.batch}x{args.prompt_len}: {t_pre*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_pre:.0f} tok/s)")
    print(f"decode  {args.batch}x{args.gen-1}: {t_dec*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/t_dec:.0f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
