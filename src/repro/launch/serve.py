"""Serving driver: the dependable serving engine (docs/serving.md).

Thin CLI over ``repro.serve.ServeEngine`` — continuous batching over a
block-paged KV cache with prefix sharing (the default; ``--legacy-pool``
forces the old fixed-slot pool), N replicas with heartbeat failover,
decode-path SDC sentinel.  The old fixed-batch demo is what
examples/serve_lm.py still shows; this driver serves a request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --tiny \
        --requests 8 --prompt-len 32 --gen 32 \
        --replicas 2 --slots 4 --fault-tolerant --kill-replica-at 5

    # push concurrency past the slot budget at the same memory
    PYTHONPATH=src python -m repro.launch.serve --tiny --requests 32 \
        --slots 4 --max-active 16
"""
from __future__ import annotations

import argparse
import statistics
import sys
import tempfile
import time

import jax

from repro.configs import ALL_ARCHS
from repro.core import CheckpointManager, FaultInjector
from repro.models import get_config, init_params
from repro.serve import ServeEngine, make_standby_source, pctl


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ALL_ARCHS)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=1,
                    help="model replicas in the serving pool")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache slots per replica (max in-flight "
                    "requests each); under --paged this sizes the "
                    "default equal-memory page pool")
    pool = ap.add_mutually_exclusive_group()
    pool.add_argument("--paged", action="store_true", default=None,
                      dest="paged",
                      help="block-paged KV cache with prefix sharing "
                      "(docs/serving.md); the default wherever the model "
                      "supports it")
    pool.add_argument("--legacy-pool", action="store_false", dest="paged",
                      help="force the legacy fixed-slot pool (the "
                      "equal-memory bench comparator)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page (default 16)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pages in each replica's pool (default: the slot "
                    "pool's memory budget, repaged)")
    ap.add_argument("--max-active", type=int, default=None,
                    help="decode rows per replica under --paged (default: "
                    "--slots); raise it to push concurrency past the "
                    "slot count at the same memory")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable refcounted prefix sharing between "
                    "requests")
    ap.set_defaults(paged=None)         # auto: paged where supported
    ap.add_argument("--fault-tolerant", action="store_true",
                    help="heartbeat monitoring + decode sentinel + "
                    "failover (re-execute drained requests on survivors)")
    ap.add_argument("--standbys", type=int, default=0,
                    help="warm standbys restored from a params checkpoint "
                    "on failure (implies --fault-tolerant)")
    ap.add_argument("--kill-replica-at", type=int, default=-1,
                    help="inject a replica kill at this engine step "
                    "(drives the failover path end to end)")
    ap.add_argument("--telemetry-dir", default="",
                    help="record the run's telemetry bundle here "
                         "(events.jsonl + trace.json + metrics, "
                         "docs/observability.md)")
    ap.add_argument("--metrics-snapshot", default="",
                    help="write a JSON metrics snapshot to this path at "
                         "the end of the run")
    ap.add_argument("--pre-drain", action="store_true",
                    help="telemetry plane: run the anomaly detectors over "
                         "the engine's event stream and pre-drain a "
                         "replica whose host risk crosses "
                         "--risk-threshold (docs/observability.md)")
    ap.add_argument("--risk-threshold", type=float, default=0.8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, tiny=args.tiny)
    if not cfg.has_decode:
        print(f"{args.arch} is encoder-only; no decode loop")
        return 1
    if cfg.embedding_inputs:
        print(f"{args.arch} takes embedding inputs; the engine serves "
              "token prompts")
        return 1

    params = init_params(cfg, jax.random.PRNGKey(0))
    injector = None
    if args.kill_replica_at >= 0:
        injector = FaultInjector()
        injector.schedule_replica_kill(args.kill_replica_at,
                                       replica_id=args.replicas - 1)
    fault_tolerant = args.fault_tolerant or args.standbys > 0

    obs = None
    if args.telemetry_dir or args.metrics_snapshot or args.pre_drain:
        import os as _os
        from repro.obs import Observability
        obs = Observability(
            jsonl_path=(_os.path.join(args.telemetry_dir, "events.jsonl")
                        if args.telemetry_dir else None))

    anomaly = None
    risk_source = None
    if args.pre_drain:
        from repro.obs import AnomalyEngine
        anomaly = AnomalyEngine()
        anomaly.attach(obs.bus)
        risk_source = anomaly.risk_scores

    paged_kw = {}
    if args.page_size is not None:
        paged_kw["page_size"] = args.page_size
    engine = ServeEngine(cfg, params, num_replicas=args.replicas,
                         slots_per_replica=args.slots,
                         max_len=args.prompt_len + args.gen,
                         fault_tolerant=fault_tolerant,
                         fault_injector=injector, obs=obs,
                         risk_source=risk_source,
                         pre_drain_threshold=args.risk_threshold,
                         paged=args.paged, num_pages=args.num_pages,
                         max_active=args.max_active,
                         prefix_cache=not args.no_prefix_cache,
                         **paged_kw)
    ckpt_dir = None
    if args.standbys > 0:
        # warm-standby params come back through restore_latest — the same
        # walk-back-past-corruption path training recovery uses
        ckpt_dir = tempfile.mkdtemp(prefix="serve_standby_")
        manager = CheckpointManager(ckpt_dir, fsync="none")
        manager.save(0, {"params": params})
        like = jax.eval_shape(lambda: params)
        for _ in range(args.standbys):
            engine.add_standby(make_standby_source(manager, like))

    for i in range(args.requests):
        prompt = jax.random.randint(jax.random.PRNGKey(100 + i),
                                    (args.prompt_len,), 0, cfg.vocab_size)
        engine.submit([int(t) for t in prompt], args.gen)

    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0

    lat = engine.request_latencies()
    ttft = sorted(t for _, t, _ in lat)
    total = sorted(t for _, _, t in lat)
    done_tokens = sum(len(v) for v in results.values())
    prefill_tokens = args.prompt_len * len(lat)
    pool_txt = (f"{engine.fns.max_active} paged rows "
                f"({engine.fns.num_pages} x {engine.fns.page_size}-token "
                f"pages)" if engine.paged else f"{args.slots} slots")
    print(f"served {len(results)}/{args.requests} requests "
          f"({done_tokens} tokens) in {wall:.2f}s on {args.replicas} "
          f"replica(s) x {pool_txt} "
          f"-> {done_tokens / wall:.0f} tok/s decode, "
          f"{prefill_tokens / wall:.0f} tok/s prefill-amortized")
    if engine.paged:
        cons = engine.page_conservation()
        hits = sum(r.pool.prefix_hits
                   for r in engine.router.replicas.values())
        misses = sum(r.pool.prefix_misses
                     for r in engine.router.replicas.values())
        total_lookups = hits + misses
        hit_txt = (f"{hits}/{total_lookups} "
                   f"({hits / total_lookups:.0%})" if total_lookups
                   else "0/0")
        print(f"paged KV: prefix hits {hit_txt}, "
              f"{cons['pages_free']}/{cons['pages_total']} pages free, "
              f"refcounts {'ok' if cons['refs_ok'] else 'DRIFTED'}")
    if total:
        print(f"latency  p50={statistics.median(total) * 1e3:.0f}ms "
              f"p99={pctl(total, 0.99) * 1e3:.0f}ms "
              f"ttft p50={statistics.median(ttft) * 1e3:.0f}ms")
    for ev in engine.events:
        print(f"event step={ev['step']}: {ev['event']} "
              + " ".join(f"{k}={v}" for k, v in ev.items()
                         if k not in ("t", "step", "event")))
    retried = len(engine.scheduler.retried_rids)
    if retried:
        print(f"failover: {retried} request(s) drained and re-executed, "
              f"{len(engine.scheduler.failed_rids)} dropped")
    if obs is not None:
        summary = obs.timeline().summary()
        mttr = summary["mttr_s"]
        mttr_txt = f"MTTR={mttr:.3f}s, " if mttr is not None else ""
        print(f"telemetry: {summary['incidents']} incidents, "
              f"{mttr_txt}availability={summary['availability']:.4f} "
              f"over {summary['span_s']:.1f}s observed")
        if args.telemetry_dir:
            paths = obs.dump(args.telemetry_dir)
            print(f"telemetry bundle: {sorted(paths.values())}")
        if args.metrics_snapshot:
            obs.registry.to_json(args.metrics_snapshot)
            print(f"metrics snapshot: {args.metrics_snapshot}")
        obs.close()
    engine.shutdown()
    return 0 if len(results) == args.requests else 1


if __name__ == "__main__":
    sys.exit(main())
