"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axis_names):
    """jax.make_mesh across jax versions: axis_types (and AxisType itself)
    only exist in newer releases; older ones default to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, expert: int = 0,
                   axis_names=None):
    """Small mesh over whatever devices exist (tests / examples).

    ``expert > 0`` grows a third "expert" axis — the 3D (data, model,
    expert) meshes MoE configs train on; the default stays 2D so existing
    callers are unchanged."""
    n = len(jax.devices())
    if expert:
        assert data * model * expert <= n, (data, model, expert, n)
        return make_mesh_compat(
            (data, model, expert), axis_names or ("data", "model", "expert"))
    assert data * model <= n, (data, model, n)
    return make_mesh_compat((data, model), axis_names or ("data", "model"))


def host_device_map(num_hosts: int, devices=None):
    """Partition the visible devices into per-host groups: host i owns a
    contiguous equal slice.  The elastic layer (core/elastic_loop.py)
    shrinks/grows meshes host-group-wise, mirroring how a real failure
    takes out a whole host's devices at once."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    assert num_hosts > 0 and n % num_hosts == 0, (n, num_hosts)
    per = n // num_hosts
    return {h: devices[h * per:(h + 1) * per] for h in range(num_hosts)}
