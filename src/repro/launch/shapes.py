"""Assigned input-shape cells + ShapeDtypeStruct input specs per cell.

LM-family shapes (per assignment):
  train_4k      seq 4096,    global_batch 256   (train_step)
  prefill_32k   seq 32768,   global_batch 32    (prefill)
  decode_32k    seq 32768,   global_batch 128   (decode: 1 token, full cache)
  long_500k     seq 524288,  global_batch 1     (decode; sub-quadratic only)

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs — no
device allocation; the dry-run lowers against them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import init_cache, init_params
from repro.models.base import BIDIR, FULL, ModelConfig
from repro.sharding.api import resolve
from repro.sharding.rules import DP_AXES, cache_specs, param_specs, state_specs
from repro.train.state import init_state

SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape_name: str
                    ) -> Tuple[bool, Optional[str]]:
    seq, batch, mode = SHAPES[shape_name]
    if mode == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k KV cache is infeasible "
                       "(quadratic); see DESIGN.md S5")
    return True, None


def _dp_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def _tp_size(mesh: Mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=resolve(spec, mesh) if mesh else None)


def batch_sds(cfg: ModelConfig, seq: int, batch: int, mesh: Optional[Mesh],
              mode: str) -> Dict:
    """ShapeDtypeStructs for the input batch of one step."""
    dp = _dp_size(mesh) if mesh else 1
    bspec = DP_AXES if (batch % dp == 0 and dp > 1) else None
    s = 1 if mode == "decode" else seq
    out: Dict = {}
    if cfg.embedding_inputs:
        out["embeddings"] = _sds((batch, s, cfg.d_model), cfg.dtype, mesh,
                                 P(bspec, None, None))
    else:
        out["tokens"] = _sds((batch, s), jnp.int32, mesh, P(bspec, None))
    if mode == "train":
        out["targets"] = _sds((batch, s), jnp.int32, mesh, P(bspec, None))
    if cfg.mrope_sections:
        out["positions"] = _sds((3, batch, s), jnp.int32, mesh,
                                P(None, bspec, None))
    return out


def state_sds(cfg: ModelConfig, mesh: Optional[Mesh], moe_ep: bool = False):
    """(ShapeDtypeStruct tree, sharding tree) for the TrainState."""
    shapes = jax.eval_shape(lambda: init_state(cfg, jax.random.PRNGKey(0)))
    if mesh is None:
        return shapes, None
    specs = state_specs(cfg, _tp_size(mesh), moe_ep)
    shardings = jax.tree.map(lambda sp: resolve(sp, mesh), specs,
                             is_leaf=lambda x: isinstance(x, P))
    sds = jax.tree.map(
        lambda sh, s: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=s),
        shapes, shardings)
    return sds, shardings


# Serving keeps weights FSDP-sharded only when a TP shard of the bf16 model
# would exceed this per-device budget; below it, weights replicate across DP
# (zero per-layer gathers at inference — EXPERIMENTS.md S Perf).
SERVE_FSDP_THRESHOLD_BYTES = 4 << 30


def params_sds(cfg: ModelConfig, mesh: Optional[Mesh], moe_ep: bool = False,
               serve_dtype=True):
    """Param specs.  For serving (prefill/decode) weights are cast to the
    compute dtype (bf16) — fp32 master copies are a training-only concern."""
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    if serve_dtype:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, cfg.dtype if s.dtype == jnp.float32 else s.dtype),
            shapes)
    if mesh is None:
        return shapes, None
    specs = param_specs(cfg, _tp_size(mesh), moe_ep)
    if serve_dtype:
        total = sum(int(np_prod(s.shape)) * s.dtype.itemsize
                    for s in jax.tree.leaves(shapes))
        if total / max(_tp_size(mesh), 1) < SERVE_FSDP_THRESHOLD_BYTES:
            strip = lambda p: P(*(None if e == "data" else e for e in p))
            specs = jax.tree.map(strip, specs,
                                 is_leaf=lambda x: isinstance(x, P))
    shardings = jax.tree.map(lambda sp: resolve(sp, mesh), specs,
                             is_leaf=lambda x: isinstance(x, P))
    sds = jax.tree.map(
        lambda sh, s: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=s),
        shapes, shardings)
    return sds, shardings


def np_prod(shape):
    n = 1
    for d in shape:
        n *= d
    return n


def cache_sds(cfg: ModelConfig, batch: int, cache_len: int,
              mesh: Optional[Mesh]):
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
    if mesh is None:
        return shapes, None
    dp = _dp_size(mesh)
    specs = cache_specs(cfg, _tp_size(mesh))

    def fix_batch(spec, shape):
        # replicate the batch dim when it doesn't divide DP (stacked cache
        # entries have a leading layer-group dim, so scan all entries)
        entries = list(spec)
        for i, e in enumerate(entries):
            if e == DP_AXES and i < len(shape) and shape[i] % dp != 0:
                entries[i] = None
        return P(*entries)

    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_sh) == len(flat_sp)
    shardings = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(shapes),
        [resolve(fix_batch(sp, sh.shape), mesh)
         for (_, sh), (_, sp) in zip(flat_sh, flat_sp)])
    sds = jax.tree.map(
        lambda sh, s: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=s),
        shapes, shardings)
    return sds, shardings


def input_specs(cfg: ModelConfig, shape_name: str, mesh: Optional[Mesh],
                moe_ep: bool = False):
    """Returns (mode, args_sds, out_shardings_hint) for the cell's step fn."""
    seq, batch, mode = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape_name)
    if not ok:
        raise ValueError(f"cell not applicable: {reason}")
    b = batch_sds(cfg, seq, batch, mesh, mode)
    if mode == "train":
        st, st_sh = state_sds(cfg, mesh, moe_ep)
        return mode, (st, b), (st_sh, None)
    pr, pr_sh = params_sds(cfg, mesh, moe_ep)
    ca, ca_sh = cache_sds(cfg, batch, seq, mesh)
    return mode, (pr, b, ca), (None, ca_sh)
