"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k [--multi-pod] [--all] [--out out.json]

For each cell this proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) and records
``compiled.memory_analysis()`` + ``compiled.cost_analysis()`` for the
roofline (EXPERIMENTS.md S Dry-run / S Roofline).
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ALL_ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.models import get_config
from repro.sharding.api import mesh_context
from repro.train import make_decode_step, make_prefill_step, make_train_step


# Gradient-accumulation defaults per arch for train_4k: keeps live
# activations under the 16 GB v5e HBM budget (measured via memory_analysis;
# the heavy archs additionally run with seq_shard=True — see configs).
DEFAULT_MICROBATCHES = {"qwen1.5-110b": 16, "gemma2-27b": 8,
                        "recurrentgemma-2b": 8}
FALLBACK_MICROBATCHES = 4


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               impl: Optional[str] = None, microbatches: Optional[int] = None,
               moe_ep: bool = False, cfg_overrides: Optional[Dict] = None,
               donate: bool = True):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mode, args, out_sh = input_specs(cfg, shape_name, mesh, moe_ep)
    seq, batch, _ = SHAPES[shape_name]
    if microbatches is None:
        microbatches = DEFAULT_MICROBATCHES.get(arch, FALLBACK_MICROBATCHES) \
            if mode == "train" else 1
    if mode == "train":
        # per-microbatch batch must stay shardable over the DP width
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("data", 1) * sizes.get("pod", 1)
        while microbatches > 1 and (batch // microbatches) % dp:
            microbatches //= 2

    with mesh_context(mesh):
        if mode == "train":
            from repro.sharding.rules import state_specs
            tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
            pspecs = state_specs(cfg, tp, moe_ep)["params"]
            fn = make_train_step(cfg, impl=impl, microbatches=microbatches,
                                 param_specs=pspecs)
            jfn = jax.jit(fn, out_shardings=out_sh,
                          donate_argnums=(0,) if donate else ())
        elif mode == "prefill":
            fn = make_prefill_step(cfg, impl=impl)
            jfn = jax.jit(fn, out_shardings=out_sh)
        else:
            fn = make_decode_step(cfg, impl=impl)
            jfn = jax.jit(fn, out_shardings=out_sh,
                          donate_argnums=(2,) if donate else ())
        t0 = time.perf_counter()
        lowered = jfn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    meta = {
        "arch": arch,
        "shape": shape_name,
        "mode": mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "seq": seq,
        "batch": batch,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "microbatches": microbatches,
    }
    return lowered, compiled, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    ok, reason = cell_applicable(cfg, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "skip", "reason": reason}
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {reason}", flush=True)
        return rec
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name,
                                             multi_pod=multi_pod)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        rec = {
            **meta,
            "status": "ok",
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device_bytes": (mem.argument_size_in_bytes
                                          + mem.temp_size_in_bytes),
            },
            "cost": {
                "flops_per_device": cost.get("flops", 0.0),
                "bytes_per_device": cost.get("bytes accessed", 0.0),
            },
        }
        if verbose:
            gb = rec["memory"]["peak_per_device_bytes"] / 2**30
            print(f"[ok]   {arch} x {shape_name} ({rec['mesh']}): "
                  f"compile={meta['compile_s']}s "
                  f"peak/dev={gb:.2f}GiB "
                  f"flops/dev={rec['cost']['flops_per_device']:.3e}",
                  flush=True)
        return rec
    except Exception as e:  # a failure here is a bug in the system
        if verbose:
            print(f"[FAIL] {arch} x {shape_name}: {e}", flush=True)
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "fail", "error": str(e)[:2000]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ALL_ARCHS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    archs = list(ALL_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    records = []
    failed = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp)
                records.append(rec)
                failed += rec["status"] == "fail"
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    print(f"\n{len(records)} cells: "
          f"{sum(r['status'] == 'ok' for r in records)} ok, "
          f"{sum(r['status'] == 'skip' for r in records)} skip, "
          f"{failed} fail")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
