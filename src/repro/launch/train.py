"""Fault-tolerant training driver (the end-to-end launcher).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --tiny \
        --steps 200 --ckpt-dir /tmp/ckpt --policy young_daly --async-save

Wires the full DeLIA stack around the BSP training loop: checkpoint policy
(Young/Daly or fixed), sync/async sharded checkpoints (+ optional int8
codec), termination-signal detection, optional UDP heartbeats, straggler
watchdog, and automatic restore-on-restart.  ``--inject-failure N`` simulates
a fail-stop at step N and recovers (the paper's fault model, end to end).

SDC guard (docs/sdc.md): ``--scrub``/``--sentinel`` turn on the tier-2/3
detectors, ``--abft`` opts the projection matmuls into the checksummed
kernel, and ``--inject-bitflip STEP:LEAF:BIT`` flips one state bit mid-run
to watch detection + rollback happen.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax

from repro.configs import ALL_ARCHS
from repro.core import (Dependability, DependabilityConfig, FaultInjector,
                        SystemModel, run_with_recovery)
from repro.data import make_pipeline
from repro.launch.mesh import make_host_mesh
from repro.models import get_config
from repro.sharding.api import mesh_context, resolve
from repro.sharding.rules import state_specs
from repro.train import init_state, make_train_step


def build(args):
    cfg = get_config(args.arch, tiny=args.tiny)
    overrides = {}
    if args.layers:
        overrides["num_layers"] = args.layers
    if args.d_model:
        overrides.update(d_model=args.d_model,
                         num_heads=max(args.d_model // 64, 1),
                         num_kv_heads=max(args.d_model // 128, 1),
                         head_dim=64, d_ff=args.d_model * 4)
    if overrides:
        overrides.setdefault("pad_heads_to", 0)
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ALL_ARCHS)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--policy", default="young_daly",
                    choices=["young_daly", "every_n", "risk_adjusted"])
    ap.add_argument("--every-n", type=int, default=10)
    ap.add_argument("--node-mtbf-hours", type=float, default=24 * 365)
    ap.add_argument("--num-nodes", type=int, default=1)
    ap.add_argument("--async-save", action="store_true")
    ap.add_argument("--codec", default=None, choices=[None, "int8"])
    ap.add_argument("--delta-checkpoint", action="store_true",
                    help="incremental saves: write only blocks whose "
                         "on-device hash changed since the last checkpoint")
    ap.add_argument("--delta-block", type=int, default=65536,
                    help="elements per delta block (multiple of 256)")
    ap.add_argument("--full-every", type=int, default=8,
                    help="force a full save every N checkpoints "
                         "(bounds the delta reference-chain depth)")
    ap.add_argument("--heartbeat", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="simulate a fail-stop at this step")
    ap.add_argument("--scrub", action="store_true",
                    help="tier-2 SDC: rotating state-checksum scrubber")
    ap.add_argument("--scrub-fraction", type=float, default=0.25)
    ap.add_argument("--sentinel", action="store_true",
                    help="tier-3 SDC: non-finite/loss-spike sentinel")
    ap.add_argument("--abft", action="store_true",
                    help="tier-1 SDC: checksummed projection matmuls")
    ap.add_argument("--inject-bitflip", default="",
                    help="STEP:LEAF:BIT, e.g. 50:params.embed.tok:30 — "
                         "flip one state bit mid-run (SDC fault model)")
    ap.add_argument("--telemetry-dir", default="",
                    help="record the run's telemetry bundle here "
                         "(events.jsonl + trace.json + metrics, "
                         "docs/observability.md)")
    ap.add_argument("--metrics-snapshot", default="",
                    help="write a JSON metrics snapshot to this path at "
                         "the end of the run")
    ap.add_argument("--telemetry-plane", action="store_true",
                    help="run the in-process telemetry plane: anomaly "
                         "detectors over the event stream, per-host risk "
                         "scores (docs/observability.md)")
    ap.add_argument("--proactive-checkpoint", action="store_true",
                    help="force a checkpoint when a precursor pushes any "
                         "host's risk past --risk-threshold (implies "
                         "--telemetry-plane)")
    ap.add_argument("--risk-threshold", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = build(args)
    mesh = make_host_mesh(args.data_par, args.model_par)
    tp = args.model_par
    specs = state_specs(cfg, tp)
    shardings = jax.tree.map(lambda s: resolve(s, mesh), specs,
                             is_leaf=lambda x: x.__class__.__name__
                             == "PartitionSpec")

    data = make_pipeline(cfg, args.seq_len, args.global_batch,
                         seed=args.seed)

    dep = Dependability(DependabilityConfig(
        checkpoint_dir=args.ckpt_dir,
        policy_mode=args.policy,
        every_n=args.every_n,
        async_save=args.async_save,
        codec=args.codec,
        delta_checkpoint=args.delta_checkpoint,
        delta_block=args.delta_block,
        full_every=args.full_every,
        heartbeat=args.heartbeat,
        scrub=args.scrub,
        scrub_fraction=args.scrub_fraction,
        sentinel=args.sentinel,
        system=SystemModel(node_mtbf_seconds=args.node_mtbf_hours * 3600,
                           num_nodes=args.num_nodes),
    )).start()
    dep.register_local_state(data)

    obs = None
    want_plane = args.telemetry_plane or args.proactive_checkpoint
    if args.telemetry_dir or args.metrics_snapshot or want_plane:
        from repro.obs import Observability
        import os as _os
        obs = Observability(
            jsonl_path=(_os.path.join(args.telemetry_dir, "events.jsonl")
                        if args.telemetry_dir else None))
        dep.attach_obs(obs)

    proactive = None
    if want_plane:
        from repro.obs import AnomalyEngine, make_proactive_hook
        anomaly = AnomalyEngine()
        anomaly.attach(obs.bus)
        if args.proactive_checkpoint:
            proactive = make_proactive_hook(
                anomaly.risk_scores, threshold=args.risk_threshold,
                policy=(dep.policy if args.policy == "risk_adjusted"
                        else None))
        elif args.policy == "risk_adjusted":
            # no forced saves — risk still tightens the Young/Daly
            # interval through the policy
            def proactive(step, _a=anomaly, _p=dep.policy):
                _p.observe_risk(
                    max(_a.risk_scores().values(), default=0.0))
                return None

    with mesh_context(mesh):
        step_fn = jax.jit(
            make_train_step(cfg, microbatches=args.microbatches,
                            total_steps=args.steps,
                            impl=("abft" if args.abft else None),
                            param_specs=specs["params"]),
            out_shardings=(shardings, None))

        latest = dep.manager.latest_step()
        template = jax.eval_shape(
            lambda: init_state(cfg, jax.random.PRNGKey(args.seed)))
        if latest is not None:
            state, got = dep.restore_latest(like=template,
                                            shardings=shardings)
            print(f"[train] restored checkpoint step {got}")
        else:
            state = jax.jit(
                lambda: init_state(cfg, jax.random.PRNGKey(args.seed)),
                out_shardings=shardings)()
        dep.register_global_state(template, shardings)

        injector = None
        if args.inject_failure:
            injector = FaultInjector()
            injector.schedule_failstop(args.inject_failure)
        if args.inject_bitflip:
            step_s, leaf, bit_s = args.inject_bitflip.split(":")
            injector = injector or FaultInjector()
            injector.schedule_bitflip(int(step_s), leaf, int(bit_s))

        def on_metrics(step, rec):
            if step % 10 == 0 or step == args.steps:
                print(f"[train] step {step:5d} loss={rec['loss']:.4f} "
                      f"gnorm={rec['grad_norm']:.3f} "
                      f"{rec['seconds']*1e3:.1f} ms"
                      + (" STRAGGLER" if rec["straggler"] else ""), flush=True)

        t0 = time.perf_counter()
        state, info = run_with_recovery(
            dep, step_fn, state, data, args.steps,
            fault_injector=injector, like=template, shardings=shardings,
            on_metrics=on_metrics, proactive=proactive)
        wall = time.perf_counter() - t0

    n_saves = len(dep.save_history)
    n_delta = sum(1 for s in dep.save_history
                  if getattr(s, "kind", "full") == "delta")
    delta_info = (f" ({n_saves - n_delta} full + {n_delta} delta)"
                  if args.delta_checkpoint else "")
    print(f"[train] {info['status']} in {wall:.1f}s; restarts="
          f"{info['restarts']}; checkpoints={n_saves}{delta_info}; "
          f"young-daly interval={dep.policy.interval_steps()} steps")
    events = [h["event"] for h in info["history"] if "event" in h]
    if events:
        print(f"[train] failure/corruption events: {events}")
    if obs is not None:
        summary = obs.timeline().summary()
        mttr = summary["mttr_s"]
        mttr_txt = f"MTTR={mttr:.3f}s, " if mttr is not None else ""
        print(f"[train] telemetry: {summary['incidents']} incidents, "
              f"{mttr_txt}availability={summary['availability']:.4f} "
              f"over {summary['span_s']:.1f}s observed")
        if args.telemetry_dir:
            paths = obs.dump(args.telemetry_dir)
            print(f"[train] telemetry bundle: {sorted(paths.values())}")
        if args.metrics_snapshot:
            obs.registry.to_json(args.metrics_snapshot)
            print(f"[train] metrics snapshot: {args.metrics_snapshot}")
        obs.close()
    dep.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
