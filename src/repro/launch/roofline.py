"""Roofline analysis from the compiled dry-run (EXPERIMENTS.md S Roofline).

Hardware model (TPU v5e-class, per assignment): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.

Methodology (probe-corrected accounting — see EXPERIMENTS.md for caveats):
XLA's ``cost_analysis`` counts a ``lax.scan`` body ONCE, so the scanned
production model under-reports by the trip count.  We therefore lower two
UNROLLED probes per cell with exact-FLOPs einsum attention:

    probe(L=0)     embed + head + loss (+ bwd)        [no layers]
    probe(L=P)     one pattern block of layers, unrolled

and linearly reconstruct:  total = L0 + (L/P) * (LP - L0).
``cost_analysis`` is per-device post-SPMD, so terms divide by per-chip peaks
directly (padding waste from uneven shardings is included, honestly).

Train cells: the probe is the grads function (fwd+bwd, remat recompute
included, grads pinned to param sharding) at the per-microbatch batch; a
step = microbatches x probe + a closed-form AdamW/clip update term
(elementwise over the local shard: ~25 flops and ~36 bytes per local param,
no collectives).  Serve cells: the probe is the actual prefill/decode step.

Collective wire bytes per device, parsed from the probe HLO:
    all-reduce 2(G-1)/G x out ; all-gather (G-1)/G x out ;
    reduce-scatter (G-1) x out ; all-to-all (G-1)/G x out ;
    collective-permute 1 x out          (G = replica group size)
collective term = wire_bytes / 50 GB/s (single-link, conservative).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, batch_sds, cache_sds, cell_applicable, \
    params_sds, state_sds
from repro.models import get_config
from repro.models.base import ModelConfig
from repro.sharding.api import mesh_context
from repro.sharding.rules import state_specs
from repro.train import make_decode_step, make_prefill_step
from repro.train.step import loss_fn

HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "ici_bw": 50e9,           # bytes/s per link
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\((%[\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> Dict[str, float]:
    """Per-device wire bytes by collective type (see module docstring).

    CPU-backend correction: XLA:CPU lowers bf16 collectives as
    convert(bf16->f32) -> collective(f32) -> convert back; on TPU these are
    native bf16.  Collectives whose operand is a convert fusion are counted
    at half the f32 bytes (their true bf16 wire size)."""
    out: Dict[str, float] = {"all-reduce": 0.0, "all-gather": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op, operand = m.group(1), m.group(2), m.group(3), \
            m.group(4)
        b = _shape_bytes(dtype, dims)
        if dtype == "f32" and "convert" in operand:
            b *= 0.5  # semantically a bf16 collective (see docstring)
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gm2 = _GROUPS_EXPL_RE.search(line)
            if gm2:
                g = len(gm2.group(1).split(","))
        g = g or 1
        if op == "all-reduce":
            wire = 2 * (g - 1) / g * b
        elif op == "all-gather":
            wire = (g - 1) / g * b
        elif op == "reduce-scatter":
            wire = (g - 1) * b
        elif op == "all-to-all":
            wire = (g - 1) / g * b
        else:  # collective-permute
            wire = b
        out[op] += wire
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class ProbeCost:
    flops: float
    bytes: float
    coll: Dict[str, float]

    def __sub__(self, o):
        return ProbeCost(self.flops - o.flops, self.bytes - o.bytes,
                         {k: self.coll.get(k, 0) - o.coll.get(k, 0)
                          for k in self.coll})

    def scaled(self, f):
        return ProbeCost(self.flops * f, self.bytes * f,
                         {k: v * f for k, v in self.coll.items()})

    def __add__(self, o):
        return ProbeCost(self.flops + o.flops, self.bytes + o.bytes,
                         {k: self.coll.get(k, 0) + o.coll.get(k, 0)
                          for k in self.coll})


def _probe_cfg(cfg: ModelConfig, layers: int) -> ModelConfig:
    return dataclasses.replace(cfg, num_layers=layers, scan_layers=False,
                               use_pallas=False)


def _lower_probe(cfg: ModelConfig, shape_name: str, mesh, layers: int,
                 microbatches: int, impl: str = "einsum") -> ProbeCost:
    seq, batch, mode = SHAPES[shape_name]
    pcfg = _probe_cfg(cfg, layers)
    with mesh_context(mesh):
        if mode == "train":
            b = batch // microbatches
            bt = batch_sds(pcfg, seq, b, mesh, "train")
            st, _ = state_sds(pcfg, mesh)
            pshard = jax.tree.map(lambda s: s.sharding, st["params"])

            def grads_fn(params, batch):
                (l, m), g = jax.value_and_grad(
                    lambda p: loss_fn(pcfg, p, batch, impl=impl),
                    has_aux=True)(params)
                return l, g

            comp = jax.jit(grads_fn, out_shardings=(None, pshard)).lower(
                st["params"], bt).compile()
        else:
            bt = batch_sds(pcfg, seq, batch, mesh, mode)
            pr, _ = params_sds(pcfg, mesh)
            ca, ca_sh = cache_sds(pcfg, batch, seq, mesh)
            fn = (make_prefill_step(pcfg, impl=impl) if mode == "prefill"
                  else make_decode_step(pcfg, impl=impl))
            comp = jax.jit(fn, out_shardings=(None, ca_sh)).lower(
                pr, bt, ca).compile()
    cost = comp.cost_analysis()
    coll = parse_collectives(comp.as_text())
    return ProbeCost(cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
                     coll)


def _local_param_count(cfg: ModelConfig, chips: int) -> float:
    from repro.models import init_params
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = 0
    for l in jax.tree.leaves(shapes):
        n = 1
        for d in l.shape:
            n *= d
        total += n
    return total / chips


def roofline_cell(arch: str, shape_name: str, *, microbatches: int = 1,
                  multi_pod: bool = False,
                  cfg_overrides: Optional[dict] = None,
                  flash_mem: bool = False) -> Dict:
    """``flash_mem=True``: take the memory term from blocked-attention
    probes (the flash/VMEM-resident production path) instead of the
    einsum probes (naive-attention baseline).  FLOPs and collectives always
    come from the einsum probes (exact; attention is collective-free)."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    ok, reason = cell_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    seq, batch, mode = SHAPES[shape_name]
    P = len(cfg.pattern)
    L = cfg.num_layers
    mb = microbatches if mode == "train" else 1

    l0 = _lower_probe(cfg, shape_name, mesh, 0, mb)
    lp = _lower_probe(cfg, shape_name, mesh, P, mb)
    per_mb = l0 + (lp - l0).scaled(L / P)
    total = per_mb.scaled(mb)

    if flash_mem and not cfg.attention_free:
        impl_b = "blocked_static" if mode == "train" else "blocked"
        l0b = _lower_probe(cfg, shape_name, mesh, 0, mb, impl=impl_b)
        lpb = _lower_probe(cfg, shape_name, mesh, P, mb, impl=impl_b)
        per_mb_b = l0b + (lpb - l0b).scaled(L / P)
        total = ProbeCost(total.flops,
                          per_mb_b.scaled(mb).bytes, total.coll)

    if mode == "train":
        n_local = _local_param_count(cfg, chips)
        total = total + ProbeCost(25.0 * n_local, 36.0 * n_local,
                                  {"total": 0.0})

    compute_s = total.flops / HW["peak_flops"]
    memory_s = total.bytes / HW["hbm_bw"]
    coll_s = total.coll["total"] / HW["ici_bw"]
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]

    # MODEL_FLOPS: 6 N D (train) / 2 N D (inference), N = active params
    n_active = cfg.num_active_params()
    tokens = batch * (1 if mode == "decode" else seq)
    mf_coef = 6 if mode == "train" else 2
    model_flops = mf_coef * n_active * tokens
    hlo_flops_global = total.flops * chips
    ratio = model_flops / max(hlo_flops_global, 1.0)

    step_s = max(compute_s, memory_s, coll_s)
    ideal_s = model_flops / (chips * HW["peak_flops"])
    return {
        "arch": arch, "shape": shape_name, "mode": mode, "status": "ok",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "microbatches": mb,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "flops_per_dev": total.flops, "bytes_per_dev": total.bytes,
        "coll_bytes_per_dev": total.coll["total"],
        "coll_breakdown": {k: v for k, v in total.coll.items()
                           if k != "total" and v > 0},
        "model_flops": model_flops,
        "useful_flops_ratio": ratio,
        "roofline_fraction": ideal_s / step_s if step_s else 0.0,
    }


def main(argv=None) -> int:
    import argparse
    from repro.configs import ALL_ARCHS
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    from repro.launch.dryrun import DEFAULT_MICROBATCHES, FALLBACK_MICROBATCHES
    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            mb = DEFAULT_MICROBATCHES.get(arch, FALLBACK_MICROBATCHES) \
                if shape == "train_4k" else 1
            try:
                rec = roofline_cell(arch, shape, microbatches=mb)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "fail",
                       "error": str(e)[:500]}
            if rec["status"] == "ok":
                print(f"[roofline] {arch} x {shape}: "
                      f"compute={rec['compute_s']*1e3:.2f}ms "
                      f"memory={rec['memory_s']*1e3:.2f}ms "
                      f"coll={rec['collective_s']*1e3:.2f}ms "
                      f"dominant={rec['dominant']} "
                      f"useful={rec['useful_flops_ratio']:.2f} "
                      f"roofline={rec['roofline_fraction']*100:.1f}%",
                      flush=True)
            else:
                print(f"[roofline] {arch} x {shape}: {rec['status']} "
                      f"{rec.get('reason', rec.get('error', ''))[:120]}",
                      flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    # run as: XLA_FLAGS=--xla_force_host_platform_device_count=512 \
    #         PYTHONPATH=src python -m repro.launch.roofline --out r.jsonl
    import sys
    sys.exit(main())
