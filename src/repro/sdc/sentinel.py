"""Tier-3 SDC detection: the end-to-end loss sentinel.

The cheapest guard with the widest net: corruption anywhere in params,
optimizer state, or activations that materially changes the computation
eventually shows up in the loss.  The sentinel checks each superstep's
metrics for (a) non-finite loss / grad-norm — the device-side flag from
train/step.py rolls both into one scalar — and (b) a loss spike versus a
running EMA.  It has no ability to localize (that is what tiers 1-2 are
for) but catches what they miss, including flips in un-scrubbed leaves.

The EMA only absorbs healthy observations: a tripping value never updates
it, so the baseline survives the anomaly and rollback-replayed steps are
judged against the pre-corruption level.
"""
from __future__ import annotations

import math
from typing import Optional


class LossSentinel:
    def __init__(self, spike_factor: float = 10.0, ema: float = 0.9,
                 warmup: int = 5):
        self.spike_factor = spike_factor
        self.ema = ema
        self.warmup = warmup
        self.loss_ema: Optional[float] = None
        self.observed = 0
        self.trips = 0

    def observe(self, step: int, loss: float,
                grad_norm: Optional[float] = None,
                nonfinite: Optional[float] = None) -> Optional[str]:
        """Feed one superstep's metrics; returns a reason string when the
        step looks corrupted, else None (and the EMA absorbs the value)."""
        reason = None
        if nonfinite is not None and nonfinite > 0:
            reason = f"non-finite loss/grad at step {step}"
        elif not math.isfinite(loss):
            reason = f"non-finite loss {loss!r} at step {step}"
        elif grad_norm is not None and not math.isfinite(grad_norm):
            reason = f"non-finite grad norm {grad_norm!r} at step {step}"
        elif (self.observed >= self.warmup and self.loss_ema is not None
                and loss > self.spike_factor * max(self.loss_ema, 1e-12)):
            reason = (f"loss spike at step {step}: {loss:.4g} > "
                      f"{self.spike_factor:g} x EMA {self.loss_ema:.4g}")
        if reason is not None:
            self.trips += 1
            return reason
        self.loss_ema = (loss if self.loss_ema is None
                         else self.ema * self.loss_ema + (1 - self.ema) * loss)
        self.observed += 1
        return None
