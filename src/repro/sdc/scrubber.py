"""Tier-2 SDC detection: the rotating state scrubber.

Training state only changes legitimately inside ``train_step``; between the
end of one superstep and the start of the next, every leaf should be
bit-identical.  The scrubber exploits that window: ``record(state, step)``
checksums a rotating subset of leaves right after the update, and
``verify(state)`` recomputes those checksums just before the next update
consumes the state — any difference is memory corruption, pinpointed to
the leaf.  With ``fraction=f`` each call checksums ceil(f * num_leaves)
leaves, so a full-state scrub is amortized over 1/f steps (f=1 covers
every leaf every step; the bench quantifies the cost curve).

The scrubber is windowed, not historical: only the most recent record is
verifiable, because older baselines predate legitimate updates.  Coverage
is therefore probabilistic for f < 1 — a flip in an un-scrubbed leaf rides
until the tier-3 sentinel (or an ABFT matmul) notices its effect.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.sdc.checksum import checksums, named_leaves


class StateScrubber:
    def __init__(self, fraction: float = 0.25):
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        self.fraction = fraction
        self._cursor = 0
        self._window: Dict[str, int] = {}    # leaf name -> checksum
        self._window_step: Optional[int] = None
        self.leaves_scrubbed = 0             # cumulative, for the bench
        self.mismatches: List[str] = []      # every leaf ever flagged

    # ------------------------------------------------------------------
    def _subset(self, names: List[str]) -> List[str]:
        n = len(names)
        k = max(1, math.ceil(n * self.fraction))
        picked = [names[(self._cursor + i) % n] for i in range(min(k, n))]
        self._cursor = (self._cursor + k) % n
        return picked

    def record(self, state, step: int) -> List[str]:
        """Checksum the next rotation subset of ``state``; returns the
        covered leaf names.  Call right after the state is produced."""
        leaves = dict(named_leaves(state))
        subset = self._subset(sorted(leaves))
        self._window = dict(zip(subset, checksums([leaves[n]
                                                   for n in subset])))
        self._window_step = step
        self.leaves_scrubbed += len(subset)
        return subset

    def verify(self, state) -> List[str]:
        """Re-checksum the recorded window against ``state``; returns the
        names of corrupted leaves (empty = clean).  Call before the next
        update consumes the state."""
        if not self._window:
            return []
        leaves = dict(named_leaves(state))
        names = [n for n in self._window if n in leaves]
        got = checksums([leaves[n] for n in names])
        bad = [n for n, g in zip(names, got) if g != self._window[n]]
        self.mismatches.extend(bad)
        return bad

    def full_checksums(self, state) -> Dict[str, int]:
        """Checksum every leaf (save-time verification / debugging)."""
        named = named_leaves(state)
        return dict(zip((n for n, _ in named),
                        checksums([v for _, v in named])))

    def reset(self) -> None:
        """Drop the window (call after a rollback: the restored state is a
        different set of buffers than the recorded one)."""
        self._window = {}
        self._window_step = None
