"""Leaf checksums for the state scrubber.

Device leaves are reduced on device — bitcast to 32-bit storage words and
reduced mod 2^32 with odd position weights (one cheap pass, no
device->host transfer of the data; a single flipped bit changes exactly
one word by ±2^k, hence its block hash by ±2^k*(2j+1) — an odd multiple of
2^k that can never cancel mod 2^32 — so any single-bit upset is caught).
The word view and the reduction live in ``repro/kernels/block_hash`` — the
SAME kernel that detects dirty blocks for incremental checkpoints: a leaf
checksum is the mod-2^32 sum of its block hashes, so scrub and delta share
one pass over the bytes.  Host leaves reuse the zero-copy ``crc32_array`` from
core/io_engine.py.  Either way a leaf's checksum is a plain int, stable
across recomputation on identical bytes.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import numpy as np

from repro.kernels.block_hash.ops import checksum_words


@jax.jit
def _device_sums(leaves):
    return [checksum_words(x) for x in leaves]


def _host_crc(leaf) -> int:
    # deferred: repro.core.__init__ imports repro.sdc (the facade wires the
    # scrubber in), so a module-level import here would be circular
    from repro.core.io_engine import crc32_array

    return crc32_array(np.ascontiguousarray(leaf))


def leaf_checksum(leaf: Any) -> int:
    """Checksum one pytree leaf; device arrays reduce on device."""
    if isinstance(leaf, jax.Array):
        return int(jax.device_get(_device_sums([leaf])[0]))
    return _host_crc(np.asarray(leaf))


def checksums(leaves: List[Any]) -> List[int]:
    """Checksum many leaves: ONE jitted device reduction + one device_get
    for all device leaves (per-leaf dispatch would dominate the scrub cost
    on small states), host crc32 for the rest."""
    dev_idx = [i for i, v in enumerate(leaves) if isinstance(v, jax.Array)]
    out: List[Any] = [None] * len(leaves)
    if dev_idx:
        sums = jax.device_get(_device_sums([leaves[i] for i in dev_idx]))
        for i, s in zip(dev_idx, sums):
            out[i] = int(s)
    for i, v in enumerate(leaves):
        if out[i] is None:
            out[i] = _host_crc(np.asarray(v))
    return out


def named_leaves(tree) -> List[Tuple[str, Any]]:
    """(dotted-name, leaf) pairs — THE checkpoint-manifest naming, so a
    scrubber hit, a bit-flip schedule, and a checkpoint leaf all refer to
    the same thing (delegates to the manifest's own flattener; import
    deferred for the same core<->sdc circularity as _host_crc)."""
    from repro.core.checkpoint import _flatten_named

    return _flatten_named(tree)
