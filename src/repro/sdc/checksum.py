"""Leaf checksums for the state scrubber.

Device leaves are reduced on device — bitcast to unsigned words and summed
mod 2^32 (one cheap pass, no device->host transfer of the data; a single
flipped bit changes exactly one word by ±2^k, which can never cancel mod
2^32, so any single-bit upset is caught).  Host leaves reuse the zero-copy
``crc32_array`` from core/io_engine.py.  Either way a leaf's checksum is a
plain int, stable across recomputation on identical bytes.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _sum32(x) -> jax.Array:
    """Mod-2^32 sum of the array's storage words (uint32 wraparound)."""
    if x.dtype.itemsize == 4:
        w = jax.lax.bitcast_convert_type(x, jnp.uint32)
    elif x.dtype.itemsize == 2:
        w = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    elif x.dtype.itemsize == 1:
        w = jax.lax.bitcast_convert_type(x, jnp.uint8).astype(jnp.uint32)
    else:  # 8-byte dtypes bitcast to a trailing (..., 2) uint32 axis
        w = jax.lax.bitcast_convert_type(x, jnp.uint32)
    return jnp.sum(w, dtype=jnp.uint32)


@jax.jit
def _device_sums(leaves):
    return [_sum32(x) for x in leaves]


def _host_crc(leaf) -> int:
    # deferred: repro.core.__init__ imports repro.sdc (the facade wires the
    # scrubber in), so a module-level import here would be circular
    from repro.core.io_engine import crc32_array

    return crc32_array(np.ascontiguousarray(leaf))


def leaf_checksum(leaf: Any) -> int:
    """Checksum one pytree leaf; device arrays reduce on device."""
    if isinstance(leaf, jax.Array):
        return int(jax.device_get(_device_sums([leaf])[0]))
    return _host_crc(np.asarray(leaf))


def checksums(leaves: List[Any]) -> List[int]:
    """Checksum many leaves: ONE jitted device reduction + one device_get
    for all device leaves (per-leaf dispatch would dominate the scrub cost
    on small states), host crc32 for the rest."""
    dev_idx = [i for i, v in enumerate(leaves) if isinstance(v, jax.Array)]
    out: List[Any] = [None] * len(leaves)
    if dev_idx:
        sums = jax.device_get(_device_sums([leaves[i] for i in dev_idx]))
        for i, s in zip(dev_idx, sums):
            out[i] = int(s)
    for i, v in enumerate(leaves):
        if out[i] is None:
            out[i] = _host_crc(np.asarray(v))
    return out


def named_leaves(tree) -> List[Tuple[str, Any]]:
    """(dotted-name, leaf) pairs — THE checkpoint-manifest naming, so a
    scrubber hit, a bit-flip schedule, and a checkpoint leaf all refer to
    the same thing (delegates to the manifest's own flattener; import
    deferred for the same core<->sdc circularity as _host_crc)."""
    from repro.core.checkpoint import _flatten_named

    return _flatten_named(tree)
