"""repro.sdc — silent-data-corruption detection (docs/sdc.md).

Three tiers, cheapest-coverage to strongest-localization:
  tier 1  ABFT checksummed matmul   repro/kernels/abft_matmul (impl="abft")
  tier 2  StateScrubber             rotating checksum scrub over the state
  tier 3  LossSentinel              non-finite / loss-spike guard (training)
          DecodeSentinel            non-finite / entropy-spike logit guard
                                    (serving decode path, docs/serving.md)

Detection raises ``repro.core.failures.CorruptionDetected``; the recovery
path in core/coordinator.run_with_recovery rolls back to the last
checksum-verified checkpoint.  ABFT single-element hits are corrected in
place and never surface.
"""
from repro.sdc.checksum import checksums, leaf_checksum, named_leaves
from repro.sdc.decode_sentinel import DecodeSentinel
from repro.sdc.scrubber import StateScrubber
from repro.sdc.sentinel import LossSentinel

__all__ = ["StateScrubber", "LossSentinel", "DecodeSentinel", "checksums",
           "leaf_checksum", "named_leaves"]
