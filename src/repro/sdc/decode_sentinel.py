"""Tier-3 SDC detection for the decode path: the logit sentinel.

Serving has no loss to watch, but it has the same end-to-end signal: the
logits every decode step produces.  Corruption on a replica — a flipped
bit in its params copy, a bad cache row, a broken MXU tile — shows up as
(a) non-finite logits, or (b) a softmax-entropy spike toward log(V): a
scrambled linear map sends inputs to near-noise, and noise logits are
near-uniform.  The sentinel is the serving sibling of ``LossSentinel``:
one observation per decode step per replica, an EMA baseline that only
absorbs healthy observations, and a reason string when a step trips.

It cannot localize which request's row is corrupt (the cache pool is one
tensor), so the router treats a trip as a REPLICA failure: exclude the
replica, drain its requests, re-execute them on survivors — greedy decode
makes the retried streams token-identical (docs/serving.md).
"""
from __future__ import annotations

import math
from typing import Optional


class DecodeSentinel:
    def __init__(self, spike_factor: float = 4.0, ema: float = 0.9,
                 warmup: int = 8, abs_max_entropy: Optional[float] = None):
        """``spike_factor``: trip when entropy > factor x EMA (after
        ``warmup`` healthy observations).  ``abs_max_entropy``: optional
        hard ceiling (e.g. 0.95 * log(vocab)) that trips even during
        warmup — a replica can come up corrupted."""
        self.spike_factor = spike_factor
        self.ema = ema
        self.warmup = warmup
        self.abs_max_entropy = abs_max_entropy
        self.entropy_ema: Optional[float] = None
        self.observed = 0
        self.trips = 0

    def observe(self, step: int, nonfinite: float,
                entropy: float) -> Optional[str]:
        """Feed one decode step's aggregated stats (max nonfinite flag and
        mean entropy over the ACTIVE rows); returns a trip reason or None
        (and the EMA absorbs the healthy value)."""
        reason = None
        if nonfinite > 0:
            reason = f"non-finite logits at decode step {step}"
        elif not math.isfinite(entropy):
            reason = f"non-finite entropy {entropy!r} at decode step {step}"
        elif (self.abs_max_entropy is not None
                and entropy > self.abs_max_entropy):
            reason = (f"entropy {entropy:.4g} above ceiling "
                      f"{self.abs_max_entropy:.4g} at decode step {step}")
        elif (self.observed >= self.warmup and self.entropy_ema is not None
                and entropy > self.spike_factor
                * max(self.entropy_ema, 1e-12)):
            reason = (f"entropy spike at decode step {step}: {entropy:.4g} "
                      f"> {self.spike_factor:g} x EMA {self.entropy_ema:.4g}")
        if reason is not None:
            self.trips += 1
            return reason
        self.entropy_ema = (entropy if self.entropy_ema is None
                            else self.ema * self.entropy_ema
                            + (1 - self.ema) * entropy)
        self.observed += 1
        return None

    def reset(self) -> None:
        """A replacement replica is a different set of buffers: start the
        baseline over."""
        self.entropy_ema = None
        self.observed = 0
