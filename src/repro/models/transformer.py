"""Generic model stack: runs every assigned architecture.

One parameter/forward implementation covering dense & MoE transformers
(GQA/MQA, qkv-bias, GeGLU/SwiGLU, sliding-window, local/global alternation,
attention & final logit soft-capping, RoPE / M-RoPE), Mamba-1 SSM stacks,
RG-LRU hybrids and bidirectional encoders.  Layer kinds come from
``cfg.pattern``; homogeneous stacks are scanned (stacked params, O(1-layer)
HLO), heterogeneous/small stacks can unroll (``cfg.scan_layers=False``).

Modes: ``train`` (logits only), ``prefill`` (logits + filled KV cache),
``decode`` (one token against the cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.layers.attention import decode_mha, mha
from repro.layers.mlp import mlp_apply, mlp_init, _act
from repro.layers.moe import moe_apply, moe_init
from repro.layers.norms import rms_norm
from repro.layers.rope import apply_mrope, apply_rope, make_positions
from repro.models.base import BIDIR, FULL, LOCAL, REC, SSM, ModelConfig
from repro.models.mamba import ssm_apply, ssm_cache_init, ssm_init
from repro.models.rglru import rec_apply, rec_cache_init, rec_init
from repro.sharding.api import U, constrain
from repro.sharding.rules import DP_AXES, TP, gathered, res_spec


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _attn_layer_init(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.effective_num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 8)
    pd = cfg.param_dtype
    s = d ** -0.5
    attn = {
        "wq": (jax.random.normal(ks[0], (d, h, hd)) * s).astype(pd),
        "wk": (jax.random.normal(ks[1], (d, kv, hd)) * s).astype(pd),
        "wv": (jax.random.normal(ks[2], (d, kv, hd)) * s).astype(pd),
        "wo": (jax.random.normal(ks[3], (h, hd, d)) * (h * hd) ** -0.5).astype(pd),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((h, hd), pd)
        attn["bk"] = jnp.zeros((kv, hd), pd)
        attn["bv"] = jnp.zeros((kv, hd), pd)
    p: Dict[str, Any] = {"ln1": jnp.ones((d,), pd), "attn": attn,
                         "ln2": jnp.ones((d,), pd)}
    if cfg.sandwich_norm:
        p["ln1_post"] = jnp.ones((d,), pd)
        p["ln2_post"] = jnp.ones((d,), pd)
    if cfg.num_experts:
        p["moe"] = moe_init(ks[4], d, cfg.d_ff, cfg.num_experts, pd)
    else:
        p["mlp"] = mlp_init(ks[4], d, cfg.d_ff, cfg.mlp_act, pd)
    return p


def _layer_init(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    if kind in (FULL, LOCAL, BIDIR):
        return _attn_layer_init(key, cfg, kind)
    if kind == SSM:
        return {"ln": jnp.ones((cfg.d_model,), cfg.param_dtype),
                "ssm": ssm_init(key, cfg)}
    if kind == REC:
        return rec_init(key, cfg)
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    kinds = cfg.layer_kinds()
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if not cfg.embedding_inputs:
        params["embed"] = {
            "tok": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model))
                    * cfg.d_model ** -0.5).astype(cfg.param_dtype)}
    if cfg.scan_layers:
        P_ = len(cfg.pattern)
        assert cfg.num_layers % P_ == 0, (cfg.name, cfg.num_layers, P_)
        G = cfg.num_layers // P_
        gkeys = jax.random.split(keys[1], G)

        def one_block(k):
            sub = jax.random.split(k, P_)
            return {f"l{p}": _layer_init(sub[p], cfg, cfg.pattern[p])
                    for p in range(P_)}

        params["blocks"] = jax.vmap(one_block)(gkeys)
    else:
        lkeys = jax.random.split(keys[1], cfg.num_layers)
        params["layers"] = {f"layer_{i}": _layer_init(lkeys[i], cfg, kinds[i])
                            for i in range(cfg.num_layers)}
    params["final_norm"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[2],
                             (cfg.d_model, cfg.padded_vocab))
                             * cfg.d_model ** -0.5).astype(cfg.param_dtype)
    return params


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def _attn_cache_init(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    sc = cache_len if (kind != LOCAL or not cfg.window) \
        else min(cache_len, cfg.window)
    kv = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, sc, kv, hd), cfg.dtype),
        "v": jnp.zeros((batch, sc, kv, hd), cfg.dtype),
        "pos": jnp.full((sc,), -1, jnp.int32),
    }


def _cache_entry_init(cfg, kind, batch, cache_len):
    if kind in (FULL, LOCAL, BIDIR):
        return _attn_cache_init(cfg, kind, batch, cache_len)
    if kind == SSM:
        return ssm_cache_init(cfg, batch)
    if kind == REC:
        return rec_cache_init(cfg, batch)
    raise ValueError(kind)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int):
    """Block-paged KV pool (serve/page_table.py): one shared pool of
    ``num_pages`` pages of ``page_size`` tokens per attention layer, in
    place of per-request contiguous rows.  Page 0 is the reserved null
    page.  Only attention stacks page — SSM/REC state has no sequence
    axis to page over (the legacy slot pool still serves those)."""
    kinds = cfg.layer_kinds()
    bad = sorted({k for k in kinds if k not in (FULL, LOCAL)})
    if bad:
        raise ValueError(f"paged KV cache needs an attention-only decode "
                         f"stack; {cfg.name} has {bad} layers")
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def entry():
        return {"k": jnp.zeros((num_pages, page_size, kv, hd), cfg.dtype),
                "v": jnp.zeros((num_pages, page_size, kv, hd), cfg.dtype)}

    if cfg.scan_layers:
        P_ = len(cfg.pattern)
        G = cfg.num_layers // P_

        def stack(e):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (G,) + x.shape), e)

        return {"blocks": {f"l{p}": stack(entry()) for p in range(P_)}}
    return {"layers": {f"layer_{i}": entry()
                       for i in range(cfg.num_layers)}}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    kinds = cfg.layer_kinds()
    if cfg.scan_layers:
        P_ = len(cfg.pattern)
        G = cfg.num_layers // P_

        def stack(entry):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (G,) + x.shape), entry)

        blocks = {f"l{p}": stack(_cache_entry_init(cfg, cfg.pattern[p],
                                                   batch, cache_len))
                  for p in range(P_)}
        return {"blocks": blocks, "index": jnp.zeros((), jnp.int32)}
    layers = {f"layer_{i}": _cache_entry_init(cfg, kinds[i], batch, cache_len)
              for i in range(cfg.num_layers)}
    return {"layers": layers, "index": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------

def _project(h, w, bias, cd, impl=None):
    if impl == "abft":
        from repro.kernels.abft_matmul.ops import abft_dot

        d, nh, hd = w.shape
        y = abft_dot(h, w.astype(cd).reshape(d, nh * hd))
        y = y.reshape(h.shape[:-1] + (nh, hd))
    else:
        y = jnp.einsum("bsd,dhk->bshk", h, w.astype(cd))
    if bias is not None:
        y = y + bias.astype(cd)
    return y


def _rope_q_k(cfg, q, k, positions):
    if cfg.mrope_sections:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _head_mask(cfg: ModelConfig):
    """(He,) mask zeroing TP-padding q-heads (see base.effective_num_heads)."""
    he, k = cfg.effective_num_heads, max(cfg.num_kv_heads, 1)
    if he == cfg.num_heads:
        return None
    gp, g = he // k, cfg.num_heads // k
    return (jnp.arange(he) % gp < g).astype(cfg.dtype)


def _attn_apply(p, x, kind, cfg: ModelConfig, positions, cache=None,
                impl="auto", page_tables=None):
    cd = cfg.dtype
    a = p["attn"]
    B, S = x.shape[0], x.shape[1]
    scale = cfg.query_scale or None
    window = cfg.window if kind == LOCAL else 0
    hmask = _head_mask(cfg)
    # impl="abft" opts the projection matmuls into the checksummed kernel
    # (docs/sdc.md tier 1); the attention core itself falls back to "auto"
    proj_impl = "abft" if impl == "abft" else None
    if impl == "abft":
        impl = "auto"

    # SP: gather the bf16 residual BEFORE the norm — a gather placed after
    # would let GSPMD reshard the norm's fp32 internals (2x wire bytes).
    h = rms_norm(gathered(cfg, x), p["ln1"], cfg.norm_eps,
                 use_pallas=cfg.use_pallas)
    q = _project(h, a["wq"], a.get("bq"), cd, impl=proj_impl)
    k = _project(h, a["wk"], a.get("bk"), cd, impl=proj_impl)
    v = _project(h, a["wv"], a.get("bv"), cd, impl=proj_impl)
    q = constrain(q, P(DP_AXES, U, TP, U))
    if kind != BIDIR or cfg.rope_theta > 0:
        q, k = _rope_q_k(cfg, q, k, positions)

    new_cache = None
    if page_tables is not None:                          # paged decode
        from repro.kernels.paged_attention.ops import paged_decode_attention

        ps = cache["k"].shape[1]
        lengths = positions[:, 0].astype(jnp.int32)      # (R,)
        ridx = jnp.arange(B)
        # write this step's k/v at logical position lengths[r]; inactive
        # rows (zeroed table, length 0) land on the null page 0, which the
        # length mask keeps out of every real request's softmax
        pidx = page_tables[ridx, lengths // ps]
        off = lengths % ps
        kc = cache["k"].at[pidx, off].set(k[:, 0].astype(cd))
        vc = cache["v"].at[pidx, off].set(v[:, 0].astype(cd))
        o = paged_decode_attention(
            q, kc, vc, page_tables, lengths, window=window,
            softcap=cfg.attn_softcap, scale=scale,
            impl=("pallas" if impl == "pallas" else "ref"))
        new_cache = {"k": kc, "v": vc}
    elif cache is not None and S == 1:                   # decode
        sc = cache["k"].shape[1]
        cur = positions[0, 0, 0] if cfg.mrope_sections else positions[0, 0]
        slot = cur % sc
        kc = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cd), slot, 1)
        vc = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cd), slot, 1)
        pos = cache["pos"].at[slot].set(cur)
        o = decode_mha(q, kc, vc, pos, cur, window=window,
                       softcap=cfg.attn_softcap, scale=scale)
        new_cache = {"k": kc, "v": vc, "pos": pos}
    else:
        o = mha(q, k, v, causal=(kind != BIDIR), window=window,
                softcap=cfg.attn_softcap, scale=scale, impl=impl)
        if cache is not None:                            # prefill fills cache
            sc = cache["k"].shape[1]
            if sc >= S:
                kc = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cd), 0, 1)
                vc = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cd), 0, 1)
                pos = cache["pos"].at[:S].set(jnp.arange(S, dtype=jnp.int32))
            else:                                        # rolling window cache
                tail_pos = jnp.arange(S - sc, S, dtype=jnp.int32)
                slots = tail_pos % sc
                kc = cache["k"].at[:, slots].set(k[:, S - sc:].astype(cd))
                vc = cache["v"].at[:, slots].set(v[:, S - sc:].astype(cd))
                pos = cache["pos"].at[slots].set(tail_pos)
            new_cache = {"k": kc, "v": vc, "pos": pos}

    if hmask is not None:
        o = o * hmask[None, None, :, None]
    # pin o (and via transpose its cotangent) to head-TP sharding: keeps the
    # backward dot aligned with wo's "model" sharding (see mlp_apply)
    o = constrain(o, P(DP_AXES, U, TP, U))
    if proj_impl == "abft":
        from repro.kernels.abft_matmul.ops import abft_dot

        nh, hd, d = a["wo"].shape
        o = abft_dot(o.reshape(B, S, nh * hd),
                     a["wo"].astype(cd).reshape(nh * hd, d))
    else:
        o = jnp.einsum("bshk,hkd->bsd", o, a["wo"].astype(cd))
    if cfg.sandwich_norm:
        o = rms_norm(o, p["ln1_post"], cfg.norm_eps)
    x = x + o
    x = constrain(x, res_spec(cfg))

    h2 = rms_norm(gathered(cfg, x), p["ln2"], cfg.norm_eps,
                  use_pallas=cfg.use_pallas)
    aux = jnp.zeros((), jnp.float32)
    if cfg.num_experts:
        m, aux = moe_apply(p["moe"], h2, num_experts=cfg.num_experts,
                           k=cfg.experts_per_token,
                           capacity_factor=cfg.capacity_factor,
                           act=_act(cfg.mlp_act), compute_dtype=cd,
                           dead_experts=cfg.dead_experts)
    else:
        m = mlp_apply(p["mlp"], h2, cfg.mlp_act, cd, impl=proj_impl)
    if cfg.sandwich_norm:
        m = rms_norm(m, p["ln2_post"], cfg.norm_eps)
    x = x + m
    x = constrain(x, res_spec(cfg))
    return x, new_cache, aux


def _apply_layer(p, x, kind, cfg, positions, cache=None, impl="auto",
                 page_tables=None):
    if kind in (FULL, LOCAL, BIDIR):
        return _attn_apply(p, x, kind, cfg, positions, cache, impl,
                           page_tables)
    if kind == SSM:
        y, nc = ssm_apply(p, x, cfg, cache, use_pallas=cfg.use_pallas)
        return y, nc, jnp.zeros((), jnp.float32)
    if kind == REC:
        y, nc = rec_apply(p, x, cfg, cache)
        return y, nc, jnp.zeros((), jnp.float32)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------

def _embed_lookup(cfg, table, tokens):
    """Vocab-sharded embedding lookup.

    A plain gather over the model-sharded vocab dim makes GSPMD all-gather
    the WHOLE table (hundreds of MB per step).  Instead: shard_map over
    "model" — each shard looks up its local rows masked, then one psum of
    the (B,S,D) activations (EXPERIMENTS.md S Perf)."""
    from repro.sharding.api import current_mesh

    mesh = current_mesh()
    tp = (dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
          if mesh is not None else 1)
    if tp <= 1 or table.shape[0] % tp != 0:
        return table[tokens]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]
    b_ax = dp_axes if (dp > 1 and tokens.shape[0] % dp == 0) else None
    local_v = table.shape[0] // tp

    def f(tab, tok):
        lo = jax.lax.axis_index("model") * local_v
        ids = tok - lo
        ok = (ids >= 0) & (ids < local_v)
        vals = tab[jnp.clip(ids, 0, local_v - 1)]
        vals = jnp.where(ok[..., None], vals, jnp.zeros((), tab.dtype))
        return jax.lax.psum(vals, "model")

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map
    kws = dict(mesh=mesh, in_specs=(P(TP, None), P(b_ax, None)),
               out_specs=P(b_ax, None, None))
    try:
        sm = shard_map(f, check_vma=False, **kws)
    except TypeError:
        sm = shard_map(f, check_rep=False, **kws)
    return sm(table, tokens)


def _embed_in(cfg, params, batch):
    cd = cfg.dtype
    if cfg.embedding_inputs:
        x = batch["embeddings"].astype(cd)
    else:
        x = _embed_lookup(cfg, params["embed"]["tok"].astype(cd),
                          batch["tokens"])
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cd)
    return constrain(x, res_spec(cfg))


def _logits_out(cfg, params, x):
    cd = cfg.dtype
    x = rms_norm(gathered(cfg, x), params["final_norm"], cfg.norm_eps,
                 use_pallas=cfg.use_pallas)
    if cfg.tie_embeddings and not cfg.embedding_inputs:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(cd))
    else:
        logits = x @ params["lm_head"].astype(cd)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return constrain(logits, P(DP_AXES, None, TP))


def _positions_for(cfg, batch, S, offset=0):
    if cfg.mrope_sections:
        if "positions" in batch:
            return batch["positions"]
        B = (batch.get("tokens") if "tokens" in batch
             else batch["embeddings"]).shape[0]
        pos = make_positions(B, S, offset)
        return jnp.broadcast_to(pos[None], (3,) + pos.shape)
    B = (batch.get("tokens") if "tokens" in batch
         else batch["embeddings"]).shape[0]
    return make_positions(B, S, offset)


# Recurrence-dynamics leaves stay fp32 (exp() of these is sensitive).
_KEEP_FP32 = ("A_log", "D", "lam")


def _cast_params(cfg: ModelConfig, params):
    """Cast float32 weights to the compute dtype ONCE, outside the
    remat/scan region, and PIN the cast outputs to the parameter sharding.
    Without the pin, GSPMD propagates the consumers' replicated sharding
    backward through the elementwise cast and all-gathers fp32 weights
    (2x the wire bytes) — measured in EXPERIMENTS.md S Perf."""
    if cfg.dtype == jnp.float32:
        return params

    from repro.sharding.api import current_mesh
    from repro.sharding.rules import param_specs

    mesh = current_mesh()
    specs = None
    if mesh is not None and "model" in mesh.axis_names:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        specs = param_specs(cfg, tp)

    def cast(path, w, spec=None):
        name = getattr(path[-1], "key", "") if path else ""
        if w.dtype == jnp.float32 and name not in _KEEP_FP32:
            w = w.astype(cfg.dtype)
            if spec is not None:
                w = constrain(w, spec)
        return w

    if specs is None:
        return jax.tree_util.tree_map_with_path(cast, params)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s), (len(flat_p), len(flat_s))
    rebuilt = [cast(path, w, s) for (path, w), s in zip(flat_p, flat_s)]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), rebuilt)


def forward(cfg: ModelConfig, params, batch, *, mode: str = "train",
            cache=None, impl: Optional[str] = None):
    """Returns (logits, new_cache, aux_loss).  new_cache is None in train."""
    impl = impl or ("pallas" if cfg.use_pallas else "auto")
    params = _cast_params(cfg, params)
    x = _embed_in(cfg, params, batch)
    B, S = x.shape[0], x.shape[1]
    page_tables = None
    if mode == "paged_decode":
        if cfg.mrope_sections:
            raise ValueError("paged decode does not support M-RoPE")
        # one token per request at its own position; the page table maps
        # logical positions onto the shared pool (init_paged_cache)
        page_tables = batch["page_tables"]
        positions = batch["lengths"].astype(jnp.int32)[:, None]   # (R, 1)
    elif mode == "decode":
        offset = cache["index"]
        positions = _positions_for(cfg, batch, 1, offset)
    else:
        positions = _positions_for(cfg, batch, S)

    kinds = cfg.layer_kinds()
    aux_total = jnp.zeros((), jnp.float32)

    remat_on = cfg.remat and mode == "train"

    # Per-layer remat: each layer recomputes from its own input in the
    # backward pass (saved residual = one (B,S,D) tensor per layer).
    def apply_one(p, xc, kind, entry, layer_remat=True):
        fn = functools.partial(_apply_layer, impl=impl,
                               page_tables=page_tables)
        if remat_on and layer_remat:
            fn = jax.checkpoint(fn, static_argnums=(2, 3), prevent_cse=False)
        return fn(p, xc, kind, cfg, positions, entry)

    if cfg.scan_layers:
        P_ = len(cfg.pattern)
        # Short patterns: checkpoint the whole scan body (one residual per
        # block, measurably lower peak).  Long patterns (recurrentgemma's 13):
        # per-layer checkpoints to bound the recompute live-set.
        block_level = P_ <= 2

        def block_fn(carry, xs):
            xc, aux = carry
            blk_params, blk_cache = xs
            new_entries = {}
            for pi in range(P_):
                entry = None if blk_cache is None else blk_cache[f"l{pi}"]
                xc, nc, a = apply_one(blk_params[f"l{pi}"], xc,
                                      cfg.pattern[pi], entry,
                                      layer_remat=not block_level)
                aux = aux + a
                if nc is not None:
                    new_entries[f"l{pi}"] = nc
            return (xc, aux), (new_entries if new_entries else None)

        fn = block_fn
        if remat_on and block_level:
            fn = jax.checkpoint(block_fn, prevent_cse=False)
        blk_cache_xs = cache["blocks"] if cache is not None else None
        (x, aux_total), ys = lax.scan(
            fn, (x, aux_total), (params["blocks"], blk_cache_xs))
        new_cache = None
        if cache is not None:
            if mode == "paged_decode":                   # pool has no index
                new_cache = {"blocks": ys}
            else:
                new_cache = {"blocks": ys,
                             "index": cache["index"]
                             + (S if mode != "decode" else 1)}
    else:
        new_layers = {}
        for i in range(cfg.num_layers):
            name = f"layer_{i}"
            entry = None if cache is None else cache["layers"][name]
            x, nc, a = apply_one(params["layers"][name], x, kinds[i], entry)
            aux_total = aux_total + a
            if nc is not None:
                new_layers[name] = nc
        new_cache = None
        if cache is not None:
            if mode == "paged_decode":                   # pool has no index
                new_cache = {"layers": new_layers}
            else:
                new_cache = {"layers": new_layers,
                             "index": cache["index"]
                             + (S if mode != "decode" else 1)}

    logits = _logits_out(cfg, params, x)
    return logits, new_cache, aux_total
