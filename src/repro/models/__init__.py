from repro.models.base import (
    BIDIR,
    FULL,
    LOCAL,
    REC,
    SSM,
    ModelConfig,
    get_config,
    list_archs,
    register,
)
from repro.models.transformer import (forward, init_cache,
                                      init_paged_cache, init_params)

__all__ = [
    "ModelConfig",
    "get_config",
    "list_archs",
    "register",
    "forward",
    "init_cache",
    "init_paged_cache",
    "init_params",
    "FULL",
    "LOCAL",
    "BIDIR",
    "SSM",
    "REC",
]
