"""Mamba-1 selective-SSM layer (falcon-mamba-7b family).

Recurrence: ``h_t = exp(dt_t A) h_{t-1} + (dt_t B_t) x_t``;  ``y_t = C_t . h_t
+ D x_t`` with diagonal A, per-channel dt.  Training/prefill uses a chunked
associative scan (chunk = ``SCAN_CHUNK``): only (B, Q, Di, N) is live per
chunk, (B, nchunks, Di, N) across chunks — TPU-native adaptation of the CUDA
fused scan (see DESIGN.md).  Decode is a single fused recurrence step.

The projections dominate FLOPs (>99%); the recurrence is elementwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.layers.norms import rms_norm
from repro.sharding.api import U, constrain
from repro.sharding.rules import DP_AXES, TP, gathered, res_spec

SCAN_CHUNK = 128


def ssm_init(key, cfg):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, w = cfg.resolved_dt_rank, cfg.conv_width
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (w, di)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[2], (di, dtr + 2 * n)) * di ** -0.5).astype(dt),
        "dt_w": (jax.random.normal(ks[3], (dtr, di)) * dtr ** -0.5).astype(dt),
        "dt_b": jnp.full((di,), -4.6, dt),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(dt),
        "D": jnp.ones((di,), dt),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * di ** -0.5).astype(dt),
    }


def _causal_conv(x, conv_w, conv_b, state=None):
    """Depthwise causal conv over time via shifted adds.

    x: (B,S,C); conv_w: (W,C).  With ``state`` (B,W-1,C) prepended (decode /
    chunk streaming), returns (y, new_state)."""
    W = conv_w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for w in range(W):
        y = y + xp[:, w : w + S].astype(jnp.float32) * conv_w[w].astype(jnp.float32)
    y = (y + conv_b.astype(jnp.float32)).astype(x.dtype)
    new_state = xp[:, S : S + W - 1] if S >= W - 1 else xp[:, -(W - 1):]
    return y, new_state


def _chunks(x, nc, Q):
    """(B,S,...) -> (nc,B,Q,...) for lax.scan over chunks."""
    Bd = x.shape[0]
    return jnp.moveaxis(x.reshape((Bd, nc, Q) + x.shape[2:]), 1, 0)


def _comb(l, r):
    al, bl = l
    ar_, br_ = r
    return al * ar_, bl * ar_ + br_


def _scan_chunked(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a,b: (B,S,...) fp32.
    Returns (h_all (B,S,...), h_last).  sqrt-memory: outer lax.scan over
    chunks with a rematerialized (jax.checkpoint) chunk body; only per-chunk
    carries persist in the backward pass."""
    Bd, S = a.shape[0], a.shape[1]
    Q = min(SCAN_CHUNK, S)
    if S % Q:
        Q = S  # tiny/odd shapes: single chunk
    nc = S // Q

    @jax.checkpoint
    def chunk(h, ab):
        ac, bc = ab  # (B,Q,...)
        bc0 = bc.at[:, 0].add(ac[:, 0] * h)
        _, hh = lax.associative_scan(_comb, (ac, bc0), axis=1)
        return hh[:, -1], hh

    h_last, h_all = lax.scan(chunk, h0, (_chunks(a, nc, Q), _chunks(b, nc, Q)))
    h_all = jnp.moveaxis(h_all, 0, 1).reshape((Bd, S) + a.shape[2:])
    return h_all, h_last


def _ssm_chunked(dt, xf, bm, cm, A, h0):
    """Memory-lean Mamba scan: never materializes (B,S,Di,N).

    dt, xf: (B,S,Di) f32; bm, cm: (B,S,N) f32; A: (Di,N); h0: (B,Di,N).
    The (B,Q,Di,N) decay/input tensors are built INSIDE the checkpointed
    chunk body, so only (B,S,Di)-sized inputs and per-chunk state snapshots
    survive to the backward pass.  Returns (y (B,S,Di), h_last)."""
    Bd, S, Di = xf.shape
    Q = min(SCAN_CHUNK, S)
    if S % Q:
        Q = S
    nc = S // Q

    @jax.checkpoint
    def chunk(h, inp):
        dt_q, x_q, b_q, c_q = inp                       # (B,Q,Di) / (B,Q,N)
        a = jnp.exp(dt_q[..., None] * A)                # (B,Q,Di,N)
        b = (dt_q * x_q)[..., None] * b_q[:, :, None, :]
        b = b.at[:, 0].add(a[:, 0] * h)
        _, hh = lax.associative_scan(_comb, (a, b), axis=1)
        y = jnp.einsum("bqdn,bqn->bqd", hh, c_q)
        return hh[:, -1], y

    h_last, ys = lax.scan(chunk, h0, (_chunks(dt, nc, Q), _chunks(xf, nc, Q),
                                      _chunks(bm, nc, Q), _chunks(cm, nc, Q)))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bd, S, Di)
    return y, h_last


def ssm_apply(p, x, cfg, cache=None, *, use_pallas=False):
    """x: (B,S,D).  cache: {"conv": (B,W-1,Di), "h": (B,Di,N)} or None.
    Returns (y, new_cache)."""
    B, S, D = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    dtr = cfg.resolved_dt_rank
    cd = cfg.dtype

    # SP: gather before the norm (bf16 edge; see transformer._attn_apply)
    h_in = rms_norm(gathered(cfg, x), p["ln"], cfg.norm_eps)
    xz = h_in @ p["ssm"]["in_proj"].astype(cd)               # (B,S,2Di)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(xi, P(DP_AXES, U, TP))
    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, p["ssm"]["conv_w"], p["ssm"]["conv_b"],
                                conv_state)
    xi = jax.nn.silu(xi)

    bcd = xi @ p["ssm"]["x_proj"].astype(cd)                 # (B,S,dtr+2N)
    dt = jax.nn.softplus(
        bcd[..., :dtr] @ p["ssm"]["dt_w"].astype(cd)
        + p["ssm"]["dt_b"].astype(cd)).astype(jnp.float32)   # (B,S,Di)
    Bm = bcd[..., dtr : dtr + n].astype(jnp.float32)         # (B,S,N)
    Cm = bcd[..., dtr + n :].astype(jnp.float32)             # (B,S,N)
    A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))      # (Di,N)
    xf = xi.astype(jnp.float32)

    if S == 1 and cache is not None:
        a = jnp.exp(dt[:, 0, :, None] * A)                   # (B,Di,N)
        b = (dt[:, 0] * xf[:, 0])[..., None] * Bm[:, 0][:, None, :]
        h_new = a * cache["h"] + b                           # (B,Di,N)
        y = jnp.einsum("bdn,bn->bd", h_new, Cm[:, 0])[:, None]
        h_all_last = h_new
    else:
        h0 = cache["h"] if cache is not None \
            else jnp.zeros((B, di, n), jnp.float32)
        if use_pallas:
            from repro.kernels.selective_scan import ops as _ss
            y, h_all_last = _ss.selective_scan(xf, dt, Bm, Cm, A, h0)
        else:
            y, h_all_last = _ssm_chunked(dt, xf, Bm, Cm, A, h0)
    y = y + p["ssm"]["D"].astype(jnp.float32) * xf
    y = (y.astype(cd)) * jax.nn.silu(z)
    out = y @ p["ssm"]["out_proj"].astype(cd)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": h_all_last}
    return constrain(x + out, res_spec(cfg)), new_cache


def ssm_cache_init(cfg, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), cfg.dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
