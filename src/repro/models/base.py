"""Model configuration + registry.

Every assigned architecture is expressed as a ``ModelConfig``.  The config is a
frozen dataclass so it can be closed over by jit'd functions and hashed as a
static argument.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

# Layer kinds used in block patterns.
FULL = "full"          # full (global) causal attention
LOCAL = "local"        # sliding-window attention
BIDIR = "bidir"        # bidirectional full attention (encoder)
REC = "rec"            # RG-LRU recurrent block
SSM = "ssm"            # Mamba-1 selective-SSM block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    # --- attention features ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) sections of head_dim/2
    window: int = 0                        # sliding-window size (0 = no SWA anywhere)
    pattern: Tuple[str, ...] = (FULL,)     # repeating per-layer kinds
    attn_softcap: float = 0.0              # gemma2 attention-logit soft capping
    final_softcap: float = 0.0             # gemma2 final-logit soft capping
    query_scale: float = 0.0               # 0 => 1/sqrt(head_dim)
    # --- mlp ---
    mlp_act: str = "silu"                  # silu (SwiGLU) | gelu (GeGLU) | gelu_plain
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    dead_experts: Tuple[int, ...] = ()    # expert ids lost to failures:
                                          # masked out of routing, capacity
                                          # computed from the live count
    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0                       # 0 => ceil(d_model / 16)
    # --- hybrid (RG-LRU) ---
    lru_width: int = 0
    # --- embeddings / head ---
    embedding_inputs: bool = False         # vlm/audio: input is precomputed embeddings
    tie_embeddings: bool = True
    embed_scale: bool = False              # gemma-style sqrt(d_model) embed scaling
    sandwich_norm: bool = False            # gemma2 post-attn/post-mlp norms
    norm_eps: float = 1e-6
    # --- execution ---
    pad_heads_to: int = 0       # pad q-heads per KV group for even TP sharding
    seq_shard: bool = False     # Megatron-style SP: residuals sharded over
                                # "model" on the sequence dim (norms run
                                # sharded; gather before proj, reduce-scatter
                                # after) — shrinks saved activations by tp
    scan_layers: bool = True
    remat: bool = True
    use_pallas: bool = False               # pallas kernels (TPU target / interpret tests)
    param_dtype: Any = jnp.float32
    dtype: Any = jnp.bfloat16

    # ----- derived -----
    @property
    def padded_vocab(self) -> int:
        """Vocab padded so logits shard evenly over TP and per-shard size is
        lane-aligned (multiple of 2048 = 16 shards x 128 lanes)."""
        mult = 2048 if self.vocab_size > 2048 else 128
        return -(-self.vocab_size // mult) * mult

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def effective_num_heads(self) -> int:
        """q-head count after TP padding (real heads sit in the first
        ``num_heads/num_kv_heads`` slots of each KV group; padded slots are
        masked to zero so the math equals the unpadded model — the padding
        waste appears honestly in per-device FLOPs)."""
        if self.pad_heads_to and self.pad_heads_to > self.num_heads:
            assert self.pad_heads_to % max(self.num_kv_heads, 1) == 0
            return self.pad_heads_to
        return self.num_heads

    @property
    def live_experts(self) -> int:
        """Expert count still routable after failures (degraded MoE)."""
        return self.num_experts - len(self.dead_experts)

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def is_causal(self) -> bool:
        return BIDIR not in self.pattern

    @property
    def has_decode(self) -> bool:
        return self.is_causal

    @property
    def attention_free(self) -> bool:
        return all(k in (SSM, REC) for k in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no layer needs an unbounded full-attention KV cache."""
        return all(k in (SSM, REC) for k in self.pattern) or (
            FULL not in self.pattern and BIDIR not in self.pattern
        )

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kinds, pattern repeated/truncated to num_layers."""
        reps = -(-self.num_layers // len(self.pattern))
        return tuple((self.pattern * reps)[: self.num_layers])

    def num_params(self) -> int:
        """Analytic parameter count (matches init; used for MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        h, k = self.num_heads, self.num_kv_heads
        n = 0 if self.embedding_inputs else v * d
        if not self.tie_embeddings:
            n += v * d
        for kind in self.layer_kinds():
            if kind in (FULL, LOCAL, BIDIR):
                n += d * h * hd + 2 * d * k * hd + h * hd * d   # q,k,v,o
                if self.qkv_bias:
                    n += (h + 2 * k) * hd
                n += 2 * d                                      # ln1, ln2
                if self.sandwich_norm:
                    n += 2 * d
                if self.num_experts:
                    n += d * self.num_experts
                    n += self.num_experts * (2 * d * f + f * d)
                else:
                    gated = self.mlp_act in ("silu", "gelu")
                    n += (2 * d * f if gated else d * f) + f * d
            elif kind == SSM:
                di, ns = self.d_inner, self.ssm_state
                dtr = self.resolved_dt_rank
                n += d * 2 * di                                  # in_proj
                n += self.conv_width * di + di                   # conv + bias
                n += di * (dtr + 2 * ns)                         # x_proj
                n += dtr * di + di                               # dt_proj
                n += di * ns + di                                # A_log, D
                n += di * d                                      # out_proj
                n += d                                           # norm
            elif kind == REC:
                w = self.lru_width or d
                n += d * 2 * w                                   # in_proj (x, gate)
                n += self.conv_width * w + w                     # conv
                n += 3 * w                                       # lru a_param, in/rec gates diag approx
                n += 2 * w * w                                   # input/recurrence gate mats (block-diag full here)
                n += w * d                                       # out_proj
                n += 2 * d                                       # norms
                gated = self.mlp_act in ("silu", "gelu")
                n += (2 * d * f if gated else d * f) + f * d
        n += d                                                   # final norm
        return n

    def num_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts)."""
        if not self.num_experts:
            return self.num_params()
        d, f = self.d_model, self.d_ff
        per_layer_moe = self.num_experts * (2 * d * f + f * d)
        active_moe = self.experts_per_token * (2 * d * f + f * d)
        n_attn_layers = sum(1 for k in self.layer_kinds() if k in (FULL, LOCAL, BIDIR))
        return self.num_params() - n_attn_layers * (per_layer_moe - active_moe)


_REGISTRY: dict = {}


def register(name: str, full: ModelConfig, tiny: ModelConfig) -> None:
    _REGISTRY[name] = (full, tiny)


def get_config(name: str, tiny: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name][1 if tiny else 0]


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401

    return tuple(sorted(_REGISTRY))
