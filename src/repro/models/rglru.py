"""RG-LRU recurrent block (RecurrentGemma / Griffin family).

Temporal block: ``y = out( gelu(gate(x)) * RG-LRU(conv1d(x_proj(x))) )``.
RG-LRU (per channel):
  r_t = sigmoid(W_r u_t + b_r);  i_t = sigmoid(W_i u_t + b_i)
  a_t = sigmoid(Lambda) ** (c * r_t)          (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * u_t)
Uses the same chunked associative scan as the SSM layer (N = 1 per channel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.norms import rms_norm
from repro.layers.mlp import mlp_apply, mlp_init
from repro.models.mamba import _causal_conv, _scan_chunked
from repro.sharding.api import U, constrain
from repro.sharding.rules import DP_AXES, TP, gathered, res_spec

_C = 8.0


def rec_init(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    rec = {
        "x_proj": (jax.random.normal(ks[0], (d, w)) * d ** -0.5).astype(dt),
        "gate_proj": (jax.random.normal(ks[1], (d, w)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_i": (jax.random.normal(ks[3], (w, w)) * w ** -0.5).astype(dt),
        "b_i": jnp.zeros((w,), dt),
        "w_r": (jax.random.normal(ks[4], (w, w)) * w ** -0.5).astype(dt),
        "b_r": jnp.zeros((w,), dt),
        # init a ~ 0.9..0.999 (sigmoid(lam) in that range)
        "lam": jnp.linspace(2.2, 6.9, w).astype(dt),
        "out_proj": (jax.random.normal(ks[5], (w, d)) * w ** -0.5).astype(dt),
    }
    return {
        "ln1": jnp.ones((d,), dt),
        "rec": rec,
        "ln2": jnp.ones((d,), dt),
        "mlp": mlp_init(ks[6], d, cfg.d_ff, cfg.mlp_act, dt),
    }


def rec_apply(p, x, cfg, cache=None):
    """x (B,S,D) -> (y, new_cache); cache {"conv": (B,W-1,w), "h": (B,w)}."""
    B, S, D = x.shape
    cd = cfg.dtype
    rec = p["rec"]
    # SP: gather before the norm (bf16 edge; see transformer._attn_apply)
    h_in = rms_norm(gathered(cfg, x), p["ln1"], cfg.norm_eps)

    u = h_in @ rec["x_proj"].astype(cd)                       # (B,S,w)
    u = constrain(u, P(DP_AXES, U, TP))
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, rec["conv_w"], rec["conv_b"], conv_state)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ rec["w_r"].astype(jnp.float32) + rec["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ rec["w_i"].astype(jnp.float32) + rec["b_i"].astype(jnp.float32))
    log_a = _C * r * jax.nn.log_sigmoid(rec["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)                                        # (B,S,w)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)

    h0 = cache["h"] if cache is not None else jnp.zeros((B, u.shape[-1]), jnp.float32)
    if S == 1 and cache is not None:
        h_new = a[:, 0] * h0 + b[:, 0]
        h_seq = h_new[:, None]
        h_last = h_new
    else:
        h_seq, h_last = _scan_chunked(a, b, h0)               # (B,S,w)

    gate = jax.nn.gelu(h_in @ rec["gate_proj"].astype(cd), approximate=True)
    y = (h_seq.astype(cd) * gate) @ rec["out_proj"].astype(cd)
    x = constrain(x + y, res_spec(cfg))

    h2 = rms_norm(gathered(cfg, x), p["ln2"], cfg.norm_eps)
    x = constrain(x + mlp_apply(p["mlp"], h2, cfg.mlp_act, cd), res_spec(cfg))

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": h_last}
    return x, new_cache


def rec_cache_init(cfg, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), cfg.dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
