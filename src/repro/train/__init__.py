from repro.train.state import TrainState, init_state
from repro.train.step import make_train_step, loss_fn
from repro.train.serve import (make_prefill_step, make_decode_step,
                               make_serve_decode_step,
                               make_paged_decode_step, logit_stats)

__all__ = [
    "TrainState",
    "init_state",
    "make_train_step",
    "loss_fn",
    "make_prefill_step",
    "make_decode_step",
    "make_serve_decode_step",
    "make_paged_decode_step",
    "logit_stats",
]
