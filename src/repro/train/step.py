"""Train step: loss, grads (with optional microbatch accumulation), clip,
AdamW update.  The whole step is one BSP superstep (DESIGN.md S2): the
collectives XLA inserts for the batch-sharded loss ARE the global sync.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.attention import NEG_INF
from repro.models import forward
from repro.models.base import ModelConfig
from repro.optim import adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule

AUX_WEIGHT = 0.01


def loss_fn(cfg: ModelConfig, params, batch, impl: Optional[str] = None):
    """Causal-LM (or frame-classification) cross entropy over padded vocab."""
    logits, _, aux = forward(cfg, params, batch, mode="train", impl=impl)
    logits = logits.astype(jnp.float32)
    v, vp = cfg.vocab_size, cfg.padded_vocab
    if vp > v:
        pad_mask = jnp.arange(vp) >= v
        logits = jnp.where(pad_mask, NEG_INF, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["targets"][..., None],
                               axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    loss = nll + AUX_WEIGHT * aux
    return loss, {"nll": nll, "aux": aux}


def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4,
                    warmup_steps: int = 100, total_steps: int = 10_000,
                    weight_decay: float = 0.1, clip_norm: float = 1.0,
                    microbatches: int = 1, impl: Optional[str] = None,
                    param_specs=None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)`` (un-jitted —
    the launcher/coordinator jits it with shardings).

    ``param_specs``: PartitionSpec tree matching params.  Critical for FSDP +
    gradient accumulation: it pins the grad accumulator (and each
    microbatch's grads) to the parameter sharding, forcing a reduce-scatter
    per microbatch instead of carrying data-replicated gradients."""
    lr_fn = cosine_schedule(peak_lr, warmup_steps, total_steps)

    def pin(grads):
        if param_specs is None:
            return grads
        from repro.sharding.api import constrain
        return jax.tree.map(constrain, grads, param_specs)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, impl), has_aux=True)(params)
        return loss, metrics, pin(grads)

    def train_step(state, batch):
        params = state["params"]
        if microbatches > 1:
            def resplit(x, lead=0):
                # split the batch dim into (microbatches, B/mb)
                if x.ndim >= 1 and x.shape[lead] % microbatches == 0:
                    shape = (x.shape[:lead] + (microbatches,
                             x.shape[lead] // microbatches) + x.shape[lead + 1:])
                    return jnp.moveaxis(x.reshape(shape), lead, 0)
                raise ValueError(f"batch dim {x.shape} not divisible by "
                                 f"{microbatches}")

            mb_batch = {k: (resplit(v, 1) if k == "positions" else resplit(v, 0))
                        for k, v in batch.items()}

            def mb_body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc_loss, acc_metrics, acc_grads = acc
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_metrics, metrics),
                        jax.tree.map(jnp.add, acc_grads, grads)), None

            zero_g = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            zero_m = {"nll": jnp.zeros((), jnp.float32),
                      "aux": jnp.zeros((), jnp.float32)}
            (loss, metrics, grads), _ = lax.scan(
                mb_body, (jnp.zeros((), jnp.float32), zero_m, zero_g), mb_batch)
            inv = 1.0 / microbatches
            loss = loss * inv
            metrics = jax.tree.map(lambda x: x * inv, metrics)
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            loss, metrics, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state["step"])
        new_params, new_opt = adamw_update(
            grads, state["opt"], params, lr=lr, weight_decay=weight_decay)
        new_state = {
            "step": state["step"] + 1,
            "params": new_params,
            "opt": new_opt,
            "rng": jax.random.fold_in(state["rng"], 1),
        }
        # tier-3 SDC guard (docs/sdc.md): non-finite loss or grad norm,
        # folded into one device scalar — gnorm is a global reduction over
        # every gradient leaf, so any non-finite grad poisons it too.  The
        # host-side LossSentinel consumes this flag plus the loss EMA.
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                       "nonfinite": (~finite).astype(jnp.float32), **metrics}
        return new_state, out_metrics

    return train_step
