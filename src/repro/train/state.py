"""TrainState: the DeLIA *global state* — a plain pytree (dict) so the
checkpoint layer can treat it generically."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import init_params
from repro.models.base import ModelConfig
from repro.optim import adamw_init

TrainState = Dict[str, Any]


def init_state(cfg: ModelConfig, key) -> TrainState:
    params = init_params(cfg, key)
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": adamw_init(params),
        "rng": jax.random.PRNGKey(0),
    }
