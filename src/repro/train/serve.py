"""Serving steps: batched prefill and single-token decode with KV cache.

``decode_step`` is what the ``decode_*`` / ``long_*`` dry-run cells lower:
one new token against a cache of ``seq_len`` (sequence-sharded over the
"model" axis — SP decode, see sharding/rules.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.layers.attention import NEG_INF
from repro.models import forward
from repro.models.base import ModelConfig


def _mask_pad_vocab(cfg, logits):
    if cfg.padded_vocab > cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, NEG_INF, logits)
    return logits


def make_prefill_step(cfg: ModelConfig, impl: Optional[str] = None) -> Callable:
    def prefill_step(params, batch, cache):
        logits, cache, _ = forward(cfg, params, batch, mode="prefill",
                                   cache=cache, impl=impl)
        logits = _mask_pad_vocab(cfg, logits.astype(jnp.float32))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, impl: Optional[str] = None) -> Callable:
    def decode_step(params, batch, cache):
        logits, cache, _ = forward(cfg, params, batch, mode="decode",
                                   cache=cache, impl=impl)
        logits = _mask_pad_vocab(cfg, logits.astype(jnp.float32))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step


def logit_stats(cfg: ModelConfig, logits):
    """Per-row decode-path SDC signals from the last-position logits
    (B, V): a non-finite flag and the softmax entropy in nats.

    Entropy is the serving sibling of the training loss for tier-3
    detection (repro.sdc.DecodeSentinel): corruption that scrambles params
    or cache rows pushes the distribution toward uniform — entropy jumps
    toward log(V) — while non-finite logits trip the flag directly.  Pad
    vocab columns are already masked to NEG_INF by the caller, so they
    carry ~zero probability and do not bias the entropy."""
    nonfinite = 1.0 - jnp.all(jnp.isfinite(logits), axis=-1).astype(
        jnp.float32)
    # entropy via logsumexp: H = lse - sum(p * z); immune to the NEG_INF
    # pad-vocab columns (p -> 0 there), fp32 throughout
    z = logits
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    p = jax.nn.softmax(z, axis=-1)
    entropy = lse - jnp.sum(jnp.where(p > 0, p * z, 0.0), axis=-1)
    return {"nonfinite": nonfinite, "entropy": entropy}


def make_serve_decode_step(cfg: ModelConfig,
                           impl: Optional[str] = None) -> Callable:
    """Decode step for the serving engine: next token + new cache + the
    per-row logit stats the decode sentinel guards.  Shapes match
    ``make_decode_step``; the engine vmaps it over the cache pool's slot
    axis (see serve/cache_pool.py) so each row advances at its own
    position."""
    def decode_step(params, batch, cache):
        logits, cache, _ = forward(cfg, params, batch, mode="decode",
                                   cache=cache, impl=impl)
        logits = _mask_pad_vocab(cfg, logits.astype(jnp.float32))
        last = logits[:, -1]
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return next_tok, cache, logit_stats(cfg, last)

    return decode_step


def make_paged_decode_step(cfg: ModelConfig,
                           impl: Optional[str] = None) -> Callable:
    """One decode step for the block-paged serving pool
    (serve/page_table.py): every pool row advances one token against the
    SHARED page pool through its page table, in one batched call — no
    vmap over per-slot caches.

    batch: ``tokens`` (R, 1) last emitted token per row, ``lengths`` (R,)
    the query position per row, ``page_tables`` (R, MPR) int32.  Inactive
    rows carry a zeroed table + length 0 and only ever touch the null
    page; their outputs are discarded by the engine."""
    def paged_decode_step(params, batch, pages):
        logits, pages, _ = forward(cfg, params, batch, mode="paged_decode",
                                   cache=pages, impl=impl)
        logits = _mask_pad_vocab(cfg, logits.astype(jnp.float32))
        last = logits[:, -1]
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return next_tok, pages, logit_stats(cfg, last)

    return paged_decode_step
