"""Serving steps: batched prefill and single-token decode with KV cache.

``decode_step`` is what the ``decode_*`` / ``long_*`` dry-run cells lower:
one new token against a cache of ``seq_len`` (sequence-sharded over the
"model" axis — SP decode, see sharding/rules.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.layers.attention import NEG_INF
from repro.models import forward
from repro.models.base import ModelConfig


def _mask_pad_vocab(cfg, logits):
    if cfg.padded_vocab > cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, NEG_INF, logits)
    return logits


def make_prefill_step(cfg: ModelConfig, impl: Optional[str] = None) -> Callable:
    def prefill_step(params, batch, cache):
        logits, cache, _ = forward(cfg, params, batch, mode="prefill",
                                   cache=cache, impl=impl)
        logits = _mask_pad_vocab(cfg, logits.astype(jnp.float32))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, impl: Optional[str] = None) -> Callable:
    def decode_step(params, batch, cache):
        logits, cache, _ = forward(cfg, params, batch, mode="decode",
                                   cache=cache, impl=impl)
        logits = _mask_pad_vocab(cfg, logits.astype(jnp.float32))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step
