"""Block-paged KV cache: free-page allocator, per-request page tables,
refcounted prefix cache.

The slot pool (cache_pool.py) gives every in-flight request a contiguous
``max_len`` cache row — concurrency is capped at ``num_slots`` and a
short request wastes the whole row.  The paged pool instead shares ONE
device pool of ``num_pages`` pages of ``page_size`` tokens per attention
layer (``models.init_paged_cache``); each request holds a *page table*
mapping its logical positions onto physical pages (logical position
``t`` -> page ``table[t // page_size]``, offset ``t % page_size``), and
one table serves every layer (all layers advance in lockstep).  Page 0
is the reserved null page: zeroed table entries of inactive rows point
at it, and the length mask keeps it out of every real softmax.

**Refcounts.**  ``refs[p]`` counts the holders of physical page ``p`` —
rows whose table maps it, plus prefix-cache entries that pin it.  A page
is writable by a row only while the row is its sole holder
(``refs == 1``); ``ensure_writable`` copy-on-writes a shared page before
the row's next decode token lands in it.  A page returns to the free
list when its last holder lets go — ``release``/``release_all`` on the
row side, LRU eviction on the entry side — so a leak or double-free is
an accounting bug ``audit()`` catches.

**Prefix cache.**  After a miss prefill, the row's pages are registered
under the prompt's page-aligned prefixes: a later prompt sharing the
prefix attaches those pages read-only (refcounted) instead of
re-prefilling them, and an *exact* repeat of a full prompt also reuses
the stored first greedy token — the whole prefill is skipped and the
stream stays bit-identical because that token came from the original
prefill's own argmax, not a recomputation.

**Admission.**  ``can_admit`` gates on worst-case growth: a request
needs ``ceil((len(prompt) + max_new_tokens - 1) / page_size)`` pages if
it runs to its token budget, and the pool *reserves* the not-yet-
allocated tail (plus one page of copy-on-write allowance for an
unaligned shared tail) so a request admitted near capacity can never
hit ``PageExhausted`` mid-decode (the failure mode the slot pool's
``free_count`` gating could not express).
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_paged_cache

DEFAULT_PAGE_SIZE = 16


class PageExhausted(RuntimeError):
    """No free page — reservation accounting should have prevented this;
    the engine treats it as a planned requeue, not an incident."""


# ---------------------------------------------------------------------------
# device ops (module-level jits: compiles shared across replicas/standbys)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _write_pages(pages, row, page_ids, start_page):
    """Scatter a filled B=1 prefill row (init_cache layout, with ``pos``)
    into physical pages ``page_ids`` covering logical pages
    ``start_page..start_page+n-1``.  Rolling LOCAL rows only retain the
    last ``window`` positions; the ``pos``-match writes zeros for
    positions the row no longer holds — the window mask excludes exactly
    those at read time, and once a position falls out of the window it
    never re-enters (queries only advance)."""
    n = page_ids.shape[0]

    def entry(pk, pv, rk, rv, rpos):
        ps = pk.shape[-3]
        t = ((start_page + jnp.arange(n, dtype=jnp.int32))[:, None] * ps
             + jnp.arange(ps, dtype=jnp.int32)[None, :])      # (n, ps)

        def one(pk1, pv1, rk1, rv1, rpos1):
            sc = rk1.shape[1]
            src = t % sc
            valid = (rpos1[src] == t)[..., None, None]
            kvals = jnp.where(valid, rk1[0][src], 0).astype(pk1.dtype)
            vvals = jnp.where(valid, rv1[0][src], 0).astype(pv1.dtype)
            return pk1.at[page_ids].set(kvals), pv1.at[page_ids].set(vvals)

        if pk.ndim == 5:                                 # scan: leading G
            return jax.vmap(one)(pk, pv, rk, rv, rpos)
        return one(pk, pv, rk, rv, rpos)

    def walk(pblk, rblk):
        out = {}
        for name, pe in pblk.items():
            re_ = rblk[name]
            nk, nv = entry(pe["k"], pe["v"], re_["k"], re_["v"], re_["pos"])
            out[name] = {"k": nk, "v": nv}
        return out

    if "blocks" in pages:
        return {"blocks": walk(pages["blocks"], row["blocks"])}
    return {"layers": walk(pages["layers"], row["layers"])}


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(pages, src, dst):
    """Copy-on-write: duplicate physical page ``src`` into ``dst`` in
    every layer's pool."""
    def cp(x):
        if x.ndim == 5:
            return x.at[:, dst].set(x[:, src])
        return x.at[dst].set(x[src])

    return jax.tree.map(cp, pages)


# ---------------------------------------------------------------------------
# host-side accounting
# ---------------------------------------------------------------------------

@dataclass
class PrefixEntry:
    """One cached prompt prefix: ``pages`` pinned read-only, covering
    ``ntok`` token positions.  ``first_token`` is set when the entry
    covers an ENTIRE prompt (the original prefill's greedy argmax) —
    an exact repeat skips prefill and still opens with the bit-identical
    token.  ``row_refs`` counts rows currently attached (an entry is
    evictable only at zero)."""
    key: bytes
    pages: Tuple[int, ...]
    ntok: int
    first_token: Optional[int] = None
    row_refs: int = 0


def _pkey(tokens) -> bytes:
    return np.asarray(list(tokens), np.int64).tobytes()


@dataclass
class AdmitPlan:
    """What ``acquire`` decided for one request (returned to the engine).

    ``shared``: prefix pages attached; ``new``: pages allocated now for
    the non-shared prompt tail; ``reserved``: pages reserved for decode
    growth + copy-on-write; ``skip_prefill`` + ``first_token``: exact
    full-prompt hit."""
    shared: int = 0
    new: int = 0
    reserved: int = 0
    skip_prefill: bool = False
    first_token: Optional[int] = None
    entry_key: Optional[bytes] = None
    write_ids: Tuple[int, ...] = field(default_factory=tuple)
    write_start: int = 0


class PagedKVCache:
    """Drop-in pool for ``Replica``: same ``free_count`` / ``active_slots``
    / ``owner`` / ``release`` / ``release_all`` surface as ``CachePool``
    (rows play the role of slots), plus the page-aware admission and
    prefix surface the paged engine drives."""

    def __init__(self, cfg, num_pages: int, page_size: int, cache_len: int,
                 max_active: int, prefix: bool = True, registry=None):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is the null "
                             f"page), got {num_pages}")
        if cache_len % page_size:
            raise ValueError(f"cache_len {cache_len} not a multiple of "
                             f"page_size {page_size}")
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.cache_len = cache_len
        self.pages_per_row = cache_len // page_size
        self.max_active = max_active
        self.prefix_enabled = prefix
        self._registry = registry
        self.pages = init_paged_cache(cfg, num_pages, page_size)

        self._refs = np.zeros(num_pages, np.int64)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._rows_free: List[int] = list(range(max_active - 1, -1, -1))
        self._owner: Dict[int, int] = {}                 # row -> rid
        self._row_entry: Dict[int, bytes] = {}           # row -> prefix key
        self._row_reserved: Dict[int, int] = {}
        self._reserved_total = 0
        self._pending_write: Dict[int, Tuple[Tuple[int, ...], int]] = {}
        self._prefix: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self.page_tables = np.zeros((max_active, self.pages_per_row),
                                    np.int32)
        self.lengths = np.zeros((max_active,), np.int32)
        # observability (docs/observability.md): pressure + sharing
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.pages_allocated = 0
        self.cow_copies = 0
        self.last_drain: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------
    # accounting views
    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def free_count(self) -> int:                         # CachePool compat
        return len(self._rows_free)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self._owner)

    def owner(self, row: int) -> Optional[int]:
        return self._owner.get(row)

    def available(self) -> int:
        """Pages free AND not spoken for by another row's growth
        reservation."""
        return len(self._free) - self._reserved_total

    def _needed(self, plen: int, max_new: int) -> int:
        return -(-(plen + max_new - 1) // self.page_size)

    # ------------------------------------------------------------------
    # prefix probe
    # ------------------------------------------------------------------
    def _probe(self, prompt, max_new: int) -> AdmitPlan:
        ps = self.page_size
        L = len(prompt)
        prompt_pages = -(-L // ps)
        total = self._needed(L, max_new)
        plan = AdmitPlan()
        if self.prefix_enabled:
            e = self._prefix.get(_pkey(prompt))
            if e is not None and e.first_token is not None:
                plan.shared = len(e.pages)
                plan.skip_prefill = True
                plan.first_token = e.first_token
                plan.entry_key = e.key
            else:
                for m in range(L // ps, 0, -1):
                    e = self._prefix.get(_pkey(prompt[:m * ps]))
                    if e is not None and e.ntok == m * ps:
                        plan.shared = m
                        plan.entry_key = e.key
                        break
        plan.new = prompt_pages - plan.shared
        # growth reservation: the unallocated decode tail, plus one page
        # of copy-on-write allowance when the first decode write can land
        # in a page the prefix cache holds (unaligned prompt tail)
        cow = 1 if (self.prefix_enabled and max_new >= 2 and L % ps) else 0
        plan.reserved = (total - prompt_pages) + cow
        return plan

    def can_admit(self, prompt, max_new: int) -> bool:
        if not self._rows_free:
            return False
        plan = self._probe(prompt, max_new)
        need = plan.new + plan.reserved
        return need <= self.available() + self._reclaimable()

    def _reclaimable(self) -> int:
        """Pages LRU eviction could free right now: pages held only by
        zero-``row_refs`` prefix entries (conservative — a page pinned by
        two idle entries counts zero until one of them goes)."""
        n = 0
        for e in self._prefix.values():
            if e.row_refs == 0:
                n += sum(1 for p in e.pages if self._refs[p] == 1)
        return n

    def _evict_until(self, need: int, keep: Optional[bytes] = None) -> None:
        while self.available() < need:
            victim = next((k for k, e in self._prefix.items()
                           if e.row_refs == 0 and k != keep), None)
            if victim is None:
                break
            self._drop_entry(victim)

    def _drop_entry(self, key: bytes) -> None:
        e = self._prefix.pop(key)
        for p in e.pages:
            self._unref(p)

    def _unref(self, p: int) -> None:
        self._refs[p] -= 1
        if self._refs[p] == 0:
            self._free.append(p)
        assert self._refs[p] >= 0, f"double-free of page {p}"

    def _alloc(self) -> int:
        if not self._free:
            raise PageExhausted(
                f"all {self.num_pages - 1} pages held "
                f"({self._reserved_total} reserved)")
        p = self._free.pop()
        self._refs[p] = 1
        self.pages_allocated += 1
        return p

    # ------------------------------------------------------------------
    # row lifecycle
    # ------------------------------------------------------------------
    def acquire(self, rid: int, prompt, max_new: int
                ) -> Tuple[int, AdmitPlan]:
        """Admit one request: attach shared prefix pages, allocate pages
        for the non-shared prompt tail, reserve worst-case decode growth.
        Returns (row, plan); call ``write_prefill`` + ``register_prefix``
        after the prefill (unless ``plan.skip_prefill``)."""
        if not self._rows_free:
            raise PageExhausted("no free row; gate on can_admit")
        plan = self._probe(prompt, max_new)
        self._evict_until(plan.new + plan.reserved, keep=plan.entry_key)
        if plan.new + plan.reserved > self.available():
            raise PageExhausted(
                f"need {plan.new}+{plan.reserved} pages, "
                f"{self.available()} available; gate on can_admit")
        row = self._rows_free.pop()
        self._owner[row] = rid
        L = len(prompt)
        table = self.page_tables[row]
        table[:] = 0
        if plan.entry_key is not None:
            e = self._prefix[plan.entry_key]
            e.row_refs += 1
            self._prefix.move_to_end(plan.entry_key)     # LRU touch
            self._row_entry[row] = plan.entry_key
            for j, p in enumerate(e.pages[:plan.shared]):
                table[j] = p
                self._refs[p] += 1
            self.prefix_hits += 1
        else:
            self.prefix_misses += 1
        new_ids = []
        for j in range(plan.shared, plan.shared + plan.new):
            p = self._alloc()
            table[j] = p
            new_ids.append(p)
        plan.write_ids = tuple(new_ids)
        plan.write_start = plan.shared
        self._pending_write[row] = (plan.write_ids, plan.write_start)
        self._row_reserved[row] = plan.reserved
        self._reserved_total += plan.reserved
        self.lengths[row] = L
        if self._registry is not None:
            self._registry.histogram("serve.page_alloc").observe(plan.new)
        return row, plan

    def write_prefill(self, row: int, row_cache: Any) -> None:
        """Scatter the prefill's B=1 cache row into the pages allocated at
        ``acquire`` (shared prefix pages are never rewritten)."""
        ids, start = self._pending_write.pop(row, ((), 0))
        if not ids:
            return
        self.pages = _write_pages(self.pages, row_cache,
                                  jnp.asarray(ids, jnp.int32),
                                  jnp.int32(start))

    def register_prefix(self, row: int, prompt, first_token: int) -> None:
        """Pin this row's prompt pages in the prefix cache: the aligned
        prefix for cross-prompt sharing, and — for an unaligned prompt —
        the full prompt with its first greedy token for exact-repeat
        prefill skips.  (An aligned prompt's full entry IS its aligned
        entry; the stored first token upgrades it in place.)"""
        if not self.prefix_enabled:
            return
        ps = self.page_size
        L = len(prompt)
        table = self.page_tables[row]
        m = L // ps
        if m > 0:
            key = _pkey(prompt[:m * ps])
            e = self._prefix.get(key)
            if e is None:
                pages = tuple(int(p) for p in table[:m])
                e = PrefixEntry(key, pages, m * ps,
                                first_token=(int(first_token)
                                             if m * ps == L else None))
                for p in pages:
                    self._refs[p] += 1
                self._prefix[key] = e
            elif m * ps == L and e.first_token is None:
                e.first_token = int(first_token)
        if L % ps:
            key = _pkey(prompt)
            if key not in self._prefix:
                pages = tuple(int(p) for p in table[:-(-L // ps)])
                e = PrefixEntry(key, pages, L, first_token=int(first_token))
                for p in pages:
                    self._refs[p] += 1
                self._prefix[key] = e

    def ensure_writable(self, row: int) -> Optional[str]:
        """Make the page under this row's next decode write exclusively
        owned: allocate it if the table still points at the null page
        (growth into the reservation), copy-on-write it if the prefix
        cache or a sharer also holds it.  Returns "grow", "cow", or None.
        Raises ``PageExhausted`` only if admission accounting was
        bypassed — the engine requeues the stream as a planned drain."""
        pos = int(self.lengths[row])
        pi = pos // self.page_size
        if pi >= self.pages_per_row:
            raise PageExhausted(
                f"row {row} at position {pos} past its {self.pages_per_row}"
                f"-page table")
        table = self.page_tables[row]
        phys = int(table[pi])
        if phys == 0:
            self._consume_reservation(row)
            table[pi] = self._alloc()
            return "grow"
        if self._refs[phys] > 1:
            self._consume_reservation(row)
            new = self._alloc()
            self.pages = _copy_page(self.pages, jnp.int32(phys),
                                    jnp.int32(new))
            self._refs[phys] -= 1                 # row lets the shared go
            table[pi] = new
            self.cow_copies += 1
            return "cow"
        return None

    def _consume_reservation(self, row: int) -> None:
        left = self._row_reserved.get(row, 0)
        if left > 0:
            self._row_reserved[row] = left - 1
            self._reserved_total -= 1

    def advance(self, row: int) -> None:
        self.lengths[row] += 1

    def release(self, row: int) -> int:
        """Give back every page this row holds (shared pages just drop a
        ref) and its unused reservation; returns the rid."""
        if row not in self._owner:
            raise ValueError(f"row {row} not assigned")
        rid = self._owner.pop(row)
        for j in range(self.pages_per_row):
            p = int(self.page_tables[row, j])
            if p:
                self._unref(p)
        self.page_tables[row] = 0
        self.lengths[row] = 0
        self._reserved_total -= self._row_reserved.pop(row, 0)
        self._pending_write.pop(row, None)
        key = self._row_entry.pop(row, None)
        if key is not None and key in self._prefix:
            self._prefix[key].row_refs -= 1
        self._rows_free.append(row)
        return rid

    def release_all(self) -> List[int]:
        """Drain every row (replica died): returns the in-flight rids in
        row order — the CachePool contract the router/engine requeue walk
        depends on.  The drained page tables and prefix refcounts become
        part of the drain record (``last_drain``): every page — including
        shared-prefix refs — returns to the free list, and ``audit()``
        must come back clean (no leak, no double-free).  The prefix cache
        dies with the replica: its pages lived in THIS pool's device
        memory."""
        rows = sorted(self._owner)
        report = {"rows": [
            {"rid": self._owner[r], "row": r, "len": int(self.lengths[r]),
             "pages": [int(p) for p in self.page_tables[r] if p],
             "reserved": self._row_reserved.get(r, 0)}
            for r in rows],
            "prefix_entries": len(self._prefix)}
        rids = [self.release(r) for r in rows]
        for key in list(self._prefix):
            self._drop_entry(key)
        report["pages_freed"] = self.num_pages - 1
        self.last_drain = report
        ok, detail = self.audit()
        assert ok, f"page leak after release_all: {detail}"
        assert len(self._free) == self.num_pages - 1, \
            f"{self.num_pages - 1 - len(self._free)} pages leaked in drain"
        return rids

    # ------------------------------------------------------------------
    # invariants
    # ------------------------------------------------------------------
    def audit(self) -> Tuple[bool, str]:
        """Recompute refcounts from the ground truth (row tables + prefix
        entries) and check page conservation.  A mismatch is a leak or
        double-free."""
        want = np.zeros(self.num_pages, np.int64)
        for row in self._owner:
            for p in self.page_tables[row]:
                if p:
                    want[int(p)] += 1
        for e in self._prefix.values():
            for p in e.pages:
                want[p] += 1
        if not np.array_equal(want[1:], self._refs[1:]):
            bad = np.nonzero(want[1:] != self._refs[1:])[0][:8] + 1
            return False, (f"refcount drift at pages {bad.tolist()}: "
                           f"have {self._refs[bad].tolist()}, "
                           f"want {want[bad].tolist()}")
        held = int(np.count_nonzero(self._refs[1:]))
        if held + len(self._free) != self.num_pages - 1:
            return False, (f"{held} held + {len(self._free)} free != "
                           f"{self.num_pages - 1} pages")
        if len(set(self._free)) != len(self._free):
            return False, "free list holds duplicates"
        if self._reserved_total != sum(self._row_reserved.values()):
            return False, (f"reserved_total {self._reserved_total} != "
                           f"sum of row reservations")
        if self._reserved_total > len(self._free):
            return False, (f"{self._reserved_total} pages reserved but "
                           f"only {len(self._free)} free")
        return True, (f"{held} held, {len(self._free)} free, "
                      f"{self._reserved_total} reserved")

    def conservation(self) -> Dict[str, int]:
        """One page-accounting sample for the chaos invariant suite."""
        ok, _ = self.audit()
        return {"pages_total": self.num_pages - 1,
                "pages_free": len(self._free),
                "pages_held": self.num_pages - 1 - len(self._free),
                "pages_reserved": self._reserved_total,
                "refs_ok": int(ok)}


__all__ = ["PagedKVCache", "PageExhausted", "AdmitPlan", "PrefixEntry",
           "DEFAULT_PAGE_SIZE"]
