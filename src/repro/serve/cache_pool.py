"""Slot-based KV-cache pool: one device cache row per in-flight request.

The model's ``init_cache`` builds a lockstep batch cache — one shared
position index for every row — which cannot express continuous batching
(each in-flight request sits at a different decode position).  The pool
instead stacks ``num_slots`` independent B=1 cache rows along a new
leading axis; the engine vmaps the decode step over that axis, so every
row carries its own ``index``/``pos`` and advances at its own rate.

Slot lifecycle: ``acquire`` hands a free slot to a request at prefill
admission; the prefill runs against a FRESH B=1 cache and ``write_row``
scatters the filled row into the pool, which also wipes whatever a
previous occupant left there (stale ``pos`` entries from a longer earlier
request would otherwise be attended once the new request's position
passes them — decode_mha masks on ``pos <= cur`` only); ``release``
recycles the slot when the request completes or drains.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import init_cache


class PoolExhausted(RuntimeError):
    """No free slot — admission control should have prevented this."""


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_row(pool, row, slot):
    # module-level so the compile is shared by every pool of the same
    # shape (all replicas of one engine, and a warm standby's pool); the
    # pool is donated — a slot write must not copy the whole pool
    return jax.tree.map(
        lambda p, r: lax.dynamic_update_slice_in_dim(p, r[None], slot, 0),
        pool, row)


class CachePool:
    def __init__(self, cfg, num_slots: int, max_len: int):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        row = init_cache(cfg, 1, max_len)
        # stack num_slots zero rows: (num_slots,) + row-leaf shape
        self.cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (num_slots,) + x.shape), row)
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._owner: Dict[int, int] = {}       # slot -> rid

    # ------------------------------------------------------------------
    # slot accounting
    # ------------------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self._owner)

    def owner(self, slot: int) -> Optional[int]:
        return self._owner.get(slot)

    def acquire(self, rid: int) -> int:
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_slots} slots in use; admission control "
                "must gate on free_count")
        slot = self._free.pop()
        self._owner[slot] = rid
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._owner:
            raise ValueError(f"slot {slot} not assigned")
        del self._owner[slot]
        self._free.append(slot)

    def release_all(self) -> List[int]:
        """Drain every slot (replica died); returns the rids that were in
        flight, in slot order (the engine requeues them in reverse so the
        queue front ends up back in slot order)."""
        rids = [self._owner[s] for s in sorted(self._owner)]
        self._owner.clear()
        self._free = list(range(self.num_slots - 1, -1, -1))
        return rids

    # ------------------------------------------------------------------
    # device cache
    # ------------------------------------------------------------------
    def write_row(self, slot: int, row_cache: Any) -> None:
        """Scatter a filled B=1 cache (prefill output) into ``slot`` —
        fully overwrites the row, so slot recycling can never leak a
        previous request's cache entries."""
        self.cache = _scatter_row(self.cache, row_cache, jnp.int32(slot))
