"""The dependable serving engine: continuous batching + replicated failover.

One ``ServeEngine`` owns a request ``Scheduler``, a ``ReplicaRouter`` over
N model replicas (each a params copy + slot ``CachePool``), and — when
``fault_tolerant`` — a ``HeartbeatMonitor`` the replicas beat into.  Each
engine step, per healthy replica:

1. **admit**: pop queued requests while the replica has free slots (up to
   ``max_prefill_per_step``), run B=1 prefill for each, scatter the filled
   cache row into its slot — prefill of new requests interleaves with
   decode of in-flight ones;
2. **decode**: one vmapped decode step over the whole pool (fixed shape,
   one compile); every active slot's request gains one greedy token;
3. **guard**: the ``DecodeSentinel`` watches the step's logit stats —
   non-finite logits or an entropy spike flags the REPLICA as corrupt.

Failures — heartbeat-detected (drained at the next step boundary),
injected (``FaultInjector.schedule_replica_kill``), or sentinel-flagged —
all take the same path: the router excludes the replica, its in-flight
requests drain back to the queue with partial output discarded, and
survivors re-execute them.  Greedy decode is a pure function of the
prompt, so the retried streams are token-identical to an uninterrupted
run and the engine drops zero requests (tests/test_serve.py asserts
both).  Warm standbys (params via ``CheckpointManager.restore_latest``)
are activated one per failure to restore capacity.

The telemetry plane adds the *proactive* path (docs/observability.md):
with ``risk_source`` set (host -> risk in [0, 1], e.g.
``collector.risk_scores`` or a local ``AnomalyEngine.risk_scores``), the
engine pre-drains a replica whose host risk crosses
``pre_drain_threshold`` — same drain + requeue + token-identical retry
machinery, but triggered BEFORE the failure, so the predicted failure
costs a planned drain instead of a detection-latency-bound failover.
A replica is only pre-drained while another healthy replica or a warm
standby can absorb its load.  With ``risk_source`` set the engine also
emits per-replica step timings (``telemetry/replica_step``) so the drift
detector can attribute slowdowns to hosts.
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.failures import CorruptionDetected, SimulatedFailure
from repro.core.heartbeat import HeartbeatMonitor
from repro.models.base import FULL, LOCAL
from repro.obs import Observability
from repro.sdc import DecodeSentinel
from repro.serve.page_table import DEFAULT_PAGE_SIZE, PageExhausted
from repro.serve.replica import Replica, ServeFns
from repro.serve.router import NoHealthyReplicasError, ReplicaRouter
from repro.serve.scheduler import DECODE, Scheduler


def _supports_paging(cfg) -> bool:
    """Paged KV needs an attention-only decode stack (SSM/REC state has
    no sequence axis to page) and plain RoPE positions."""
    return (all(k in (FULL, LOCAL) for k in cfg.layer_kinds())
            and not cfg.mrope_sections)


def pctl(xs, q: float) -> float:
    """Nearest-rank percentile over a non-empty sample — one quantile
    convention for the driver and the benchmark."""
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


class ServeEngine:
    def __init__(self, cfg, params, *, num_replicas: int = 1,
                 slots_per_replica: int = 4, max_len: int = 256,
                 hosts_per_replica: int = 1,
                 fault_tolerant: bool = False,
                 heartbeat_period: float = 0.05,
                 heartbeat_timeout_factor: float = 5.0,
                 sentinel: bool = True,
                 sentinel_spike_factor: float = 4.0,
                 max_pending: int = 256,
                 max_prefill_per_step: int = 2,
                 max_retries: int = 3,
                 fault_injector=None,
                 impl: Optional[str] = None,
                 obs: Optional[Observability] = None,
                 risk_source: Optional[Callable[[], Dict[int, float]]]
                 = None,
                 pre_drain_threshold: float = 0.8,
                 paged: Optional[bool] = None,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 num_pages: Optional[int] = None,
                 max_active: Optional[int] = None,
                 prefix_cache: bool = True):
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only; cannot serve "
                             "autoregressive decode")
        if cfg.embedding_inputs:
            raise ValueError(f"{cfg.name} takes embedding inputs; the "
                             "engine serves token prompts")
        self.cfg = cfg
        # the paged pool is the default memory stack wherever the model
        # supports it; paged=False forces the legacy slot pool (kept as
        # the SSM/REC fallback and the equal-memory bench comparator)
        if paged is None:
            paged = _supports_paging(cfg)
        elif paged and not _supports_paging(cfg):
            raise ValueError(f"{cfg.name} cannot page its KV cache "
                             "(non-attention decode state or M-RoPE)")
        self.paged = paged
        # telemetry: the engine's event history lives on the obs bus (the
        # old ``self.events`` list survives as a read-only property view);
        # a shared Observability correlates serving with the other planes
        self.obs = obs if obs is not None else Observability()
        self.fns = ServeFns(cfg, slots_per_replica, max_len, impl=impl,
                            paged=paged, page_size=page_size,
                            num_pages=num_pages, max_active=max_active,
                            prefix_cache=prefix_cache)
        self.scheduler = Scheduler(max_pending=max_pending,
                                   max_retries=max_retries)
        self.injector = fault_injector
        self.max_prefill_per_step = max_prefill_per_step
        hosts_per_replica = max(int(hosts_per_replica), 1)
        self.monitor: Optional[HeartbeatMonitor] = None
        if fault_tolerant:
            # mesh-aware: a replica sharded over a multi-host tp group
            # beats under one identity PER host, so the monitor watches
            # num_replicas * hosts_per_replica hosts
            self.monitor = HeartbeatMonitor(
                num_replicas * hosts_per_replica, period=heartbeat_period,
                timeout_factor=heartbeat_timeout_factor,
                obs=self.obs).start()
        sentinel_factory = None
        if sentinel:
            # hard ceiling just under uniform: a replica corrupt from the
            # first step (bad standby restore) trips even during warmup
            ceiling = 0.98 * math.log(cfg.padded_vocab)
            sentinel_factory = lambda: DecodeSentinel(  # noqa: E731
                spike_factor=sentinel_spike_factor,
                abs_max_entropy=ceiling)
        self.router = ReplicaRouter(self.fns, self.monitor,
                                    heartbeat_period=heartbeat_period,
                                    sentinel_factory=sentinel_factory,
                                    hosts_per_replica=hosts_per_replica,
                                    registry=self.obs.registry)
        for _ in range(num_replicas):
            self.router.add_replica(params)
        self.engine_step = 0
        self.risk_source = risk_source
        self.pre_drain_threshold = pre_drain_threshold

    @property
    def events(self) -> List[Dict[str, Any]]:
        """Back-compat view of the engine's event history, reconstructed
        from the obs bus ("serve" subsystem): the same ``{"t", "step",
        "event", ...}`` dicts the old capped list held, bounded by the
        bus ring (DEFAULT_CAPACITY = the scheduler's 10k observability
        cap)."""
        return [{"t": e.t_mono, "step": e.data.get("step"),
                 "event": e.kind,
                 **{k: v for k, v in e.data.items() if k != "step"}}
                for e in self.obs.events(subsystem="serve")]

    # ------------------------------------------------------------------
    # client surface
    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> int:
        """Admit one request (raises ``scheduler.QueueFull`` past
        ``max_pending``); returns the request id."""
        # enforce the cache bound AT ADMISSION: past it, decode's rolling
        # cache write wraps (slot = cur % sc) and silently overwrites the
        # prompt's earliest KV entries — wrong tokens, and a broken
        # determinism guarantee for failover retries
        need = len(prompt) + max_new_tokens - 1
        if need > self.fns.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) needs {need} cache positions > "
                f"max_len {self.fns.max_len}")
        req = self.scheduler.submit(prompt, max_new_tokens,
                                    t_submit=time.perf_counter())
        return req.rid

    def add_standby(self, source) -> None:
        self.router.add_standby(source)

    def results(self) -> Dict[int, List[int]]:
        return self.scheduler.results()

    def reap(self, rid: int) -> List[int]:
        """Consume one finished request's tokens and evict its record
        (``scheduler.requests`` is bounded only if results are reaped)."""
        return list(self.scheduler.reap(rid).tokens)

    def drain_finished(self) -> Dict[int, List[int]]:
        """Consume-and-evict every finished request: rid -> tokens (FAILED
        requests drain too, with whatever partial tokens they kept — callers
        distinguish them via ``scheduler.failed_rids``).  Under sustained
        traffic call this after collecting results, or the per-request
        records leak."""
        return {r.rid: list(r.tokens)
                for r in self.scheduler.reap_finished()}

    def page_conservation(self) -> Dict[str, int]:
        """Aggregate page-accounting sample over every replica's pool
        (chaos invariant: pages_free + pages_held == pages_total and
        refcounts consistent, at every sample — see
        ``chaos.invariants.check_page_conservation``).  Dead replicas
        count too: their drained pools must sit fully free."""
        agg = {"pages_total": 0, "pages_free": 0, "pages_held": 0,
               "pages_reserved": 0, "refs_ok": 1}
        for rep in self.router.replicas.values():
            s = rep.pool.conservation()
            for k in ("pages_total", "pages_free", "pages_held",
                      "pages_reserved"):
                agg[k] += s[k]
            agg["refs_ok"] &= s["refs_ok"]
        return agg

    def request_latencies(self) -> List[Tuple[int, float, float]]:
        """[(rid, time-to-first-token, total latency), ...] for DONE
        requests.  A retried request's TTFT is measured to its RETRY's
        first token — partial pre-failure output was discarded, so that is
        when the client-visible stream actually starts."""
        out = []
        for r in self.scheduler.requests.values():
            if r.t_done is not None and r.t_first_token is not None:
                out.append((r.rid, r.t_first_token - r.t_submit,
                            r.t_done - r.t_submit))
        return out

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration over every healthy replica."""
        self._drain_detected()
        if self.risk_source is not None:
            self._pre_drain_risky()
        healthy = sorted(self.router.healthy(), key=lambda r: r.id)
        if not healthy and not self.scheduler.all_done():
            rep = self.router.activate_standby()
            if rep is None:
                raise NoHealthyReplicasError(
                    "every replica failed and no warm standby remains; "
                    f"{len(self.scheduler.in_flight())} requests in "
                    f"flight, {self.scheduler.pending()} queued")
            self._record("standby_activated", replica=rep.id)
            healthy = [rep]
        for rep in healthy:
            try:
                self._step_replica(rep)
            except SimulatedFailure as e:
                self._fail(rep, f"injected:{e.kind}")
            except CorruptionDetected as e:
                self._fail(rep, f"sentinel:{e.detail}")
        self.engine_step += 1
        reg = self.obs.registry
        reg.gauge("serve.queue_depth").set(self.scheduler.pending())
        reg.gauge("serve.in_flight").set(len(self.scheduler.in_flight()))
        reg.gauge("serve.healthy_replicas").set(len(healthy))
        if self.paged:
            # memory-pressure view for the telemetry plane + pre-drain
            # risk logic (docs/observability.md)
            reg.gauge("serve.pages_free").set(
                sum(r.pool.free_pages for r in self.router.healthy()))
            reg.gauge("serve.prefix_hits").set(
                sum(r.pool.prefix_hits
                    for r in self.router.replicas.values()))

    def run(self, max_steps: Optional[int] = None) -> Dict[int, List[int]]:
        """Drive ``step`` until every request is DONE (or FAILED past its
        retry budget); returns rid -> greedy tokens."""
        if max_steps is None:
            budget = sum(r.max_new_tokens
                         for r in self.scheduler.requests.values())
            # every step decodes >= 1 token on some replica unless the
            # engine is draining a failure; x4 + slack absorbs retries
            max_steps = 4 * budget + 200
        start = self.engine_step
        while not self.scheduler.all_done():
            if self.engine_step - start > max_steps:
                raise RuntimeError(
                    f"no completion after {max_steps} engine steps: "
                    f"{self.scheduler.pending()} queued, "
                    f"{len(self.scheduler.in_flight())} in flight")
            self.step()
        return self.results()

    def shutdown(self) -> None:
        self.router.shutdown()
        if self.monitor is not None:
            self.monitor.stop()

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _record(self, event: str, **kw) -> None:
        # bounded observability under sustained traffic: the bus ring caps
        # retention exactly like the old _trim'd list did
        self.obs.emit("serve", event, step=self.engine_step, **kw)

    def _drain_detected(self) -> None:
        for rid in self.router.take_detected():
            rep = self.router.replicas[rid]
            self._fail(rep, "heartbeat-timeout")

    def _fail(self, rep: Replica, reason: str) -> None:
        t0 = time.perf_counter()
        drained = self.router.fail_replica(rep, reason)
        # requeue in REVERSE slot order: each requeue prepends, so the
        # reversed walk leaves the queue front in slot (= admission) order
        for r in reversed(drained):
            # requeue clears t_first_token: the retry restamps the stream
            self.scheduler.requeue(self.scheduler.requests[r])
        drain_s = time.perf_counter() - t0
        extra = {}
        if self.paged and rep.pool.last_drain is not None:
            # page tables + prefix refcounts are part of the drained-
            # request state: the event carries what each retried stream
            # held, and release_all already audited zero leak/double-free
            extra = {"pages_drained": rep.pool.last_drain["pages_freed"],
                     "prefix_entries_dropped":
                         rep.pool.last_drain["prefix_entries"]}
        self._record("replica_failed", replica=rep.id, reason=reason,
                     drained=len(drained), hosts=list(rep.hosts), **extra)
        reg = self.obs.registry
        reg.histogram("serve.failover_drain_ms").observe(drain_s * 1e3)
        reg.counter("serve.replica_failures").inc()
        reg.counter("serve.requests_drained").inc(len(drained))
        if self.router.standby_count:
            standby = self.router.activate_standby()
            if standby is not None:
                self._record("standby_activated", replica=standby.id)

    def _pre_drain_risky(self) -> None:
        """The telemetry plane's proactive path: drain a replica whose
        host risk crossed the threshold — BEFORE its failure is
        detected — while capacity exists to absorb it."""
        scores = self.risk_source()
        for host, risk in sorted(scores.items()):
            if risk < self.pre_drain_threshold:
                continue
            rid = self.router._host_to_rid.get(host)
            if rid is None:
                continue
            rep = self.router.replicas[rid]
            if not rep.healthy:
                continue
            # never drain the last line of service: require a surviving
            # healthy replica or a warm standby to absorb the requeue
            others = [r for r in self.router.healthy() if r.id != rid]
            if not others and not self.router.standby_count:
                continue
            drained = self.router.drain_replica(rep, f"risk={risk:.2f}")
            for r in reversed(drained):
                self.scheduler.requeue(self.scheduler.requests[r])
            self._record("replica_predrained", replica=rep.id,
                         hosts=list(rep.hosts), risk=risk,
                         drained=len(drained))
            reg = self.obs.registry
            reg.counter("serve.replica_predrains").inc()
            reg.counter("serve.requests_drained").inc(len(drained))
            if self.router.standby_count:
                standby = self.router.activate_standby()
                if standby is not None:
                    self._record("standby_activated",
                                 replica=standby.id)

    def _step_replica(self, rep: Replica) -> None:
        # t0 BEFORE the injector: an injected latency spike sleeps in
        # check_replica, and the emitted step timing must include it —
        # that stretch is exactly what the drift detector watches
        t0 = time.perf_counter()
        if self.injector is not None:
            # may raise SimulatedFailure (replica kill) or sleep (latency
            # spike) — caught by step()
            self.injector.check_replica(self.engine_step, rep.id)
        self._admit(rep)
        self._decode(rep)
        if self.risk_source is not None and rep.hosts:
            # host-attributed step timing for the drift detector; the
            # "telemetry" subsystem keeps it out of the serve-subsystem
            # back-compat .events view
            self.obs.emit("telemetry", "replica_step", replica=rep.id,
                          host=rep.hosts[0],
                          seconds=time.perf_counter() - t0)

    def _admit(self, rep: Replica) -> None:
        if self.paged:
            self._admit_paged(rep)
            return
        admitted = 0
        while (rep.pool.free_count > 0 and self.scheduler.pending() > 0
               and admitted < self.max_prefill_per_step):
            req = self.scheduler.pop_queued()
            slot = rep.pool.acquire(req.rid)
            self.scheduler.start_prefill(req, slot, rep.id)
            tok0, row = rep.prefill(req.prompt)
            rep.pool.write_row(slot, row)
            self._first_token(rep, req, slot, tok0)
            admitted += 1

    def _admit_paged(self, rep: Replica) -> None:
        """Page-aware admission: a request leaves the queue only when the
        pool can cover its prompt pages AND a worst-case-growth
        reservation (prompt + max_new_tokens, plus copy-on-write
        allowance) — so decode can never strand an admitted stream on an
        empty free list.  An exact full-prompt prefix hit skips the
        prefill entirely: the cached pages attach read-only and the
        stream opens with the stored first greedy token (bit-identical —
        it came from the original prefill's argmax)."""
        admitted = 0
        pool = rep.pool
        while (self.scheduler.pending() > 0
               and admitted < self.max_prefill_per_step):
            nxt = self.scheduler.peek_queued()
            if not pool.can_admit(nxt.prompt, nxt.max_new_tokens):
                break
            req = self.scheduler.pop_queued()
            try:
                row, plan = pool.acquire(req.rid, req.prompt,
                                         req.max_new_tokens)
            except PageExhausted:
                # can_admit's reclaimable estimate is conservative but an
                # entry pinned by the plan can still starve it — put the
                # request back untouched and try next step
                self.scheduler._queue.appendleft(req.rid)
                break
            self.scheduler.start_prefill(req, row, rep.id)
            if plan.skip_prefill:
                tok0 = plan.first_token
                self._record("prefix_hit", rid=req.rid,
                             shared_pages=plan.shared, full=True)
            else:
                tok0, row_cache = rep.prefill(req.prompt)
                pool.write_prefill(row, row_cache)
                pool.register_prefix(row, req.prompt, tok0)
                if plan.shared:
                    self._record("prefix_hit", rid=req.rid,
                                 shared_pages=plan.shared, full=False)
            self._first_token(rep, req, row, tok0)
            admitted += 1

    def _first_token(self, rep: Replica, req, slot: int, tok0: int) -> None:
        self.scheduler.start_decode(req, tok0)
        req.t_first_token = time.perf_counter()
        self.obs.registry.histogram("serve.ttft_ms").observe(
            (req.t_first_token - req.t_submit) * 1e3)
        if req.retries > 0:
            # a drained request's retry produced its first client-
            # visible token: the failover incident is repaired
            self._record("retry_first_token", rid=req.rid,
                         retries=req.retries)
        if req.remaining == 0:           # max_new_tokens == 1
            self._finish(rep, req, slot)

    def _decode(self, rep: Replica) -> None:
        active = rep.pool.active_slots
        if self.paged and active:
            # make each active row's write-target page exclusively owned
            # BEFORE the batched step (allocate growth, copy-on-write a
            # shared tail).  PageExhausted here means reservation
            # accounting was bypassed — drain the stream back to the
            # queue as a PLANNED requeue (no retry burned, no incident)
            for row in list(active):
                req = self.scheduler.requests[rep.pool.owner(row)]
                try:
                    rep.pool.ensure_writable(row)
                except PageExhausted:
                    rep.pool.release(row)
                    self.scheduler.requeue(req, planned=True)
                    self._record("page_requeue", rid=req.rid, row=row)
                    self.obs.registry.counter("serve.page_requeues").inc()
            active = rep.pool.active_slots
        if not active:
            return
        last = np.zeros((self.fns.num_rows,), np.int32)
        for slot in active:
            req = self.scheduler.requests[rep.pool.owner(slot)]
            assert req.state == DECODE, (req.rid, req.state)
            last[slot] = req.last_token
        toks, stats = rep.decode(last)
        if rep.sentinel is not None:
            nonfinite = float(np.max(
                np.asarray(stats["nonfinite"]).reshape(-1)[active]))
            entropy = float(np.mean(
                np.asarray(stats["entropy"]).reshape(-1)[active]))
            reason = rep.sentinel.observe(self.engine_step, nonfinite,
                                          entropy)
            if reason is not None:
                # the step's tokens are suspect: discard them, fail the
                # replica (its requests retry on a survivor)
                raise CorruptionDetected(self.engine_step,
                                         "decode-sentinel", reason)
        now = time.perf_counter()
        self.obs.registry.counter("serve.tokens").inc(len(active))
        for slot in active:
            req = self.scheduler.requests[rep.pool.owner(slot)]
            if self.paged:
                rep.pool.advance(slot)   # this step wrote position len
            done = self.scheduler.append_token(req, int(toks[slot]))
            if done:
                self._finish(rep, req, slot, now=now)

    def _finish(self, rep: Replica, req, slot: int,
                now: Optional[float] = None) -> None:
        self.scheduler.finish(req)
        rep.pool.release(slot)
        req.t_done = time.perf_counter() if now is None else now
        self.obs.registry.histogram("serve.latency_ms").observe(
            (req.t_done - req.t_submit) * 1e3)
        self.obs.registry.counter("serve.requests_done").inc()
