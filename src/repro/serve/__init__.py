"""repro.serve — the dependable serving engine (docs/serving.md).

Continuous-batching inference with the dependability guarantees training
already has: a slot-based KV-cache pool so prefill of new requests
interleaves with decode of in-flight ones, N model replicas registered
with the heartbeat monitor, and detect-and-recover failover — a dead or
sentinel-flagged replica's requests drain back to the queue and re-execute
on survivors with token-identical greedy streams.
"""
from repro.serve.cache_pool import CachePool, PoolExhausted
from repro.serve.engine import ServeEngine, pctl
from repro.serve.replica import (Replica, ServeFns, make_standby_source,
                                 restore_standby_params)
from repro.serve.router import NoHealthyReplicasError, ReplicaRouter
from repro.serve.scheduler import (DECODE, DONE, FAILED, PREFILL, QUEUED,
                                   QueueFull, Request, Scheduler)

__all__ = [
    "ServeEngine",
    "pctl",
    "Scheduler",
    "Request",
    "QueueFull",
    "CachePool",
    "PoolExhausted",
    "Replica",
    "ServeFns",
    "ReplicaRouter",
    "NoHealthyReplicasError",
    "make_standby_source",
    "restore_standby_params",
    "QUEUED", "PREFILL", "DECODE", "DONE", "FAILED",
]
