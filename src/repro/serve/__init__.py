"""repro.serve — the dependable serving engine (docs/serving.md).

Continuous-batching inference with the dependability guarantees training
already has: a block-paged KV cache (``PagedKVCache`` — shared page pool,
per-request page tables, refcounted prefix sharing) so concurrency scales
with tokens instead of slots, N model replicas registered with the
heartbeat monitor, and detect-and-recover failover — a dead or
sentinel-flagged replica's requests drain back to the queue (page tables
and prefix refs released leak-free) and re-execute on survivors with
token-identical greedy streams.  The legacy slot pool (``CachePool``)
remains the fallback for SSM/REC decode stacks and the equal-memory
benchmark comparator.
"""
from repro.serve.cache_pool import CachePool, PoolExhausted
from repro.serve.engine import ServeEngine, pctl
from repro.serve.page_table import (DEFAULT_PAGE_SIZE, AdmitPlan,
                                    PagedKVCache, PageExhausted,
                                    PrefixEntry)
from repro.serve.replica import (Replica, ServeFns, make_standby_source,
                                 restore_standby_params)
from repro.serve.router import NoHealthyReplicasError, ReplicaRouter
from repro.serve.scheduler import (DECODE, DONE, FAILED, PREFILL, QUEUED,
                                   QueueFull, Request, Scheduler)

__all__ = [
    "ServeEngine",
    "pctl",
    "Scheduler",
    "Request",
    "QueueFull",
    "CachePool",
    "PoolExhausted",
    "PagedKVCache",
    "PageExhausted",
    "AdmitPlan",
    "PrefixEntry",
    "DEFAULT_PAGE_SIZE",
    "Replica",
    "ServeFns",
    "ReplicaRouter",
    "NoHealthyReplicasError",
    "make_standby_source",
    "restore_standby_params",
    "QUEUED", "PREFILL", "DECODE", "DONE", "FAILED",
]
