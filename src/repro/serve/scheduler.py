"""Continuous-batching request scheduler: queue + per-request state machine.

Requests move through QUEUED -> PREFILL -> DECODE -> DONE; a replica
failure mid-flight drains its requests back to QUEUED (the RETRY
transition) with their partial output discarded, so the re-execution on a
survivor replays the greedy stream from scratch — token-identical to an
uninterrupted run, because each request's decode depends only on its own
prompt and cache row (see docs/serving.md, "Determinism").

Admission control is two-level: ``max_pending`` bounds the host-side
queue (``submit`` raises ``QueueFull`` beyond it — backpressure to the
caller), and slot availability in the replica's ``CachePool`` gates the
QUEUED -> PREFILL transition (a request never leaves the queue without a
cache slot to land in).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

QUEUED = "QUEUED"
PREFILL = "PREFILL"
DECODE = "DECODE"
DONE = "DONE"
FAILED = "FAILED"

_TRANSITIONS = {
    QUEUED: {PREFILL},
    PREFILL: {DECODE, QUEUED, DONE},   # -> QUEUED: replica died mid-prefill
    DECODE: {DONE, QUEUED},            # -> QUEUED: replica died mid-decode
    DONE: set(),
    FAILED: set(),
}


class QueueFull(RuntimeError):
    """Admission control rejected the request (queue at max_pending)."""


# retained tail of the observability lists (retried_rids/failed_rids and
# ServeEngine.events): unbounded growth under sustained traffic would be
# the same leak class reap() exists to close
OBSERVABILITY_CAP = 10_000


def _trim(lst: List) -> None:
    if len(lst) > OBSERVABILITY_CAP:
        del lst[:-OBSERVABILITY_CAP]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: str = QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    replica: Optional[int] = None
    retries: int = 0
    # engine-stamped perf_counter times for latency percentiles
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.tokens)

    @property
    def last_token(self) -> int:
        return self.tokens[-1]


class Scheduler:
    def __init__(self, max_pending: int = 256, max_retries: int = 3):
        self.max_pending = max_pending
        self.max_retries = max_retries
        self.requests: Dict[int, Request] = {}
        self._queue: Deque[int] = deque()
        self._next_rid = 0
        self.retried_rids: List[int] = []      # observability: every requeue
        self.failed_rids: List[int] = []

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               t_submit: float = 0.0) -> Request:
        if len(self._queue) >= self.max_pending:
            raise QueueFull(
                f"{len(self._queue)} requests pending (max_pending="
                f"{self.max_pending})")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        req = Request(rid=self._next_rid, prompt=[int(t) for t in prompt],
                      max_new_tokens=max_new_tokens, t_submit=t_submit)
        self._next_rid += 1
        self.requests[req.rid] = req
        self._queue.append(req.rid)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def peek_queued(self) -> Optional[Request]:
        """Front of the queue WITHOUT popping — page-aware admission must
        inspect the request's size (prompt + worst-case decode growth)
        before committing pages to it; a pop-then-push-back would reorder
        the FIFO against later requeues."""
        if not self._queue:
            return None
        return self.requests[self._queue[0]]

    def pop_queued(self) -> Optional[Request]:
        """Next request to prefill (FIFO), or None when the queue is empty.
        The caller must immediately transition it with ``start_prefill`` —
        popping without a cache slot in hand is a scheduling bug."""
        if not self._queue:
            return None
        return self.requests[self._queue.popleft()]

    # ------------------------------------------------------------------
    # state machine
    # ------------------------------------------------------------------
    def _transition(self, req: Request, to: str) -> None:
        if to not in _TRANSITIONS[req.state]:
            raise ValueError(f"request {req.rid}: illegal transition "
                             f"{req.state} -> {to}")
        req.state = to

    def start_prefill(self, req: Request, slot: int, replica: int) -> None:
        self._transition(req, PREFILL)
        req.slot = slot
        req.replica = replica

    def start_decode(self, req: Request, first_token: int) -> None:
        self._transition(req, DECODE)
        req.tokens.append(int(first_token))

    def append_token(self, req: Request, token: int) -> bool:
        """Record one decoded token; returns True when the request just
        reached its budget (caller finishes it and recycles the slot)."""
        if req.state != DECODE:
            raise ValueError(f"request {req.rid} not decoding ({req.state})")
        if req.remaining <= 0:
            raise ValueError(f"request {req.rid} already at budget")
        req.tokens.append(int(token))
        return req.remaining == 0

    def finish(self, req: Request) -> None:
        self._transition(req, DONE)
        req.slot = None
        req.replica = None

    def requeue(self, req: Request, planned: bool = False) -> None:
        """Drain a request off a dead/corrupt replica back to the queue.

        Partial output is discarded — greedy decode is a pure function of
        the prompt, so the retry regenerates the identical stream.  Retried
        requests go to the FRONT of the queue (they have already waited
        once).  Each call PREPENDS, so a caller requeuing a drained batch
        must walk it in reverse to keep the batch in slot order at the
        queue front (see ServeEngine._fail).

        ``planned=True`` marks a scheduler-initiated drain (page
        exhaustion under paging) rather than a failure: the request does
        not burn retry budget — a stream must never FAIL because the
        engine chose to requeue it — but it still counts in
        ``retried_rids`` so drain accounting stays monotonic."""
        if req.state not in (PREFILL, DECODE):
            raise ValueError(f"request {req.rid} not in flight ({req.state})")
        if not planned:
            req.retries += 1
        self.retried_rids.append(req.rid)
        # the pre-failure first token was discarded with the partial
        # output: leaving its timestamp in place would make a retried
        # request report its PRE-FAILURE TTFT and understate failover
        # latency — the retry restamps it when its stream actually starts
        req.t_first_token = None
        _trim(self.retried_rids)
        if req.retries > self.max_retries:
            req.state = FAILED
            req.slot = None
            req.replica = None
            self.failed_rids.append(req.rid)
            _trim(self.failed_rids)
            return
        self._transition(req, QUEUED)
        req.tokens = []
        req.slot = None
        req.replica = None
        self._queue.appendleft(req.rid)

    def reap(self, rid: int) -> Request:
        """Evict one finished (DONE/FAILED) request and return it.

        Without eviction ``requests`` grows without bound — the engine
        leaks one Request per served stream under sustained traffic.  Call
        after the result has been consumed; reaping an in-flight or queued
        request is a caller bug and raises."""
        req = self.requests.get(rid)
        if req is None:
            raise KeyError(f"request {rid} unknown (already reaped?)")
        if req.state not in (DONE, FAILED):
            raise ValueError(f"request {rid} not finished ({req.state}); "
                             "reap only after DONE/FAILED")
        del self.requests[rid]
        return req

    def reap_finished(self) -> List[Request]:
        """Evict and return every finished request (drain path for
        sustained serving: keeps ``requests`` bounded by in-flight+queued)."""
        done = [r.rid for r in self.requests.values()
                if r.state in (DONE, FAILED)]
        return [self.reap(rid) for rid in done]

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def in_flight(self, replica: Optional[int] = None) -> List[Request]:
        return [r for r in self.requests.values()
                if r.state in (PREFILL, DECODE)
                and (replica is None or r.replica == replica)]

    def all_done(self) -> bool:
        return all(r.state in (DONE, FAILED) for r in self.requests.values())

    def results(self) -> Dict[int, List[int]]:
        return {r.rid: list(r.tokens) for r in self.requests.values()
                if r.state == DONE}
