"""Replica router: dispatch to healthy replicas, drain the dead ones.

Failure detection reuses the training stack wholesale: every replica runs
a ``HeartbeatEmitter`` under its replica id against one
``HeartbeatMonitor`` (``watch``/``unwatch`` register ids added after
start — warm standbys).  Detection arrives on monitor threads, so the
router latches it (same pattern as ``core.elastic_loop._HostLatch``) and
the engine drains the latch at step boundaries.  A replica can also die
synchronously — an injected ``SimulatedFailure(kind="replica-kill")`` or
a ``DecodeSentinel`` trip — in which case the router fails it immediately
and pauses its emitter so the monitor's view agrees.

Failing a replica drains its in-flight requests (``CachePool.release_all``
in slot order) back to the scheduler queue; greedy decode makes the
re-execution on a survivor token-identical.  If warm standbys were
registered, one is activated per failure: params materialized from its
source (typically ``CheckpointManager.restore_latest`` — see
``replica.make_standby_source``), a new replica id registered with the
monitor, compiled fns shared, so capacity recovers without an XLA compile
or a process relaunch.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.heartbeat import HeartbeatMonitor
from repro.sdc import DecodeSentinel
from repro.serve.replica import Replica, ServeFns


class NoHealthyReplicasError(RuntimeError):
    """Every replica is dead and no standby remains — the serving
    counterpart of ``core.elastic.NoSurvivorsError``."""


class ReplicaRouter:
    def __init__(self, fns: ServeFns,
                 monitor: Optional[HeartbeatMonitor] = None,
                 heartbeat_period: float = 0.05,
                 sentinel_factory: Optional[Callable[[], DecodeSentinel]]
                 = None,
                 hosts_per_replica: int = 1,
                 registry=None):
        self.fns = fns
        self.monitor = monitor
        self.heartbeat_period = heartbeat_period
        self.sentinel_factory = sentinel_factory
        self.registry = registry             # metrics for paged pools
        self.hosts_per_replica = max(int(hosts_per_replica), 1)
        self.replicas: Dict[int, Replica] = {}
        self._standby_sources: List[Callable[[], object]] = []
        self._next_id = 0
        self._next_host = 0              # next unused heartbeat identity
        self._host_to_rid: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._detected: set = set()      # monitor-thread detections, latched
        self.events: List[Tuple[str, int, str]] = []   # (kind, id, detail)
        if monitor is not None:
            # chain, don't clobber: the embedding application may watch too
            prev = monitor.on_failure
            monitor.on_failure = lambda h: (self._latch(h),
                                            prev(h) if prev else None)

    def _latch(self, replica_id: int) -> None:
        with self._lock:
            self._detected.add(replica_id)

    def take_detected(self) -> List[int]:
        """Replica ids the monitor declared failed since the last drain,
        plus any currently-failed ids (covers a detection that landed
        between ``start`` and the first latch wiring).

        Detections arrive as HOST ids; a multi-host replica maps every one
        of its hosts to the same replica id, so losing several hosts of a
        tp group — or one — surfaces the replica exactly once."""
        with self._lock:
            got, self._detected = set(self._detected), set()
        if self.monitor is not None:
            got |= set(self.monitor.failed_hosts())
        rids = {self._host_to_rid[h] for h in got if h in self._host_to_rid}
        return sorted(r for r in rids
                      if r in self.replicas and self.replicas[r].healthy)

    # ------------------------------------------------------------------
    # pool membership
    # ------------------------------------------------------------------
    def add_replica(self, params,
                    hosts_per_replica: Optional[int] = None) -> Replica:
        """``hosts_per_replica > 1``: the replica's params are sharded over
        a multi-host tp group — it gets that many heartbeat identities and
        fails over AS A UNIT (one drain) when any of them dies.  Default:
        the router-wide setting (so activated standbys match too)."""
        k = (self.hosts_per_replica if hosts_per_replica is None
             else max(int(hosts_per_replica), 1))
        rid = self._next_id
        self._next_id += 1
        hosts = tuple(range(self._next_host, self._next_host + k))
        self._next_host += k
        sentinel = (self.sentinel_factory() if self.sentinel_factory
                    else None)
        rep = Replica(rid, params, self.fns, sentinel=sentinel, hosts=hosts,
                      registry=self.registry)
        self.replicas[rid] = rep
        for h in hosts:
            self._host_to_rid[h] = rid
        if self.monitor is not None:
            for h in hosts:
                self.monitor.watch(h)
            rep.attach_emitter(self.monitor.addr, self.heartbeat_period)
        return rep

    def add_standby(self, source: Callable[[], object]) -> None:
        """Register a warm standby: ``source()`` materializes its params
        at activation time (e.g. ``make_standby_source(manager, like)``)."""
        self._standby_sources.append(source)

    @property
    def standby_count(self) -> int:
        return len(self._standby_sources)

    def healthy(self) -> List[Replica]:
        return [r for r in self.replicas.values() if r.healthy]

    # ------------------------------------------------------------------
    # failure handling
    # ------------------------------------------------------------------
    def fail_replica(self, rep: Replica, reason: str) -> List[int]:
        """Take a replica out of service; returns the drained rids (slot
        order).  Idempotent: a replica already failed drains nothing.

        A multi-host replica fails AS A UNIT: every host's emitter pauses
        and every host is acknowledged, but the pool drains exactly once —
        one failover incident, not one per host."""
        if not rep.healthy:
            return []
        rep.healthy = False
        rep.fail_reason = reason
        for em in rep.emitters:
            em.pause()                   # monitor view must agree: no beats
        if self.monitor is not None:
            for h in rep.hosts:
                self.monitor.acknowledge(h)
        drained = rep.pool.release_all()
        self.events.append(("replica_failed", rep.id,
                            f"{reason};drained={len(drained)}"))
        return drained

    def drain_replica(self, rep: Replica, reason: str) -> List[int]:
        """Proactively take a replica out of service BEFORE it fails
        (the telemetry plane's pre-drain, docs/observability.md):
        mechanically identical to ``fail_replica`` — emitters pause,
        hosts are acknowledged, the pool drains once — but recorded as
        ``replica_predrained``, and the acknowledged hosts never produce
        a ``heartbeat/failure`` event, so the Timeline sees a planned
        drain, not an incident."""
        if not rep.healthy:
            return []
        rep.healthy = False
        rep.fail_reason = f"predrain:{reason}"
        for em in rep.emitters:
            em.pause()
        if self.monitor is not None:
            for h in rep.hosts:
                self.monitor.acknowledge(h)
        drained = rep.pool.release_all()
        self.events.append(("replica_predrained", rep.id,
                            f"{reason};drained={len(drained)}"))
        return drained

    def activate_standby(self) -> Optional[Replica]:
        """Bring one warm standby into the pool (None when none remain)."""
        if not self._standby_sources:
            return None
        source = self._standby_sources.pop(0)
        rep = self.add_replica(source())
        self.events.append(("standby_activated", rep.id, ""))
        return rep

    def shutdown(self) -> None:
        for rep in self.replicas.values():
            rep.shutdown()
