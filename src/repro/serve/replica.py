"""One model replica: params + slot cache pool + compiled serve steps.

Replication is the serving counterpart of the elastic training loop
(docs/elastic.md): N replicas hold the same params, each with its own
``CachePool``, each emitting heartbeats to the shared ``HeartbeatMonitor``
under its replica id.  The compiled step functions are SHARED across
replicas of the same (cfg, num_slots, max_len) — a warm standby activates
without paying a fresh XLA compile (see ``ServeFns``).

The decode step is the vmapped-per-slot serve step
(``train.serve.make_serve_decode_step``): every pool row advances at its
own position, which is what lets prefill of new requests interleave with
decode of in-flight ones (continuous batching).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.heartbeat import HeartbeatEmitter
from repro.models import init_cache
from repro.sdc import DecodeSentinel
from repro.serve.cache_pool import CachePool
from repro.train import make_prefill_step, make_serve_decode_step


class ServeFns:
    """Compiled prefill/decode shared by every replica of one engine.

    Prefill is B=1 against a fresh cache row (compiled once per distinct
    prompt length); decode is vmapped over the pool's slot axis with the
    pool donated (no per-step cache copy — the same fix satellite-applied
    to examples/serve_lm.py)."""

    def __init__(self, cfg, num_slots: int, max_len: int,
                 impl: Optional[str] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill = jax.jit(make_prefill_step(cfg, impl))
        self.decode = jax.jit(
            jax.vmap(make_serve_decode_step(cfg, impl),
                     in_axes=(None, 0, 0)),
            donate_argnums=(2,))
        # fresh-row template: functional, never mutated — reused by every
        # prefill so slot recycling starts from a clean cache row
        self.fresh_row = init_cache(cfg, 1, max_len)


class Replica:
    def __init__(self, replica_id: int, params: Any, fns: ServeFns,
                 sentinel: Optional[DecodeSentinel] = None,
                 hosts: Optional[Sequence[int]] = None):
        self.id = replica_id
        self.params = params
        self.fns = fns
        self.pool = CachePool(fns.cfg, fns.num_slots, fns.max_len)
        self.sentinel = sentinel
        # a mesh-aware replica spans several hosts (a tp group sharded over
        # them): one heartbeat identity PER host, and the replica fails as
        # a unit when ANY of them dies.  Default: one host = the replica id
        # (the original single-host behavior, bit-for-bit).
        self.hosts: Tuple[int, ...] = (tuple(int(h) for h in hosts)
                                       if hosts is not None
                                       else (replica_id,))
        self.emitters: List[HeartbeatEmitter] = []
        self.healthy = True
        self.fail_reason: Optional[str] = None
        self.steps = 0                      # decode steps this replica ran

    # ------------------------------------------------------------------
    # heartbeat
    # ------------------------------------------------------------------
    @property
    def emitter(self) -> Optional[HeartbeatEmitter]:
        """First host's emitter (back-compat view; pausing it simulates
        killing ONE host of a multi-host replica)."""
        return self.emitters[0] if self.emitters else None

    def attach_emitter(self, monitor_addr, period: float) -> None:
        for h in self.hosts:
            self.emitters.append(
                HeartbeatEmitter(h, tuple(monitor_addr),
                                 period=period).start())

    def shutdown(self) -> None:
        for em in self.emitters:
            em.stop()
        self.emitters = []

    # ------------------------------------------------------------------
    # model steps
    # ------------------------------------------------------------------
    def prefill(self, prompt: Sequence[int]) -> Tuple[int, Any]:
        """Run B=1 prefill for one request; returns (first greedy token,
        filled cache row) — the caller scatters the row into a pool slot."""
        toks = jnp.asarray(list(prompt), jnp.int32)[None]
        if toks.shape[1] > self.fns.max_len:
            raise ValueError(f"prompt length {toks.shape[1]} exceeds "
                             f"max_len {self.fns.max_len}")
        tok, row = self.fns.prefill(self.params, {"tokens": toks},
                                    self.fns.fresh_row)
        return int(jax.device_get(tok)[0]), row

    def decode(self, last_tokens) -> Tuple[Any, Dict[str, Any]]:
        """One decode step over the WHOLE pool (fixed shape, one compile):
        ``last_tokens`` is (num_slots,) int32 — the previous token per
        slot, arbitrary for inactive slots (their outputs are ignored).
        Returns (tokens (num_slots,), stats with per-slot nonfinite and
        entropy)."""
        batch = {"tokens": jnp.asarray(last_tokens, jnp.int32)
                 .reshape(self.fns.num_slots, 1, 1)}
        toks, self.pool.cache, stats = self.fns.decode(
            self.params, batch, self.pool.cache)
        self.steps += 1
        return (jax.device_get(toks).reshape(-1),
                jax.device_get(stats))


def restore_standby_params(manager, like) -> Tuple[Any, int]:
    """Warm-standby restore path: pull the newest verifying params
    checkpoint through ``CheckpointManager.restore_latest`` (walks back
    past CRC-corrupt checkpoints exactly like training recovery does).
    ``like``: template pytree of the params.  Returns (params, step)."""
    state, _local, step, _skipped = manager.restore_latest(
        like={"params": like})
    return state["params"], step


def make_standby_source(manager, like):
    """Returns a zero-arg callable the router uses to materialize a warm
    standby's params on activation."""
    def source():
        params, _ = restore_standby_params(manager, like)
        return params
    return source


__all__ = ["Replica", "ServeFns", "restore_standby_params",
           "make_standby_source"]
