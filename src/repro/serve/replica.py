"""One model replica: params + slot cache pool + compiled serve steps.

Replication is the serving counterpart of the elastic training loop
(docs/elastic.md): N replicas hold the same params, each with its own
``CachePool``, each emitting heartbeats to the shared ``HeartbeatMonitor``
under its replica id.  The compiled step functions are SHARED across
replicas of the same (cfg, num_slots, max_len) — a warm standby activates
without paying a fresh XLA compile (see ``ServeFns``).

The decode step is the vmapped-per-slot serve step
(``train.serve.make_serve_decode_step``): every pool row advances at its
own position, which is what lets prefill of new requests interleave with
decode of in-flight ones (continuous batching).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.heartbeat import HeartbeatEmitter
from repro.models import init_cache
from repro.sdc import DecodeSentinel
from repro.serve.cache_pool import CachePool
from repro.serve.page_table import DEFAULT_PAGE_SIZE, PagedKVCache
from repro.train import (make_paged_decode_step, make_prefill_step,
                         make_serve_decode_step)


class ServeFns:
    """Compiled prefill/decode shared by every replica of one engine.

    Prefill is B=1 against a fresh cache row (compiled once per distinct
    prompt length); decode is vmapped over the pool's slot axis with the
    pool donated (no per-step cache copy — the same fix satellite-applied
    to examples/serve_lm.py).

    ``paged=True`` swaps the memory stack: replicas get a shared
    ``PagedKVCache`` pool (serve/page_table.py) of ``num_pages`` pages of
    ``page_size`` tokens instead of per-slot rows, decode runs ONE
    batched ``make_paged_decode_step`` over ``max_active`` rows through
    their page tables (pool donated), and prefill still runs B=1 against
    a fresh contiguous row whose filled pages are scattered into the
    pool.  The fresh row is sized to the page-aligned ``cache_len`` so
    the gathered logical cache matches the contiguous row shape exactly
    — that is what keeps paged greedy streams bit-identical to the slot
    pool's (docs/serving.md)."""

    def __init__(self, cfg, num_slots: int, max_len: int,
                 impl: Optional[str] = None, paged: bool = False,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 num_pages: Optional[int] = None,
                 max_active: Optional[int] = None,
                 prefix_cache: bool = True):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.paged = paged
        self.prefill = jax.jit(make_prefill_step(cfg, impl))
        if paged:
            self.page_size = page_size
            self.pages_per_row = -(-max_len // page_size)
            self.cache_len = self.pages_per_row * page_size
            # default pool = the slot pool's memory budget, repaged
            # (+1 for the reserved null page): equal-memory comparisons
            # come out of the box
            self.num_pages = (num_pages if num_pages is not None
                              else num_slots * self.cache_len // page_size
                              + 1)
            self.max_active = (max_active if max_active is not None
                               else num_slots)
            self.prefix_cache = prefix_cache
            self.paged_decode = jax.jit(make_paged_decode_step(cfg, impl),
                                        donate_argnums=(2,))
            self.fresh_row = init_cache(cfg, 1, self.cache_len)
        else:
            self.decode = jax.jit(
                jax.vmap(make_serve_decode_step(cfg, impl),
                         in_axes=(None, 0, 0)),
                donate_argnums=(2,))
            # fresh-row template: functional, never mutated — reused by
            # every prefill so slot recycling starts from a clean row
            self.fresh_row = init_cache(cfg, 1, max_len)

    @property
    def num_rows(self) -> int:
        """Rows the decode step advances per call (pool width)."""
        return self.max_active if self.paged else self.num_slots

    def make_pool(self, registry=None):
        if self.paged:
            return PagedKVCache(self.cfg, self.num_pages, self.page_size,
                                self.cache_len, self.max_active,
                                prefix=self.prefix_cache, registry=registry)
        return CachePool(self.cfg, self.num_slots, self.max_len)


class Replica:
    def __init__(self, replica_id: int, params: Any, fns: ServeFns,
                 sentinel: Optional[DecodeSentinel] = None,
                 hosts: Optional[Sequence[int]] = None,
                 registry=None):
        self.id = replica_id
        self.params = params
        self.fns = fns
        self.pool = fns.make_pool(registry=registry)
        self.sentinel = sentinel
        # a mesh-aware replica spans several hosts (a tp group sharded over
        # them): one heartbeat identity PER host, and the replica fails as
        # a unit when ANY of them dies.  Default: one host = the replica id
        # (the original single-host behavior, bit-for-bit).
        self.hosts: Tuple[int, ...] = (tuple(int(h) for h in hosts)
                                       if hosts is not None
                                       else (replica_id,))
        self.emitters: List[HeartbeatEmitter] = []
        self.healthy = True
        self.fail_reason: Optional[str] = None
        self.steps = 0                      # decode steps this replica ran

    # ------------------------------------------------------------------
    # heartbeat
    # ------------------------------------------------------------------
    @property
    def emitter(self) -> Optional[HeartbeatEmitter]:
        """First host's emitter (back-compat view; pausing it simulates
        killing ONE host of a multi-host replica)."""
        return self.emitters[0] if self.emitters else None

    def attach_emitter(self, monitor_addr, period: float) -> None:
        for h in self.hosts:
            self.emitters.append(
                HeartbeatEmitter(h, tuple(monitor_addr),
                                 period=period).start())

    def shutdown(self) -> None:
        for em in self.emitters:
            em.stop()
        self.emitters = []

    # ------------------------------------------------------------------
    # model steps
    # ------------------------------------------------------------------
    def prefill(self, prompt: Sequence[int]) -> Tuple[int, Any]:
        """Run B=1 prefill for one request; returns (first greedy token,
        filled cache row) — the caller scatters the row into a pool slot."""
        toks = jnp.asarray(list(prompt), jnp.int32)[None]
        if toks.shape[1] > self.fns.max_len:
            raise ValueError(f"prompt length {toks.shape[1]} exceeds "
                             f"max_len {self.fns.max_len}")
        tok, row = self.fns.prefill(self.params, {"tokens": toks},
                                    self.fns.fresh_row)
        return int(jax.device_get(tok)[0]), row

    def decode(self, last_tokens) -> Tuple[Any, Dict[str, Any]]:
        """One decode step over the WHOLE pool (fixed shape, one compile):
        ``last_tokens`` is (num_rows,) int32 — the previous token per
        row, arbitrary for inactive rows (their outputs are ignored).
        Returns (tokens (num_rows,), stats with per-row nonfinite and
        entropy).  Paged pools advance every row through their page
        tables in one batched call; slot pools vmap over per-slot rows."""
        if self.fns.paged:
            pool = self.pool
            batch = {"tokens": jnp.asarray(last_tokens, jnp.int32)
                     .reshape(self.fns.max_active, 1),
                     "lengths": jnp.asarray(pool.lengths),
                     "page_tables": jnp.asarray(pool.page_tables)}
            toks, pool.pages, stats = self.fns.paged_decode(
                self.params, batch, pool.pages)
        else:
            batch = {"tokens": jnp.asarray(last_tokens, jnp.int32)
                     .reshape(self.fns.num_slots, 1, 1)}
            toks, self.pool.cache, stats = self.fns.decode(
                self.params, batch, self.pool.cache)
        self.steps += 1
        return (jax.device_get(toks).reshape(-1),
                jax.device_get(stats))


def restore_standby_params(manager, like) -> Tuple[Any, int]:
    """Warm-standby restore path: pull the newest verifying params
    checkpoint through ``CheckpointManager.restore_latest`` (walks back
    past CRC-corrupt checkpoints exactly like training recovery does).
    ``like``: template pytree of the params.  Returns (params, step)."""
    state, _local, step, _skipped = manager.restore_latest(
        like={"params": like})
    return state["params"], step


def make_standby_source(manager, like):
    """Returns a zero-arg callable the router uses to materialize a warm
    standby's params on activation."""
    def source():
        params, _ = restore_standby_params(manager, like)
        return params
    return source


__all__ = ["Replica", "ServeFns", "restore_standby_params",
           "make_standby_source"]
