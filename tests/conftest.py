import os
import sys

# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests must see the real
# single-CPU device.  Mesh-dependent tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
