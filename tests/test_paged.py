"""Block-paged KV cache: allocator/refcount invariants, paged-vs-slot
bit-identity, prefix sharing, and the high-concurrency failover E2E
(docs/serving.md, "Paged KV cache").

The contracts under test:

- the paged engine's greedy streams are bit-identical to the legacy slot
  pool's (and therefore to the B=1 oracle) across arrival orders — the
  failover determinism guarantee survives the memory-stack swap;
- page accounting is conserved through every lifecycle edge: admission
  reservations, decode growth, copy-on-write, ``release``/``release_all``
  drains, and planned requeues — no leak, no double-free;
- prefix sharing is transparent: a sharer finishing (or its replica
  dying) mid-decode never perturbs the surviving stream.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.chaos import (Scenario, ServeScenarioDriver, check_conservation,
                         check_monotonic_drain, check_page_conservation,
                         check_token_identical, check_zero_drop, verify)
from repro.models import get_config, init_params
from repro.serve import (PagedKVCache, PageExhausted, Scheduler, ServeEngine)

CFG = get_config("granite-3-8b", tiny=True)
KEY = jax.random.PRNGKey(0)
MAX_LEN = 32
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCENARIOS = os.path.join(ROOT, "scenarios")


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, KEY)


def _prompts(n, lens=(4, 6, 8, 5, 7, 4, 9, 6)):
    return [list(range(5 + i, 5 + i + lens[i % len(lens)]))
            for i in range(n)]


def _reference_streams(params, prompts, gen, max_len=MAX_LEN):
    from repro.models import init_cache
    from repro.train import make_decode_step, make_prefill_step
    import jax.numpy as jnp
    prefill = jax.jit(make_prefill_step(CFG))
    decode = jax.jit(make_decode_step(CFG))
    out = []
    for p in prompts:
        toks = jnp.asarray(p, jnp.int32)[None]
        tok, row = prefill(params, {"tokens": toks},
                           init_cache(CFG, 1, max_len))
        s = [int(tok[0])]
        for _ in range(gen - 1):
            tok, row = decode(params, {"tokens": tok[:, None]}, row)
            s.append(int(tok[0]))
        out.append(s)
    return out


def _pool(num_pages=9, page_size=4, cache_len=16, max_active=4,
          prefix=False):
    return PagedKVCache(CFG, num_pages=num_pages, page_size=page_size,
                        cache_len=cache_len, max_active=max_active,
                        prefix=prefix)


# ---------------------------------------------------------------------------
# allocator: admission, reservations, growth
# ---------------------------------------------------------------------------

def test_pool_admission_reserves_worst_case_growth():
    """A request is admitted only when the pool covers its prompt pages
    AND its worst-case decode tail — so decode can never strand an
    admitted stream on an empty free list."""
    pool = _pool()                       # 8 usable pages (page 0 = null)
    prompt = [1] * 6                     # 2 prompt pages at ps=4
    # worst case: ceil((6 + 4 - 1) / 4) = 3 pages -> reserve 1 for growth
    assert pool.can_admit(prompt, 4)
    row, plan = pool.acquire(1, prompt, 4)
    assert plan.new == 2 and plan.reserved == 1 and not plan.skip_prefill
    assert pool.free_pages == 6 and pool.available() == 5
    pool.acquire(2, [1] * 6, 4)
    assert pool.available() == 2
    # a third identical request needs 2 + 1 > 2 available: gated out even
    # though 4 pages sit on the free list — they are spoken for
    assert pool.free_pages == 4
    assert not pool.can_admit([1] * 6, 4)
    ok, detail = pool.audit()
    assert ok, detail


def test_pool_decode_growth_consumes_reservation():
    pool = _pool()
    row, plan = pool.acquire(1, [1] * 6, 4)
    # prompt wrote positions 0..5; decode writes land at 6, 7, 8 — the
    # first two stay inside prompt page 1, position 8 grows into page 2
    assert pool.ensure_writable(row) is None       # pos 6: owned page
    pool.advance(row)
    assert pool.ensure_writable(row) is None       # pos 7
    pool.advance(row)
    assert pool.available() == pool.free_pages - 1
    assert pool.ensure_writable(row) == "grow"     # pos 8: null -> alloc
    assert pool.available() == pool.free_pages     # reservation consumed
    ok, detail = pool.audit()
    assert ok, detail


def test_pool_growth_past_table_raises_page_exhausted():
    pool = _pool(cache_len=8)            # 2-page tables at ps=4
    row, _ = pool.acquire(1, [1] * 6, 3)
    pool.lengths[row] = 8                # next write past the table
    with pytest.raises(PageExhausted):
        pool.ensure_writable(row)


def test_pool_release_returns_every_page_no_double_free():
    pool = _pool()
    row, _ = pool.acquire(7, [1] * 6, 4)
    pool.advance(row); pool.advance(row)
    pool.ensure_writable(row)            # grow: 3 pages held now
    assert pool.free_pages == 5
    assert pool.release(row) == 7
    assert pool.free_pages == 8 and pool.available() == 8
    assert pool.active_slots == [] and pool.free_count == 4
    with pytest.raises(ValueError):
        pool.release(row)                # double release is a caller bug
    ok, detail = pool.audit()
    assert ok, detail


def test_pool_release_all_drains_in_row_order():
    """The drain contract failover depends on: ``release_all`` returns
    rids in row (= admission) order, every page returns to the free list,
    and the drain report carries the page tables the retried streams
    held."""
    pool = _pool(num_pages=17)
    for rid in (7, 8, 9):
        pool.acquire(rid, [1] * 6, 4)
    assert pool.release_all() == [7, 8, 9]
    assert pool.free_pages == 16 and pool.free_count == 4
    assert pool.last_drain is not None
    assert [r["rid"] for r in pool.last_drain["rows"]] == [7, 8, 9]
    assert all(len(r["pages"]) == 2 for r in pool.last_drain["rows"])
    # the pool is reusable from a clean slate after the drain
    row, _ = pool.acquire(10, [2] * 4, 2)
    assert pool.owner(row) == 10
    ok, detail = pool.audit()
    assert ok, detail


# ---------------------------------------------------------------------------
# prefix cache: refcounts, sharing, copy-on-write
# ---------------------------------------------------------------------------

def test_prefix_exact_repeat_skips_prefill_with_stored_token():
    pool = _pool(num_pages=17, prefix=True)
    prompt = list(range(8))              # page-aligned at ps=4
    row, plan = pool.acquire(1, prompt, 3)
    assert not plan.skip_prefill
    pool.register_prefix(row, prompt, first_token=42)
    row2, plan2 = pool.acquire(2, prompt, 3)
    assert plan2.skip_prefill and plan2.first_token == 42
    assert plan2.shared == 2 and plan2.new == 0
    # both rows map the same physical pages, each held 3x (2 rows + entry)
    assert (pool.page_tables[row, :2] == pool.page_tables[row2, :2]).all()
    for p in pool.page_tables[row, :2]:
        assert pool._refs[int(p)] == 3
    assert pool.prefix_hits == 1 and pool.prefix_misses == 1
    ok, detail = pool.audit()
    assert ok, detail


def test_prefix_unaligned_tail_copy_on_write():
    """An unaligned shared tail page must be copy-on-written before the
    sharer's first decode token lands in it — covered by the reservation's
    CoW allowance, never by luck."""
    pool = _pool(num_pages=17, prefix=True)
    prompt = list(range(6))              # tail page holds positions 4..5
    row, plan = pool.acquire(1, prompt, 4)
    assert plan.reserved == 2            # growth tail + CoW allowance
    pool.register_prefix(row, prompt, first_token=9)
    tail = int(pool.page_tables[row, 1])
    assert pool._refs[tail] == 2         # row + full-prompt entry
    assert pool.ensure_writable(row) == "cow"      # pos 6 shares the tail
    assert int(pool.page_tables[row, 1]) != tail
    assert pool._refs[tail] == 1 and pool.cow_copies == 1
    ok, detail = pool.audit()
    assert ok, detail


def test_prefix_refcounts_survive_sharer_release_and_drain():
    pool = _pool(num_pages=17, prefix=True)
    prompt = list(range(8))
    row, _ = pool.acquire(1, prompt, 2)
    pool.register_prefix(row, prompt, first_token=3)
    row2, _ = pool.acquire(2, prompt, 2)
    pool.release(row)                    # one sharer leaves mid-flight
    for p in pool.page_tables[row2, :2]:
        assert pool._refs[int(p)] == 2   # surviving row + entry
    ok, detail = pool.audit()
    assert ok, detail
    pool.release(row2)
    # pages persist under the (idle) entry until eviction or drain
    assert pool.conservation()["pages_held"] == 2
    assert pool.release_all() == []      # empty drain still drops entries
    assert pool.free_pages == 16


def test_prefix_eviction_reclaims_idle_entries_for_admission():
    pool = _pool(num_pages=9, cache_len=32, prefix=True)  # 8 usable pages
    prompt = list(range(8))
    row, _ = pool.acquire(1, prompt, 2)        # 2 pages, no reservation
    pool.register_prefix(row, prompt, first_token=3)
    pool.release(row)
    assert pool.available() == 6 and pool._reclaimable() == 2
    # 5 prompt pages + 2 reserved only fit by evicting the idle entry
    big = list(range(100, 118))
    assert pool.can_admit(big, 4)
    row2, plan = pool.acquire(2, big, 4)
    assert plan.new == 5 and plan.reserved == 2
    assert len(pool._prefix) == 0              # LRU victim evicted
    ok, detail = pool.audit()
    assert ok, detail


# ---------------------------------------------------------------------------
# scheduler: planned requeue (page exhaustion is not an incident)
# ---------------------------------------------------------------------------

def test_scheduler_planned_requeue_burns_no_retry():
    """A page-exhaustion drain is the ENGINE's choice, not a failure of
    the stream — it must never consume the request's retry budget (a
    stream could otherwise FAIL without any replica ever dying), but it
    still counts in the drained-request accounting."""
    s = Scheduler(max_retries=0)         # any real retry would FAIL
    r = s.submit([1, 2], 4)
    s.pop_queued()
    s.start_prefill(r, 0, 0)
    s.start_decode(r, 7)
    s.requeue(r, planned=True)
    assert r.state == "QUEUED" and r.retries == 0
    assert s.retried_rids[-1] == r.rid   # monotonic drain accounting
    assert s.pop_queued() is r           # back at the queue front


# ---------------------------------------------------------------------------
# engine: paged vs slot-pool bit-identity
# ---------------------------------------------------------------------------

def test_paged_streams_bit_identical_to_slot_pool_any_order(params):
    """The tentpole determinism contract: the paged engine's greedy
    streams equal the legacy slot pool's token for token, across arrival
    orders — same model, same memory budget, different memory stack."""
    prompts = _prompts(6)
    gen = 5

    def run(paged, order):
        eng = ServeEngine(CFG, params, num_replicas=1,
                          slots_per_replica=3, max_len=MAX_LEN,
                          fault_tolerant=False, paged=paged)
        rids = {eng.submit(prompts[i], gen): i for i in order}
        res = eng.run()
        if paged:
            for rep in eng.router.replicas.values():
                ok, detail = rep.pool.audit()
                assert ok, detail
        eng.shutdown()
        return {i: res[rid] for rid, i in rids.items()}

    legacy = run(False, [0, 1, 2, 3, 4, 5])
    assert run(True, [0, 1, 2, 3, 4, 5]) == legacy
    assert run(True, [5, 3, 1, 0, 2, 4]) == legacy


def test_paged_prefix_sharing_streams_stay_bit_identical(params):
    """Prefix sharing is a pure memory optimization: prompts sharing an
    aligned 16-token prefix (and exact repeats, which skip prefill) must
    produce the same streams as the B=1 oracle, with hits recorded."""
    base = list(range(3, 19))            # one full page at ps=16
    prompts = [base + [21, 22], base + [33], list(base), list(base)]
    gen = 4
    ref = _reference_streams(params, prompts, gen)
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=4,
                      max_len=MAX_LEN, fault_tolerant=False, paged=True,
                      num_pages=64)
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()
    pool = eng.router.replicas[0].pool
    hits = [e for e in eng.events if e["event"] == "prefix_hit"]
    full_hits = [e for e in hits if e.get("full")]
    assert pool.prefix_hits >= 3, "sharers + exact repeat must all hit"
    assert full_hits, "the exact repeat must skip prefill entirely"
    ok, detail = pool.audit()
    assert ok, detail
    eng.shutdown()
    for rid, r in zip(rids, ref):
        assert res[rid] == r


def test_prefix_sharer_finishing_mid_decode_leaves_stream_intact(params):
    """One sharer releases its pages mid-decode of the other: the
    surviving stream must not notice (its shared pages were CoW'd or
    refcounted, never freed under it) and accounting must stay clean."""
    prompt = list(range(2, 22))          # unaligned: shared tail page
    gen_long, gen_short = 8, 2
    ref = _reference_streams(params, [prompt, prompt],
                             gen_long)[0]
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=4,
                      max_len=MAX_LEN, fault_tolerant=False, paged=True,
                      num_pages=64)
    pool = eng.router.replicas[0].pool
    rid_long = eng.submit(prompt, gen_long)
    rid_short = eng.submit(prompt, gen_short)    # exact-repeat sharer
    while not eng.scheduler.all_done():
        eng.step()
        ok, detail = pool.audit()
        assert ok, f"mid-decode accounting drift: {detail}"
    res = eng.results()
    assert pool.prefix_hits >= 1 and pool.cow_copies >= 1
    eng.shutdown()
    assert res[rid_long] == ref
    assert res[rid_short] == ref[:gen_short]


def test_paged_requeues_on_page_exhaustion_without_dropping(params):
    """Starve the pool so streams must wait: every submitted request
    still completes with an oracle stream (admission defers, planned
    requeues burn no retries, nothing FAILs)."""
    prompts = _prompts(5)
    gen = 5
    ref = _reference_streams(params, prompts, gen)
    # 5 usable pages: at most two 2-page streams + reservations in flight
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=4,
                      max_len=MAX_LEN, fault_tolerant=False, paged=True,
                      page_size=4, num_pages=6, prefix_cache=False)
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()
    pool = eng.router.replicas[0].pool
    ok, detail = pool.audit()
    assert ok, detail
    assert eng.scheduler.failed_rids == []
    eng.shutdown()
    for rid, r in zip(rids, ref):
        assert res[rid] == r


def test_paged_rejected_for_unpageable_stack(params):
    """paged=True on a decode stack with non-attention state must fail
    loudly at construction, and the auto default must fall back to the
    slot pool."""
    ssm = get_config("falcon-mamba-7b", tiny=True)
    sparams = init_params(ssm, KEY)
    with pytest.raises(ValueError, match="page"):
        ServeEngine(ssm, sparams, max_len=16, paged=True)
    eng = ServeEngine(ssm, sparams, max_len=16)      # auto: legacy pool
    assert not eng.paged
    eng.shutdown()


# ---------------------------------------------------------------------------
# E2E: flash crowd at 100+ concurrent streams + replica kill mid-spike
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_e2e_flash_crowd_paged_replica_kill(params):
    """The acceptance scenario (scenarios/flash_crowd_paged.json): a 16x
    traffic spike pushes the paged engine past 100 concurrent streams —
    far beyond any slot pool at this memory budget — then one replica
    dies mid-spike.  Zero admitted requests drop, every retried stream is
    token-identical to the B=1 oracle, and page conservation holds at
    every engine step across the kill and drain."""
    sc = Scenario.from_json(os.path.join(SCENARIOS,
                                         "flash_crowd_paged.json"))
    eng = ServeEngine(CFG, params, num_replicas=2, slots_per_replica=4,
                      max_len=MAX_LEN, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      max_pending=512, max_prefill_per_step=16,
                      paged=True, max_active=64, num_pages=200)
    drv = ServeScenarioDriver(eng, sc, base_rate=1, prompt_len=8,
                              max_new_tokens=16)
    results = drv.run()
    rep = drv.report()
    samples = drv.samples
    page_samples = drv.page_samples
    retried = sorted(set(eng.scheduler.retried_rids))
    failures = [e for e in eng.events if e["event"] == "replica_failed"]
    sched = eng.scheduler

    assert failures and failures[0]["replica"] == 1
    assert "pages_drained" in failures[0]      # page tables in the drain
    assert retried, "the mid-spike kill must have drained in-flight work"
    assert rep["rejected"] == 0                # max_pending absorbed it
    peak = max(s["in_flight"] for s in samples)
    assert peak >= 100, (f"spike peaked at {peak} concurrent streams; "
                         "the paged pool must sustain 100+")

    # oracle the streams failover touched (plus a control sample): the
    # full ~200-request set would dominate the test's runtime for no
    # additional coverage
    check_rids = retried + [r for r in drv.submitted_rids[:8]
                            if r not in retried]
    ref = {rid: s for rid, s in zip(
        check_rids,
        _reference_streams(params, [drv.prompts[r] for r in check_rids],
                           drv.max_new_tokens))}
    verify([check_zero_drop(sched, drv.submitted_rids),
            check_token_identical({r: results[r] for r in check_rids},
                                  ref),
            check_conservation(samples),
            check_page_conservation(page_samples),
            check_monotonic_drain(drv.drained_series)])
    eng.shutdown()


def test_flash_crowd_paged_scenario_loads():
    """The committed trace parses, validates, and spikes while the kill
    lands inside the spike window (mid-spike is the point)."""
    with open(os.path.join(SCENARIOS, "flash_crowd_paged.json")) as f:
        raw = json.load(f)
    sc = Scenario.from_dict(raw)
    sc.validate()
    spike = next(e for e in sc.window_events("traffic_spike"))
    kill = next(e for e in sc.point_events("kill_hosts"))
    assert spike.args["mult"] >= 16
    assert spike.at < kill.at < spike.until
