"""Cross-host telemetry plane (docs/observability.md, "Telemetry plane").

Layers under test, bottom up:

- the bounded JSONL sink (rotation + ``load_jsonl`` segment ordering)
  and the Prometheus exposition extensions (label escaping, configurable
  quantiles);
- the wire: ``TelemetryAgent`` datagram formation and the ``Collector``
  merge protocol — (inc, seq) acceptance, per-host gap accounting,
  skew-tolerant ordering.  The acceptance case: two agents with opposite
  clock skews plus a dropped-datagram window still merge into one
  gap-annotated global Timeline whose MTTR matches the single-host
  oracle exactly;
- the detectors (``StepTimeDriftDetector`` / ``BeatJitterDetector`` /
  ``ScrubRateDetector``) and the ``AnomalyEngine`` risk fold;
- the proactive consumers: risk-adjusted Young/Daly, ``run_bsp``'s
  forced-checkpoint hook, the serve engine's replica pre-drain;
- the ``check_detect_before_act`` invariant and the straggle-then-kill
  E2Es (train via ``run_scenario_elastic`` + ``precursor_storm``, serve
  via injected latency spikes), both marked ``slow``.
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import pytest

from repro.obs import (AnomalyEngine, BeatJitterDetector, Collector, Event,
                       EventBus, MetricsRegistry, ScrubRateDetector,
                       StepTimeDriftDetector, TelemetryAgent, Timeline,
                       load_jsonl, make_proactive_hook)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCENARIOS = os.path.join(ROOT, "scenarios")


# ---------------------------------------------------------------------------
# JSONL sink rotation
# ---------------------------------------------------------------------------

def test_jsonl_rotation_keeps_stream_readable_in_order(tmp_path):
    """Rotation must be invisible to the reader: emit past the byte cap,
    then load_jsonl stitches segments + live file back into the exact
    emit order."""
    path = str(tmp_path / "t.jsonl")
    bus = EventBus()
    bus.attach_jsonl(path, max_bytes=600, max_segments=50)
    n = 40
    for i in range(n):
        bus.emit("bench", "tick", step=i)
    bus.close()
    segs = sorted(p for p in os.listdir(tmp_path)
                  if p.startswith("t.jsonl."))
    assert segs, "the byte cap must have forced at least one rotation"
    # every segment stays under the cap (a record written just before
    # rotating may leave the file near but never over cap + one line)
    for p in segs:
        assert os.path.getsize(tmp_path / p) <= 600
    back = load_jsonl(path)
    assert [e.data["step"] for e in back] == list(range(n))
    assert [e.seq for e in back] == sorted(e.seq for e in back)


def test_jsonl_rotation_prunes_oldest_segments(tmp_path):
    path = str(tmp_path / "t.jsonl")
    bus = EventBus()
    bus.attach_jsonl(path, max_bytes=300, max_segments=2)
    for i in range(60):
        bus.emit("bench", "tick", step=i)
    bus.close()
    segs = sorted(int(p.rsplit(".", 1)[1]) for p in os.listdir(tmp_path)
                  if p.startswith("t.jsonl."))
    assert len(segs) == 2
    # pruning removes the OLDEST: surviving indices are the two highest
    back = load_jsonl(path)
    steps = [e.data["step"] for e in back]
    assert steps == sorted(steps), "pruned stream must stay chronological"
    assert steps[-1] == 59, "the newest records live in the live file"
    assert steps[0] > 0, "the oldest records must have been pruned"


def test_jsonl_reattach_resumes_segment_numbering(tmp_path):
    """A restarted process re-attaching the same path must not clobber
    existing rotated segments — numbering continues past them."""
    path = str(tmp_path / "t.jsonl")
    bus = EventBus()
    bus.attach_jsonl(path, max_bytes=200, max_segments=50)
    for i in range(20):
        bus.emit("a", "x", step=i)
    bus.close()
    first = {p for p in os.listdir(tmp_path) if p.startswith("t.jsonl.")}
    assert first
    bus2 = EventBus()
    bus2.attach_jsonl(path, max_bytes=200, max_segments=50)
    for i in range(20, 40):
        bus2.emit("a", "x", step=i)
    bus2.close()
    second = {p for p in os.listdir(tmp_path) if p.startswith("t.jsonl.")}
    assert first < second, "pre-existing segments survived the re-attach"
    assert [e.data["step"] for e in load_jsonl(path)] == list(range(40))


def test_jsonl_unbounded_legacy_and_validation(tmp_path):
    path = str(tmp_path / "t.jsonl")
    bus = EventBus()
    bus.attach_jsonl(path)                   # no cap: legacy behaviour
    for i in range(200):
        bus.emit("a", "x", step=i)
    bus.close()
    assert not [p for p in os.listdir(tmp_path)
                if p.startswith("t.jsonl.")]
    assert len(load_jsonl(path)) == 200
    with pytest.raises(ValueError):
        EventBus().attach_jsonl(str(tmp_path / "u.jsonl"), max_bytes=0)


def test_load_jsonl_missing_file_still_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_jsonl(str(tmp_path / "never_written.jsonl"))


# ---------------------------------------------------------------------------
# Prometheus exposition: quantiles + label escaping
# ---------------------------------------------------------------------------

def test_prometheus_custom_quantiles_and_default_identity():
    reg = MetricsRegistry()
    h = reg.histogram("serve.latency_ms")
    for v in range(1, 101):
        h.observe(float(v))
    default = reg.to_prometheus()
    assert default == reg.to_prometheus(quantiles=(0.5, 0.99)), \
        "explicit default quantiles must be byte-identical"
    assert 'quantile="0.5"' in default and 'quantile="0.99"' in default
    custom = reg.to_prometheus(quantiles=(0.25, 0.9))
    assert 'quantile="0.25"' in custom and 'quantile="0.9"' in custom
    assert 'quantile="0.99"' not in custom
    line = next(l for l in custom.splitlines() if 'quantile="0.25"' in l)
    assert float(line.rsplit(" ", 1)[1]) == h.percentile(25.0)
    with pytest.raises(ValueError):
        reg.to_prometheus(quantiles=(1.5,))


def test_prometheus_escapes_label_values():
    reg = MetricsRegistry()
    reg.counter("req.total", path='a"b\\c\nnext').inc(3)
    text = reg.to_prometheus()
    assert r'path="a\"b\\c\nnext"' in text
    assert "\nnext" not in text.split("path=")[1].split("}")[0], \
        "a raw newline inside a label value corrupts the exposition"


# ---------------------------------------------------------------------------
# wire: ingest protocol
# ---------------------------------------------------------------------------

def _dgram(host, seq, t_send, events=(), inc=1.0, **extra):
    return {"host": host, "inc": inc, "seq": seq, "t_send": t_send,
            "events": list(events), **extra}


def _wire_event(t_mono, subsystem="train", kind="step", **data):
    return {"seq": 0, "t_mono": t_mono, "t_wall": 0.0,
            "subsystem": subsystem, "kind": kind, **data}


def test_ingest_inc_seq_acceptance_and_gap_accounting():
    col = Collector()
    try:
        assert col.ingest(_dgram(1, 0, 10.0), t_recv=10.1)
        assert not col.ingest(_dgram(1, 0, 10.0), t_recv=10.2), \
            "duplicate seq must be rejected as stale"
        assert col.ingest(_dgram(1, 1, 10.5), t_recv=10.6)
        # seq jumps 1 -> 4: two datagrams lost, one gap event synthesized
        assert col.ingest(_dgram(1, 4, 11.0), t_recv=11.1)
        gaps = col.events("telemetry", "gap")
        assert len(gaps) == 1
        assert gaps[0].data["missed_datagrams"] == 2
        assert gaps[0].data["after_seq"] == 1
        assert gaps[0].data["origin"] == 1
        # an older incarnation is stale wholesale; a newer one supersedes
        assert not col.ingest(_dgram(1, 9, 12.0, inc=0.5), t_recv=12.1)
        assert col.ingest(_dgram(1, 0, 12.5, inc=2.0), t_recv=12.6)
        rep = col.gap_report()[1]
        assert rep == {"datagrams": 4, "missed": 2, "stale": 2}
    finally:
        col.stop()


def test_ingest_accumulates_counter_deltas_and_gauge_last_values():
    col = Collector()
    try:
        col.ingest(_dgram(3, 0, 1.0, counters={"tok": 5.0},
                          gauges={"queue": 2.0}), t_recv=1.1)
        col.ingest(_dgram(3, 1, 2.0, counters={"tok": 2.5},
                          gauges={"queue": 7.0}), t_recv=2.1)
        m = col.host_metrics()[3]
        assert m["counters"] == {"tok": 7.5}
        assert m["gauges"] == {"queue": 7.0}
    finally:
        col.stop()


# ---------------------------------------------------------------------------
# wire: skew + loss merge correctness (the acceptance case)
# ---------------------------------------------------------------------------

def test_merged_timeline_under_skew_and_loss_matches_oracle():
    """Two agents whose monotonic clocks disagree with the collector's
    (+40s and -25s), with a dropped-datagram window on one of them: the
    merged stream must be gap-annotated, per-host emit-ordered, and its
    incident MTTR must match the single-host oracle computed from the
    host's own (unshipped, unskewed) bus."""
    col = Collector()
    shipped = {1: [], 2: []}

    def capture(host):
        def flt(h, payload):
            shipped[host].append(payload)
            return False                 # never hits the real socket
        return flt

    buses = {1: EventBus(), 2: EventBus()}
    agents = {
        1: TelemetryAgent(1, col.addr, buses[1], skew_seconds=40.0,
                          chunk=1, send_filter=capture(1)),
        2: TelemetryAgent(2, col.addr, buses[2], skew_seconds=-25.0,
                          chunk=1, send_filter=capture(2)),
    }
    for host, ag in agents.items():
        buses[host].subscribe(ag._on_event)

    # host 1 lives through an incident with a known repair duration
    buses[1].emit("heartbeat", "failure", host=1)
    time.sleep(0.12)
    buses[1].emit("train", "resume", step=7)
    # host 2 emits ordered filler spanning the same wall-clock span
    for i in range(6):
        buses[2].emit("train", "step", step=i)
        time.sleep(0.01)
    for ag in agents.values():
        ag.flush()                       # chunk=1: one datagram per event

    oracle = Timeline.from_events(buses[1].events()).mttr()
    assert oracle is not None and oracle > 0.1

    # deliver host 1 intact; drop a mid-stream window of host 2 datagrams
    for p in shipped[1]:
        col.ingest(p)
    dropped = 0
    for p in shipped[2]:
        if 2 <= p["seq"] <= 3:
            dropped += 1
            continue
        col.ingest(p)
    assert dropped == 2

    try:
        merged = col.events()
        # (inc, seq) consistency: each host's events keep emit order in
        # the global merge
        for host in (1, 2):
            steps = [e.data["step"] for e in merged
                     if e.data.get("origin") == host and "step" in e.data]
            assert steps == sorted(steps), (host, steps)
        # the loss window is VISIBLE: gap accounting + a merged gap event
        gaps = [e for e in merged
                if (e.subsystem, e.kind) == ("telemetry", "gap")]
        assert len(gaps) == 1 and gaps[0].data["origin"] == 2
        assert gaps[0].data["missed_datagrams"] == 2
        assert col.gap_report()[2]["missed"] == 2
        assert col.gap_report()[1]["missed"] == 0
        # same-host time differences survive skew + offset mapping
        # exactly: merged MTTR == the single-host oracle
        merged_mttr = Timeline.from_events(merged).mttr()
        assert merged_mttr is not None
        assert abs(merged_mttr - oracle) < 1e-9, (merged_mttr, oracle)
        # and the merged timestamps live in the COLLECTOR's clock domain,
        # not the skewed agents' (offset cancels the +/-40s skews)
        span = max(e.t_mono for e in merged) - min(e.t_mono
                                                   for e in merged)
        assert span < 10.0, f"skew leaked into the merged clock: {span}"
    finally:
        col.stop()
        for ag in agents.values():
            ag._sock.close()


def test_agent_collector_over_real_udp():
    """The socket path end to end: background agent thread ships a live
    bus to a listening collector."""
    col = Collector().start()
    bus = EventBus()
    reg = MetricsRegistry()
    reg.counter("tokens").inc(9)
    ag = TelemetryAgent(0, col.addr, bus, registry=reg,
                        period=0.02).start()
    try:
        for i in range(5):
            bus.emit("train", "step", step=i, seconds=0.01)
        deadline = time.time() + 5.0
        while time.time() < deadline:
            if len(col.events("train", "step")) == 5:
                break
            time.sleep(0.02)
        got = col.events("train", "step")
        assert [e.data["step"] for e in got] == [0, 1, 2, 3, 4]
        assert all(e.data["origin"] == 0 for e in got)
        deadline = time.time() + 5.0
        while time.time() < deadline:
            if col.host_metrics().get(0, {}).get("counters"):
                break
            time.sleep(0.02)
        assert col.host_metrics()[0]["counters"] == {"tokens": 9.0}
        assert col.gap_report()[0]["missed"] == 0
    finally:
        ag.stop()
        col.stop()


def test_agent_buffer_sheds_oldest_under_backpressure():
    bus = EventBus()
    ag = TelemetryAgent(0, ("127.0.0.1", 1), bus, buffer_cap=4,
                        send_filter=lambda h, p: False)
    bus.subscribe(ag._on_event)
    for i in range(10):
        bus.emit("a", "x", step=i)
    assert ag.shed == 6
    assert [d["step"] for d in ag._buf] == [6, 7, 8, 9]
    ag._sock.close()


# ---------------------------------------------------------------------------
# detectors
# ---------------------------------------------------------------------------

def _step_ev(seconds, host=None, t=0.0):
    data = {"seconds": seconds}
    if host is not None:
        data["host"] = host
    return Event(seq=0, t_mono=t, t_wall=0.0, subsystem="train",
                 kind="step", data=data)


def test_drift_detector_fires_after_consecutive_and_rearms():
    det = StepTimeDriftDetector(factor=2.0, consecutive=3, warmup=3)
    for _ in range(5):
        assert det.observe(0, _step_ev(0.01)) is None    # healthy baseline
    assert det.observe(0, _step_ev(0.05)) is None        # streak 1
    assert det.observe(0, _step_ev(0.05)) is None        # streak 2
    score = det.observe(0, _step_ev(0.05))               # streak 3: fire
    assert score is not None and 0.5 <= score <= 1.0
    # refractory: the streak re-arms from zero, same drift fires again
    assert det.observe(0, _step_ev(0.05)) is None
    assert det.observe(0, _step_ev(0.05)) is None
    assert det.observe(0, _step_ev(0.05)) is not None
    # the anomalous samples never polluted the EWMA baseline
    assert det._mean[0] == pytest.approx(0.01, rel=1e-6)


def test_drift_detector_needs_warmup_and_tracks_hosts_independently():
    det = StepTimeDriftDetector(factor=2.0, consecutive=1, warmup=3)
    # hot-from-the-start host: first samples define its baseline, no fire
    assert det.observe(0, _step_ev(0.5, host=7)) is None
    assert det.observe(0, _step_ev(0.5, host=7)) is None
    # another host's baseline is its own
    for _ in range(4):
        det.observe(0, _step_ev(0.01, host=8))
    assert det.observe(0, _step_ev(0.05, host=8)) is not None
    assert det.observe(0, _step_ev(0.5, host=7)) is None


def test_jitter_detector_fires_on_interarrival_blowup():
    det = BeatJitterDetector(factor=3.0, consecutive=2, warmup=3)
    t = 0.0
    for _ in range(5):                   # healthy cadence: 50 ms
        t += 0.05
        assert det.observe_arrival(1, t) is None
    t += 0.3                             # 6x gap, streak 1
    assert det.observe_arrival(1, t) is None
    t += 0.3                             # streak 2: fire
    assert det.observe_arrival(1, t) is not None
    assert det.observe(0, _step_ev(9.9)) is None   # event-path is inert


def test_scrub_detector_fires_on_burst_not_single_flip():
    det = ScrubRateDetector(window=3, max_span=60.0)

    def sdc(t):
        return Event(seq=0, t_mono=t, t_wall=0.0, subsystem="sdc",
                     kind="corruption", data={"host": 2})
    assert det.observe(0, sdc(1.0)) is None
    assert det.observe(0, sdc(2.0)) is None
    score = det.observe(0, sdc(3.0))     # 3 hits in 2 s: accelerating
    assert score is not None and score > 0.5
    assert det.observe(0, sdc(4.0)) is None          # refractory cleared
    # a slow trickle (window spans > max_span) never fires
    slow = ScrubRateDetector(window=3, max_span=10.0)
    for t in (0.0, 20.0, 40.0, 60.0):
        assert slow.observe(0, sdc(t)) is None


def test_anomaly_engine_risk_max_merges_and_decays():
    fired = []
    eng = AnomalyEngine(
        detectors=[StepTimeDriftDetector(factor=2.0, consecutive=1,
                                         warmup=2)],
        decay=0.5, on_precursor=lambda h, k, r: fired.append((h, k, r)))
    emitted = []
    eng.emit = lambda *a, **kw: emitted.append((a, kw))
    for _ in range(3):
        eng.observe_event(4, _step_ev(0.01))
    eng.observe_event(4, _step_ev(0.08))             # fires, score 1.0
    assert eng.risk(4) == 1.0
    assert fired == [(4, "step_time_drift", 1.0)]
    assert emitted and emitted[0][0] == ("precursor", "step_time_drift")
    assert emitted[0][1]["host"] == 4
    # healthy samples decay the risk multiplicatively
    eng.observe_event(4, _step_ev(0.01))
    assert eng.risk(4) == pytest.approx(0.5)
    eng.observe_event(4, _step_ev(0.01))
    assert eng.risk(4) == pytest.approx(0.25)
    assert eng.risk_scores() == {4: pytest.approx(0.25)}
    # its own precursor output is never re-ingested (no feedback loop)
    n = eng.precursors
    eng.observe_event(4, Event(seq=0, t_mono=0.0, t_wall=0.0,
                               subsystem="precursor",
                               kind="step_time_drift",
                               data={"host": 4, "seconds": 99.0}))
    assert eng.precursors == n


def test_anomaly_engine_attach_emits_precursors_onto_the_bus():
    bus = EventBus()
    eng = AnomalyEngine(detectors=[StepTimeDriftDetector(
        factor=2.0, consecutive=1, warmup=2)])
    eng.attach(bus)
    for _ in range(3):
        bus.emit("train", "step", seconds=0.01)
    bus.emit("train", "step", seconds=0.09)
    pre = bus.events(subsystem="precursor")
    assert len(pre) == 1
    assert pre[0].kind == "step_time_drift"
    assert pre[0].data["host"] == 0 and pre[0].data["risk"] == 1.0


def test_make_proactive_hook_threshold_cooldown_and_policy_feed():
    from repro.core.policy import CheckpointPolicy

    scores = {}
    policy = CheckpointPolicy(mode="risk_adjusted")
    hook = make_proactive_hook(lambda: dict(scores), threshold=0.5,
                               cooldown_steps=5, policy=policy)
    assert hook(1) is None               # nothing hot
    assert policy.risk == 0.0
    scores[3] = 0.9
    why = hook(2)
    assert why == "risk:3:0.90"
    assert policy.risk == pytest.approx(0.9)
    assert hook(4) is None               # cooling down
    assert policy.risk == pytest.approx(0.9), \
        "policy feed must continue through the cooldown"
    assert hook(7) == "risk:3:0.90"      # cooldown elapsed
    scores.clear()
    scores[1], scores[2] = 0.6, 0.8
    assert hook(20) == "risk:2:0.80"     # hottest host named


# ---------------------------------------------------------------------------
# risk-adjusted Young/Daly
# ---------------------------------------------------------------------------

def test_policy_risk_adjusted_contracts_interval_and_relaxes_back():
    from repro.core.policy import CheckpointPolicy, SystemModel

    def make(mode):
        p = CheckpointPolicy(mode=mode,
                             system=SystemModel(node_mtbf_seconds=3600.0,
                                                num_nodes=1,
                                                restart_seconds=1.0,
                                                downtime_seconds=1.0))
        p.observe_step(1.0)
        p.observe_checkpoint(2.0)
        return p

    yd, ra = make("young_daly"), make("risk_adjusted")
    assert ra.interval_steps() == yd.interval_steps(), \
        "risk 0 must be exactly young_daly"
    ra.observe_risk(1.0)                 # risk_gain=8 -> mtbf / 9
    assert ra.interval_steps() < yd.interval_steps()
    assert ra.interval_steps() >= 1
    contracted = ra.interval_steps()
    ra.observe_risk(0.25)
    assert contracted < ra.interval_steps() < yd.interval_steps()
    ra.observe_risk(0.0)
    assert ra.interval_steps() == yd.interval_steps()
    # clamping: garbage risk never widens or inverts the interval
    ra.observe_risk(50.0)
    assert ra.risk == 1.0
    ra.observe_risk(-3.0)
    assert ra.risk == 0.0
    # young_daly ignores the feed entirely
    yd.observe_risk(1.0)
    assert yd.interval_steps() == make("young_daly").interval_steps()


# ---------------------------------------------------------------------------
# run_bsp proactive checkpoint hook
# ---------------------------------------------------------------------------

def test_run_bsp_proactive_hook_forces_save_and_emits(tmp_path):
    import jax.numpy as jnp
    from repro.core.api import Dependability, DependabilityConfig
    from repro.core.coordinator import run_bsp
    from repro.obs import Observability

    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=100,
        signal_detection=False))
    obs = Observability()
    dep.attach_obs(obs)
    dep.start()
    state = {"step": jnp.array(0), "w": jnp.ones((4,))}
    dep.register_global_state(state)

    class Data:
        def next_batch(self):
            return jnp.ones((4,))

    def train_step(state, batch):
        return ({"step": state["step"] + 1, "w": state["w"] + 0.01},
                {"loss": 1.0})

    calls = []

    def hook(step):
        calls.append(step)
        return "risk:0:0.90" if step == 5 else None

    state, status, hist = run_bsp(dep, train_step, state, Data(), 8,
                                  proactive=hook, final_save=False)
    assert status == "done"
    assert calls == list(range(1, 9)), "hook polled once per superstep"
    assert [s.step for s in dep.save_history] == [5], \
        "exactly the forced save, nothing from the every_n=100 cadence"
    pro = obs.events("checkpoint", "proactive")
    assert len(pro) == 1
    assert pro[0].data == {"step": 5, "reason": "risk:0:0.90"}
    assert obs.registry.counter("checkpoint.proactive").value == 1
    # forced saves re-anchor the cadence like any other
    assert dep.policy._last_ckpt_step == 5
    dep.stop()


def test_run_bsp_cadence_save_wins_over_proactive(tmp_path):
    """When the policy cadence saves at a step anyway, the hook is not
    even polled there — no double save, no forced-save event."""
    import jax.numpy as jnp
    from repro.core.api import Dependability, DependabilityConfig
    from repro.core.coordinator import run_bsp
    from repro.obs import Observability

    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=2,
        signal_detection=False))
    obs = Observability()
    dep.attach_obs(obs)
    dep.start()
    state = {"step": jnp.array(0), "w": jnp.ones((2,))}
    dep.register_global_state(state)

    class Data:
        def next_batch(self):
            return jnp.ones((2,))

    def train_step(state, batch):
        return ({"step": state["step"] + 1, "w": state["w"]},
                {"loss": 1.0})

    polled = []
    state, status, _ = run_bsp(dep, train_step, state, Data(), 6,
                               proactive=lambda s: polled.append(s),
                               final_save=False)
    assert status == "done"
    assert polled == [1, 3, 5], "cadence steps (2, 4, 6) skip the hook"
    assert obs.events("checkpoint", "proactive") == []
    dep.stop()


# ---------------------------------------------------------------------------
# detect -> act invariant
# ---------------------------------------------------------------------------

def _mk(t, subsystem, kind, **data):
    return Event(seq=int(t * 1000), t_mono=t, t_wall=0.0,
                 subsystem=subsystem, kind=kind, data=data)


def test_check_detect_before_act_passes_on_correct_ordering():
    from repro.chaos import check_detect_before_act
    res = check_detect_before_act([
        _mk(1.0, "train", "step", step=1),
        _mk(2.0, "precursor", "step_time_drift", host=2, risk=1.0),
        _mk(3.0, "checkpoint", "proactive", step=6),
        _mk(4.0, "serve", "replica_predrained", replica=0, hosts=[2]),
        _mk(5.0, "heartbeat", "failure", host=2),
    ])
    assert res.passed, res.detail


def test_check_detect_before_act_fails_without_precursor():
    from repro.chaos import check_detect_before_act
    res = check_detect_before_act([
        _mk(1.0, "checkpoint", "proactive", step=3),
    ])
    assert not res.passed and "no precursor" in res.detail


def test_check_detect_before_act_fails_on_act_before_precursor():
    from repro.chaos import check_detect_before_act
    res = check_detect_before_act([
        _mk(1.0, "checkpoint", "proactive", step=3),
        _mk(2.0, "precursor", "step_time_drift", host=0, risk=1.0),
    ])
    assert not res.passed


def test_check_detect_before_act_fails_on_unpredicted_named_failure():
    from repro.chaos import check_detect_before_act
    res = check_detect_before_act([
        _mk(1.0, "heartbeat", "failure", host=2),
        _mk(2.0, "precursor", "step_time_drift", host=2, risk=1.0),
        _mk(3.0, "checkpoint", "proactive", step=6),
    ])
    assert not res.passed, \
        "host 2 failed BEFORE its first precursor — not a prediction"


# ---------------------------------------------------------------------------
# serve pre-drain (fast units)
# ---------------------------------------------------------------------------

def _tiny_serve():
    import jax
    from repro.models import get_config, init_params
    cfg = get_config("granite-3-8b", tiny=True)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def test_engine_pre_drains_risky_replica_and_serves_everything():
    from repro.serve import ServeEngine
    cfg, params = _tiny_serve()
    risk = {}
    eng = ServeEngine(cfg, params, num_replicas=2, slots_per_replica=2,
                      max_len=16, risk_source=lambda: dict(risk),
                      pre_drain_threshold=0.8)
    rids = [eng.submit([3, 4, 5], 8) for _ in range(4)]
    eng.step()                           # work lands on both replicas
    victim_host = eng.router.replicas[1].hosts[0]
    risk[victim_host] = 0.95
    eng.step()                           # crossing risk pre-drains now
    pre = eng.obs.events("serve", "replica_predrained")
    assert len(pre) == 1
    assert pre[0].data["replica"] == 1
    assert pre[0].data["risk"] == pytest.approx(0.95)
    assert not eng.router.replicas[1].healthy
    assert eng.router.replicas[1].fail_reason.startswith("predrain:")
    assert ("replica_predrained", 1) in [
        (k, i) for k, i, _ in eng.router.events]
    assert eng.obs.registry.counter("serve.replica_predrains").value == 1
    res = eng.run()                      # survivor finishes everything
    assert sorted(res) == sorted(rids)
    assert eng.scheduler.failed_rids == []
    # no heartbeat/failure, no replica_failed: a pre-drain is PLANNED —
    # the Timeline must not open an incident for it
    assert eng.obs.events("serve", "replica_failed") == []
    assert Timeline.from_events(eng.obs.events()).incidents == []
    eng.shutdown()


def test_engine_never_pre_drains_the_last_healthy_replica():
    from repro.serve import ServeEngine
    cfg, params = _tiny_serve()
    eng = ServeEngine(cfg, params, num_replicas=1, slots_per_replica=2,
                      max_len=16,
                      risk_source=lambda: {0: 1.0},
                      pre_drain_threshold=0.5)
    rids = [eng.submit([3, 4, 5], 4) for _ in range(2)]
    res = eng.run()
    assert sorted(res) == sorted(rids)
    assert eng.router.replicas[0].healthy, \
        "draining the only replica would stop the service"
    assert eng.obs.events("serve", "replica_predrained") == []
    eng.shutdown()


# ---------------------------------------------------------------------------
# E2E (slow): straggle-then-kill, detect -> act, both planes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_e2e_serve_pre_drain_beats_the_kill():
    """Latency spikes degrade replica 1 (steps 3..14), a kill is
    scheduled for step 16.  The drift detector fed by the engine's
    per-replica step timings must push the host's risk past threshold,
    the engine pre-drains the replica, and the kill never fires (a
    pre-drained replica is no longer dispatched) — zero drops,
    token-identical streams, detect-before-act green."""
    import jax
    import jax.numpy as jnp
    from repro.chaos import (check_detect_before_act, check_token_identical,
                             check_zero_drop, verify)
    from repro.core import FaultInjector
    from repro.models import init_cache
    from repro.obs import Observability
    from repro.serve import ServeEngine
    from repro.train import make_decode_step, make_prefill_step

    cfg, params = _tiny_serve()
    prompts = [list(range(5 + i, 11 + i)) for i in range(4)]
    gen = 24

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    ref = []
    for p in prompts:
        toks = jnp.asarray(p, jnp.int32)[None]
        tok, row = prefill(params, {"tokens": toks}, init_cache(cfg, 1, 32))
        s = [int(tok[0])]
        for _ in range(gen - 1):
            tok, row = decode(params, {"tokens": tok[:, None]}, row)
            s.append(int(tok[0]))
        ref.append(s)

    inj = FaultInjector()
    for step in range(3, 15):
        inj.schedule_latency_spike(step, 0.25, replica_id=1)
    inj.schedule_replica_kill(16, replica_id=1)

    obs = Observability()
    anomaly = AnomalyEngine(detectors=[StepTimeDriftDetector(
        factor=2.0, consecutive=3, warmup=3)])
    anomaly.attach(obs.bus)
    eng = ServeEngine(cfg, params, num_replicas=2, slots_per_replica=2,
                      max_len=32, fault_injector=inj, obs=obs,
                      risk_source=anomaly.risk_scores,
                      pre_drain_threshold=0.8)
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()

    pre = obs.events("serve", "replica_predrained")
    assert len(pre) == 1 and pre[0].data["replica"] == 1, \
        "the risky replica must have been pre-drained"
    assert obs.events("serve", "replica_failed") == [], \
        "proactive won: the scheduled kill never fired"
    assert not inj.replica_kills
    precursors = obs.events(subsystem="precursor")
    assert precursors, "the drift detector must have fired"
    assert precursors[0].t_mono < pre[0].t_mono
    victim_host = eng.router.replicas[1].hosts[0]
    assert all(p.data["host"] == victim_host for p in precursors)

    verify([check_zero_drop(eng.scheduler, rids),
            check_token_identical(res, dict(zip(rids, ref))),
            check_detect_before_act(obs.events())])
    assert eng.scheduler.retried_rids, "drained requests were re-executed"
    eng.shutdown()


_PRELUDE = """
import os
import time
import jax
from repro.chaos import (Scenario, run_scenario_elastic, verify,
                         check_detect_before_act, check_no_lost_steps)
from repro.core import Dependability, DependabilityConfig, HeartbeatEmitter
from repro.data import ShardedPipeline
from repro.launch.mesh import host_device_map
from repro.models import get_config
from repro.obs import AnomalyEngine, Observability, make_proactive_hook
from repro.sharding.api import resolve
from repro.sharding.rules import state_specs
from repro.train import init_state, make_train_step

cfg = get_config("granite-3-8b", tiny=True)
KEY = jax.random.PRNGKey(0)
PERIOD = 0.05

def shardings_for(mesh):
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    specs = state_specs(cfg, tp)
    return jax.tree.map(lambda s: resolve(s, mesh), specs,
                        is_leaf=lambda x: x.__class__.__name__ ==
                        "PartitionSpec")

_STEP_CACHE = {}

def make_step_for(steps):
    # memoized per mesh so a pre-warmed jit is REUSED inside the elastic
    # loop: without this, the first superstep carries seconds of XLA
    # compile time, which poisons the drift detector's EWMA baseline
    def make_step(mesh):
        key = (steps, mesh.axis_names, mesh.devices.shape,
               tuple(d.id for d in mesh.devices.flat))
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = jax.jit(
                make_train_step(cfg, total_steps=steps),
                out_shardings=(shardings_for(mesh), None))
        return _STEP_CACHE[key]
    return make_step
"""


def _run(script, devices=8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["CHAOS_SCENARIOS"] = SCENARIOS
    p = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_e2e_precursor_storm_proactive_checkpoint(tmp_path):
    """scenarios/precursor_storm.json through run_elastic with the
    telemetry plane wired in: host 2 straggles over [4, 10) — every BSP
    superstep stretches, the drift detector fires precursors — then the
    host is killed at 10 and rejoins at 16.  The precursor must land
    BEFORE the kill, force a proactive checkpoint, and the mesh must
    shrink and re-grow with no lost supersteps."""
    out = _run(f"""
    STEPS = 20
    sc = Scenario.from_json(
        os.path.join(os.environ["CHAOS_SCENARIOS"],
                     "precursor_storm.json"))
    # the storm's deferred kill takes host 2; fail its rack-mate at the
    # same step so the survivor count keeps a legal (data, model) grid
    # (6 devices has no tp<=2 factorization that divides the FSDP leaves)
    sc.kill_hosts([3], at=10).rejoin(3, at=16).validate()
    hosts = host_device_map(4)               # 4 hosts x 2 devices
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=r"{tmp_path}", policy_mode="every_n", every_n=5,
        heartbeat=True, heartbeat_period=PERIOD,
        heartbeat_timeout_factor=5.0, signal_detection=False,
        monitor_hosts=4), host_id=0, num_hosts=1).start()
    obs = Observability()
    dep.attach_obs(obs)
    anomaly = AnomalyEngine()
    anomaly.attach(obs.bus)
    hook = make_proactive_hook(anomaly.risk_scores, threshold=0.5)
    ems = {{h: HeartbeatEmitter(h, dep.monitor.addr, PERIOD).start()
           for h in (1, 2, 3)}}
    ems[0] = dep.emitter

    data = ShardedPipeline(cfg, 16, 4, dp_width=4)
    state = init_state(cfg, KEY)
    template = jax.eval_shape(lambda: init_state(cfg, KEY))

    # pre-compile the full-mesh step so superstep 1's timing is a real
    # step, not XLA compile — the detector's baseline must be honest
    from repro.core.elastic import survivor_mesh
    make_step = make_step_for(STEPS)
    mesh0 = survivor_mesh([d for h in sorted(hosts)
                           for d in hosts[h]], model_axis=2)
    warm = jax.device_put(state, shardings_for(mesh0))
    jax.block_until_ready(
        make_step(mesh0)(warm, data.shards[0].peek_global_batch()))
    del warm

    state, info = run_scenario_elastic(
        dep, make_step_for(STEPS), state, data, STEPS, scenario=sc,
        emitters=ems, host_devices=hosts, model_axis=2, like=template,
        shardings_fn=shardings_for, step_seconds=0.3, proactive=hook)

    assert info["status"] == "done", info["status"]
    kinds = [e.kind for e in info["events"]]
    assert "shrink" in kinds and "grow" in kinds, kinds
    shrunk = [h for e in info["events"] if e.kind == "shrink"
              for h in e.hosts]
    assert sorted(shrunk) == [2, 3], shrunk
    assert info["dp"] == 4                   # hosts 2+3 healed

    evs = obs.events()
    pre = [e for e in evs if e.subsystem == "precursor"]
    assert pre, "the drift detector must have fired during the storm"
    forced = [e for e in evs
              if (e.subsystem, e.kind) == ("checkpoint", "proactive")]
    assert forced, "a precursor must have forced a checkpoint"
    fails = [e for e in evs
             if (e.subsystem, e.kind) == ("heartbeat", "failure")]
    assert fails and all(p.t_mono < f.t_mono for p in pre[:1]
                         for f in fails), \
        "detection must precede the kill's heartbeat failure"
    assert forced[0].data["step"] < 10, \
        "the proactive checkpoint must land before the kill step"
    verify([check_detect_before_act(evs),
            check_no_lost_steps(info["history"], STEPS)])

    for em in ems.values():
        em.stop()
    dep.stop()
    print("precursor storm OK: precursors=", len(pre),
          "forced_saves=", [e.data["step"] for e in forced],
          "events=", kinds)
    """, devices=8)
    assert "precursor storm OK" in out


# ---------------------------------------------------------------------------
# chaos schema: precursor_storm scenario kind
# ---------------------------------------------------------------------------

def test_precursor_storm_scenario_round_trip_and_validation(tmp_path):
    from repro.chaos import Scenario, ScenarioError

    sc = Scenario.from_json(os.path.join(SCENARIOS,
                                         "precursor_storm.json"))
    ev = next(e for e in sc.events if e.kind == "precursor_storm")
    assert ev.args == {"host": 2, "factor": 4.0, "kill": True}
    assert (ev.at, ev.until) == (4.0, 10.0)
    p = str(tmp_path / "round.json")
    sc.to_json(p)
    assert Scenario.from_json(p).to_dict() == sc.to_dict()

    with pytest.raises(ScenarioError):
        Scenario("bad").precursor_storm(1, factor=1.0, window=(2, 5))
    # the deferred kill participates in kill/rejoin timeline validation:
    # rejoining a host before its storm's kill-at-window-end is an error
    bad = Scenario("bad2")
    bad.precursor_storm(1, factor=3.0, window=(2, 8))
    bad.rejoin(1, at=5)
    with pytest.raises(ScenarioError):
        bad.validate()


def test_precursor_storm_drives_sim_and_dead_intervals():
    from repro.chaos import ControlPlaneSim, Scenario

    sc = Scenario("storm", clock="step")
    sc.precursor_storm(2, factor=4.0, window=(3, 7))
    sc.rejoin(2, at=20)
    rep = ControlPlaneSim(4, devices_per_host=2).run(sc)
    assert any(d["host"] == 2 for d in rep.detections), \
        "the sim must see the storm's deferred kill"
    from repro.chaos.driver import TrainScenarioDriver

    class _Em:
        def pause(self):
            pass

        def resume(self):
            pass
    drv = TrainScenarioDriver(sc, emitters={h: _Em() for h in range(4)},
                              settle_seconds=0.0)
    assert drv.dead_intervals() == {2: [(7.0, 20.0)]}
    assert len(drv.injector.pending()) == 4, \
        "one straggle per storm step [3, 7)"
