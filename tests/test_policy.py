"""Young/Daly + checkpoint-policy property tests (hypothesis)."""
import math

import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.policy import CheckpointPolicy, SystemModel, \
    young_daly_period

pos = st.floats(min_value=1e-3, max_value=1e9, allow_nan=False,
                allow_infinity=False)


@given(mu=pos, c=pos)
@settings(max_examples=200, deadline=None)
def test_young_daly_formula(mu, c):
    """T = sqrt(2 (mu - D + R) C) — paper eq. (1) exactly (D=R=0 here)."""
    t = young_daly_period(mu, c)
    assert math.isclose(t, math.sqrt(2 * mu * c), rel_tol=1e-9)


@given(mu=pos, c1=pos, c2=pos)
@settings(max_examples=100, deadline=None)
def test_young_daly_monotone_in_cost(mu, c1, c2):
    lo, hi = sorted((c1, c2))
    assert young_daly_period(mu, lo) <= young_daly_period(mu, hi)


@given(c=pos, n1=st.integers(1, 10000), n2=st.integers(1, 10000))
@settings(max_examples=100, deadline=None)
def test_more_nodes_shorter_period(c, n1, n2):
    """System MTBF = node MTBF / N: bigger fleets checkpoint more often."""
    lo, hi = sorted((n1, n2))
    t_lo = young_daly_period(SystemModel(num_nodes=lo).system_mtbf, c)
    t_hi = young_daly_period(SystemModel(num_nodes=hi).system_mtbf, c)
    assert t_hi <= t_lo


@given(mu=pos, c=pos, d=st.floats(0, 1e3), r=st.floats(0, 1e3))
@settings(max_examples=100, deadline=None)
def test_young_daly_never_negative(mu, c, d, r):
    assert young_daly_period(mu, c, r, d) >= 0.0


@given(mu=pos, c=pos, d=st.floats(0, 1e3), r=st.floats(0, 1e3))
@settings(max_examples=100, deadline=None)
def test_formula_standard_never_longer_than_paper(mu, c, d, r):
    """The paper prints mu - D + R; textbook Young/Daly is mu - D - R.
    The standard bracket is smaller by 2R, so its period can't exceed the
    paper's — i.e. "standard" checkpoints at least as often."""
    t_paper = young_daly_period(mu, c, r, d, formula="paper")
    t_std = young_daly_period(mu, c, r, d, formula="standard")
    assert t_std <= t_paper + 1e-12


def test_formula_brackets_differ_by_2r():
    mu, c, d, r = 3600.0, 0.5, 60.0, 120.0
    t_paper = young_daly_period(mu, c, r, d, formula="paper")
    t_std = young_daly_period(mu, c, r, d, formula="standard")
    assert math.isclose(t_paper, math.sqrt(2 * (mu - d + r) * c))
    assert math.isclose(t_std, math.sqrt(2 * (mu - d - r) * c))
    assert t_std < t_paper


def test_formula_rejects_unknown():
    with pytest.raises(ValueError):
        young_daly_period(100.0, 1.0, formula="bogus")


def test_policy_threads_formula():
    for formula in ("paper", "standard"):
        p = CheckpointPolicy(mode="young_daly", formula=formula,
                             system=SystemModel(node_mtbf_seconds=3600 * 100,
                                                num_nodes=100))
        for _ in range(5):
            p.observe_step(1.0)
        p.observe_checkpoint(0.5)
        assert p.interval_steps() >= 1
    # with mu ~ D + R the brackets diverge hard: paper ~ 2R, standard ~ floor
    sys_edge = SystemModel(node_mtbf_seconds=180.0, num_nodes=1,
                           restart_seconds=120.0, downtime_seconds=60.0)
    p_paper = CheckpointPolicy(mode="young_daly", formula="paper",
                               system=sys_edge)
    p_std = CheckpointPolicy(mode="young_daly", formula="standard",
                             system=sys_edge)
    for p in (p_paper, p_std):
        for _ in range(5):
            p.observe_step(1.0)
        p.observe_checkpoint(0.5)
    assert p_std.interval_steps() <= p_paper.interval_steps()


def test_every_n_policy():
    p = CheckpointPolicy(mode="every_n", every_n=3)
    fired = [s for s in range(1, 13) if p.should_checkpoint(s)
             and (p.record_checkpoint(s) or True)]
    assert fired == [3, 6, 9, 12]


def test_young_daly_policy_adapts():
    p = CheckpointPolicy(mode="young_daly",
                         system=SystemModel(node_mtbf_seconds=3600 * 100,
                                            num_nodes=100))
    # mu = 3600 s; step 1 s; C 0.5 s -> T_opt = sqrt(2*~3700*0.5) ~ 61 s
    for _ in range(5):
        p.observe_step(1.0)
    p.observe_checkpoint(0.5)
    assert 30 <= p.interval_steps() <= 120
    # cheaper checkpoints (codec/async) => checkpoint more often
    p2 = CheckpointPolicy(mode="young_daly",
                          system=SystemModel(node_mtbf_seconds=3600 * 100,
                                             num_nodes=100))
    for _ in range(5):
        p2.observe_step(1.0)
    p2.observe_checkpoint(0.05)
    assert p2.interval_steps() < p.interval_steps()


def test_overhead_metric_eq2():
    """Paper eq. (2): overhead = (M_with - M_without) / M_with."""
    ov = CheckpointPolicy.fault_free_overhead(13441.8312,
                                              13441.8312 - 174.9448)
    assert abs(ov - 174.9448 / 13441.8312) < 1e-12
    assert abs(ov - 0.013) < 0.002  # the paper's ~1.4% (1.3015%)


def test_observe_checkpoint_kind_weighted_amortized_cost():
    """Delta checkpointing makes C bimodal (cheap deltas + periodic
    fulls); with ``kind`` the policy tracks one EMA per kind and C is the
    count-weighted mean — the amortized per-save cost — instead of an EMA
    whipsawing between the two modes."""
    p = CheckpointPolicy(mode="young_daly", ema=0.5)
    p.observe_checkpoint(10.0, kind="full")
    for _ in range(4):
        p.observe_checkpoint(1.0, kind="delta")
    assert p.ckpt_cost_s == pytest.approx((10.0 + 4.0) / 5.0)
    # the legacy single-EMA path is untouched
    q = CheckpointPolicy(mode="young_daly", ema=0.5)
    q.observe_checkpoint(2.0)
    q.observe_checkpoint(4.0)
    assert q.ckpt_cost_s == pytest.approx(3.0)


def test_smaller_measured_c_tightens_interval():
    """The whole point of shrinking C: the adaptive Young/Daly interval
    tightens automatically when the measured save cost drops (delta saves
    feed the smaller cost through the same observe path)."""
    full = CheckpointPolicy(mode="young_daly",
                            system=SystemModel(node_mtbf_seconds=3600 * 100,
                                               num_nodes=100))
    delta = CheckpointPolicy(mode="young_daly",
                             system=SystemModel(node_mtbf_seconds=3600 * 100,
                                                num_nodes=100))
    for _ in range(5):
        full.observe_step(1.0)
        delta.observe_step(1.0)
    full.observe_checkpoint(2.0, kind="full")
    delta.observe_checkpoint(2.0, kind="full")
    for _ in range(9):
        delta.observe_checkpoint(0.1, kind="delta")
    assert delta.interval_steps() < full.interval_steps()
