"""Shard I/O engine: zero-copy CRC, streamed .npy writes, pooled jobs."""
import os
import zlib

import numpy as np
import pytest

from repro.core.io_engine import (ShardIOEngine, crc32_array, fsync_path,
                                  write_npy)


@pytest.mark.parametrize("shape,dtype", [
    ((17,), np.float32), ((64, 256), np.float32), ((3, 5, 7), np.float64),
    ((1000,), np.int8), ((), np.float32),
])
def test_crc32_array_matches_tobytes(shape, dtype):
    rng = np.random.default_rng(0)
    arr = rng.standard_normal(shape).astype(dtype, copy=False) \
        if dtype != np.int8 else rng.integers(-100, 100, shape).astype(np.int8)
    expect = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
    assert crc32_array(arr) == expect
    # chunked traversal must agree with one-shot
    assert crc32_array(arr, chunk=13) == expect


def test_crc32_array_noncontiguous():
    arr = np.arange(100, dtype=np.float32).reshape(10, 10)[:, ::2]
    expect = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
    assert crc32_array(arr) == expect


def test_write_npy_single_roundtrip(tmp_path):
    arr = np.random.default_rng(1).standard_normal((33, 17)).astype(np.float32)
    path = str(tmp_path / "a.npy")
    nbytes, crc = write_npy(path, arr, chunk=64)
    assert nbytes == arr.nbytes
    loaded = np.load(path)
    assert np.array_equal(loaded, arr)
    assert crc32_array(loaded) == crc


def test_write_npy_parts_pack_as_uint8(tmp_path):
    q = np.random.default_rng(2).integers(-127, 127, (5, 256)).astype(np.int8)
    s = np.random.default_rng(3).standard_normal(5).astype(np.float32)
    path = str(tmp_path / "packed.npy")
    nbytes, crc = write_npy(path, [q, s])
    assert nbytes == q.nbytes + s.nbytes
    payload = np.load(path)
    assert payload.dtype == np.uint8 and payload.shape == (nbytes,)
    assert np.array_equal(payload[:q.nbytes].view(np.int8).reshape(q.shape), q)
    assert np.array_equal(payload[q.nbytes:].view(np.float32), s)
    assert crc32_array(payload) == crc


def test_engine_runs_jobs_in_parallel(tmp_path):
    eng = ShardIOEngine(threads=4, fsync_mode="none")
    arrs = [np.full((100,), i, np.float32) for i in range(16)]

    def job(i):
        p = str(tmp_path / f"{i}.npy")
        n, _ = write_npy(p, arrs[i])
        return p, n

    import functools
    total, paths = eng.run_jobs([functools.partial(job, i)
                                 for i in range(16)])
    assert total == sum(a.nbytes for a in arrs)
    assert len(paths) == 16 and all(os.path.exists(p) for p in paths)
    out = eng.read_many([functools.partial(np.load, p) for p in paths])
    for i, a in enumerate(out):
        assert np.array_equal(a, arrs[i])
    eng.close()


def test_engine_propagates_job_errors(tmp_path):
    eng = ShardIOEngine(threads=2, fsync_mode="none")

    def bad():
        raise RuntimeError("disk on fire")

    def good():
        return str(tmp_path / "x"), 0

    with pytest.raises(RuntimeError, match="disk on fire"):
        eng.run_jobs([good, bad, good])
    eng.close()


@pytest.mark.parametrize("mode", ["batch", "per_file", "none"])
def test_engine_finalize_modes(tmp_path, mode):
    eng = ShardIOEngine(threads=2, fsync_mode=mode)
    paths = []
    for i in range(3):
        p = str(tmp_path / f"{i}.npy")
        write_npy(p, np.zeros(8, np.float32), fsync=eng.per_file_fsync)
        paths.append(p)
    eng.finalize(str(tmp_path), paths)  # must not raise in any mode
    eng.close()


def test_engine_rejects_bad_fsync_mode():
    with pytest.raises(ValueError, match="fsync_mode"):
        ShardIOEngine(fsync_mode="sometimes")


def test_fsync_path_on_dir(tmp_path):
    fsync_path(str(tmp_path))
