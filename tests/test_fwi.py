"""FWI 4D case study: physics, inversion, and DeLIA protection."""
import jax
import numpy as np
import pytest

from repro.apps.fwi import (FWIConfig, forward_model, init_fwi_state,
                            make_fwi_step, make_observed_data, run_fwi,
                            shot_positions, true_models)
from repro.core import Dependability, DependabilityConfig, FaultInjector

CFG = FWIConfig(nz=50, nx=50, nt=300, n_shots=2, iterations=6)


@pytest.fixture(scope="module")
def observed():
    return make_observed_data(CFG)


def test_forward_model_deterministic_and_finite(observed):
    base, _ = true_models(CFG)
    sx, _ = shot_positions(CFG)
    s1 = forward_model(base, sx[0], CFG)
    s2 = forward_model(base, sx[0], CFG)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    assert np.isfinite(np.asarray(s1)).all()
    assert np.abs(np.asarray(s1)).max() > 0  # wave reaches receivers


def test_4d_surveys_differ(observed):
    assert not np.array_equal(np.asarray(observed["baseline"]),
                              np.asarray(observed["monitor"]))


def test_inversion_reduces_misfit(observed):
    state, hist = run_fwi(CFG, observed["baseline"])
    losses = [h["loss"] for h in hist]
    assert losses[-1] < 0.5 * losses[0], losses


def test_delia_wrapped_fwi_bit_exact(tmp_path, observed):
    ref_state, _ = run_fwi(CFG, observed["baseline"])
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=2,
        signal_detection=False)).start()
    st, _ = run_fwi(CFG, observed["baseline"], dep=dep)
    assert np.array_equal(np.asarray(ref_state["params"]["c"]),
                          np.asarray(st["params"]["c"]))
    dep.stop()


def test_fwi_crash_recovery(tmp_path, observed):
    ref_state, _ = run_fwi(CFG, observed["baseline"])
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=2,
        signal_detection=False)).start()
    injector = FaultInjector()
    injector.schedule_failstop(4)
    st, _ = run_fwi(CFG, observed["baseline"], dep=dep,
                    fault_injector=injector)
    assert np.array_equal(np.asarray(ref_state["params"]["c"]),
                          np.asarray(st["params"]["c"]))
    dep.stop()


def test_fwi_local_scope_shard_checkpointing(tmp_path, observed):
    """The configuration the paper could NOT validate: local-scope (per
    DP shard) data checkpointing, through a fail-stop, bit-exact."""
    ref_state, _ = run_fwi(CFG, observed["baseline"])
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=2,
        signal_detection=False)).start()
    injector = FaultInjector()
    injector.schedule_failstop(4)
    st, _ = run_fwi(CFG, observed["baseline"], dep=dep,
                    fault_injector=injector, local_scope=True, dp_width=2)
    assert np.array_equal(np.asarray(ref_state["params"]["c"]),
                          np.asarray(st["params"]["c"]))
    # each shard's cursor + shot slice landed as its own file
    import os
    latest = os.path.join(str(tmp_path),
                          f"step_{dep.manager.latest_step():08d}")
    files = [f for f in os.listdir(latest) if f.startswith("local_s")]
    assert len(files) == 2
    shards = dep.manager.restore_local_shards(dep.manager.latest_step())
    assert [(d["shot_lo"], d["shot_hi"]) for d in shards] == [(0, 1), (1, 2)]
    dep.stop()


def test_fwi_shard_state_remaps_across_widths():
    """Per-shard dicts saved at width 2 restore onto width 1 (shrink after
    losing a worker): spans retile, the cursor carries over."""
    from repro.apps.fwi import FWIShardData

    d_obs = np.zeros((4, 8, 3), np.float32)
    a = FWIShardData(d_obs, dp_width=2)
    for _ in range(5):
        a.next_batch()
    saved = a.shard_state_dicts()
    assert [(d["shot_lo"], d["shot_hi"]) for d in saved] == [(0, 2), (2, 4)]

    b = FWIShardData(d_obs, dp_width=1)
    b.load_shard_state_dicts(saved)
    assert b.step == 5 and b.remapped_from == 2
    assert b.spans == [(0, 4)]

    c = FWIShardData(d_obs, dp_width=4)      # grow: finer repartition
    c.load_shard_state_dicts(saved)
    assert c.step == 5 and c.spans == [(0, 1), (1, 2), (2, 3), (3, 4)]
    assert np.array_equal(c.shard_batch(2)["d_obs"], d_obs[2:3])

    # tampered spans (a missing slice) must be rejected, not papered over
    bad = [dict(saved[0]), dict(saved[1])]
    bad[1]["shot_lo"] = 3
    with pytest.raises(AssertionError, match="tile"):
        FWIShardData(d_obs, dp_width=2).load_shard_state_dicts(bad)
