"""FWI 4D case study: physics, inversion, and DeLIA protection."""
import jax
import numpy as np
import pytest

from repro.apps.fwi import (FWIConfig, forward_model, init_fwi_state,
                            make_fwi_step, make_observed_data, run_fwi,
                            shot_positions, true_models)
from repro.core import Dependability, DependabilityConfig, FaultInjector

CFG = FWIConfig(nz=50, nx=50, nt=300, n_shots=2, iterations=6)


@pytest.fixture(scope="module")
def observed():
    return make_observed_data(CFG)


def test_forward_model_deterministic_and_finite(observed):
    base, _ = true_models(CFG)
    sx, _ = shot_positions(CFG)
    s1 = forward_model(base, sx[0], CFG)
    s2 = forward_model(base, sx[0], CFG)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    assert np.isfinite(np.asarray(s1)).all()
    assert np.abs(np.asarray(s1)).max() > 0  # wave reaches receivers


def test_4d_surveys_differ(observed):
    assert not np.array_equal(np.asarray(observed["baseline"]),
                              np.asarray(observed["monitor"]))


def test_inversion_reduces_misfit(observed):
    state, hist = run_fwi(CFG, observed["baseline"])
    losses = [h["loss"] for h in hist]
    assert losses[-1] < 0.5 * losses[0], losses


def test_delia_wrapped_fwi_bit_exact(tmp_path, observed):
    ref_state, _ = run_fwi(CFG, observed["baseline"])
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=2,
        signal_detection=False)).start()
    st, _ = run_fwi(CFG, observed["baseline"], dep=dep)
    assert np.array_equal(np.asarray(ref_state["params"]["c"]),
                          np.asarray(st["params"]["c"]))
    dep.stop()


def test_fwi_crash_recovery(tmp_path, observed):
    ref_state, _ = run_fwi(CFG, observed["baseline"])
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=2,
        signal_detection=False)).start()
    injector = FaultInjector().schedule_failstop(4)
    st, _ = run_fwi(CFG, observed["baseline"], dep=dep,
                    fault_injector=injector)
    assert np.array_equal(np.asarray(ref_state["params"]["c"]),
                          np.asarray(st["params"]["c"]))
    dep.stop()
