"""Data-pipeline determinism + local-state resume (hypothesis)."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.data import make_pipeline
from repro.models import get_config

CFG = get_config("granite-3-8b", tiny=True)


def _tok(b):
    return np.asarray(b["tokens"])


@given(crash_at=st.integers(1, 8), seed=st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_resume_reproduces_stream(crash_at, seed):
    ref = make_pipeline(CFG, 8, 2, seed=seed)
    stream = [_tok(ref.next_batch()) for _ in range(10)]

    p = make_pipeline(CFG, 8, 2, seed=seed)
    for _ in range(crash_at):
        p.next_batch()
    saved = p.state_dict()

    q = make_pipeline(CFG, 8, 2, seed=seed)
    q.load_state_dict(saved)
    for i in range(crash_at, 10):
        assert np.array_equal(_tok(q.next_batch()), stream[i])


@given(step=st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_batches_are_pure_functions_of_step(step):
    a = make_pipeline(CFG, 8, 2, seed=3)
    b = make_pipeline(CFG, 8, 2, seed=3)
    assert np.array_equal(_tok(a.peek_batch(step)), _tok(b.peek_batch(step)))


def test_hosts_get_disjoint_data():
    a = make_pipeline(CFG, 8, 4, seed=0, host_id=0, num_hosts=2)
    b = make_pipeline(CFG, 8, 4, seed=0, host_id=1, num_hosts=2)
    assert a.host_batch == b.host_batch == 2
    assert not np.array_equal(_tok(a.next_batch()), _tok(b.next_batch()))


def test_targets_shift_tokens():
    p = make_pipeline(CFG, 8, 2, seed=0)
    b = p.next_batch()
    assert b["tokens"].shape == b["targets"].shape
    assert (np.asarray(b["targets"]) < CFG.vocab_size).all()


def test_embedding_input_pipeline():
    cfg = get_config("qwen2-vl-2b", tiny=True)
    p = make_pipeline(cfg, 8, 2, seed=0)
    b = p.next_batch()
    assert b["embeddings"].shape == (2, 8, cfg.d_model)
    assert b["positions"].shape == (3, 2, 8)

