"""Per-kernel allclose sweeps vs the pure-jnp ref.py oracles (interpret
mode — kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 128, 4, 4, 32), (2, 256, 4, 2, 64), (1, 256, 8, 1, 64),
    (1, 512, 2, 2, 128),
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0), (False, 0, 0.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, hd, causal, window, softcap,
                               dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=128, block_k=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,S,Di,N", [
    (1, 64, 32, 4), (2, 128, 64, 8), (1, 256, 128, 16),
])
def test_selective_scan_sweep(B, S, Di, N):
    from repro.kernels.selective_scan.ops import selective_scan
    from repro.kernels.selective_scan.ref import selective_scan_ref

    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))) * 0.1
    bm = jax.random.normal(ks[2], (B, S, N))
    cm = jax.random.normal(ks[3], (B, S, N))
    a = -jnp.exp(jax.random.normal(ks[4], (Di, N)) * 0.2)
    h0 = jax.random.normal(ks[5], (B, Di, N)) * 0.1
    y, h = selective_scan(x, dt, bm, cm, a, h0, block_c=32, chunk=32,
                          interpret=True)
    yr, hr = selective_scan_ref(x, dt, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("shape", [(255,), (256,), (1000,), (64, 256),
                                   (7, 13, 5)])
@pytest.mark.parametrize("scale", [0.01, 1.0, 100.0])
def test_ckpt_codec_sweep(shape, scale):
    from repro.kernels.ckpt_codec.ops import dequantize, quantize
    from repro.kernels.ckpt_codec.ref import dequantize_ref, quantize_ref

    x = jax.random.normal(KEY, shape) * scale
    q, s = quantize(x, interpret=True)
    qr, sr = quantize_ref(x)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = dequantize(q, s, shape, interpret=True)
    yr = dequantize_ref(qr, sr, shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)
    # quantization error bounded by half a quantization step per block
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 * 0.5 + 1e-6


@pytest.mark.parametrize("shape", [(4, 64), (2, 16, 128), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    from repro.kernels.rmsnorm.ops import rms_norm
    from repro.kernels.rmsnorm.ref import rms_norm_ref

    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],),
                          jnp.float32)
    y = rms_norm(x, w, interpret=True)
    yr = rms_norm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=2e-2,
                               rtol=2e-2)
