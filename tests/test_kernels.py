"""Per-kernel allclose sweeps vs the pure-jnp ref.py oracles (interpret
mode — kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 128, 4, 4, 32), (2, 256, 4, 2, 64), (1, 256, 8, 1, 64),
    (1, 512, 2, 2, 128),
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0), (False, 0, 0.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, hd, causal, window, softcap,
                               dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=128, block_k=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,S,Di,N", [
    (1, 64, 32, 4), (2, 128, 64, 8), (1, 256, 128, 16),
])
def test_selective_scan_sweep(B, S, Di, N):
    from repro.kernels.selective_scan.ops import selective_scan
    from repro.kernels.selective_scan.ref import selective_scan_ref

    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))) * 0.1
    bm = jax.random.normal(ks[2], (B, S, N))
    cm = jax.random.normal(ks[3], (B, S, N))
    a = -jnp.exp(jax.random.normal(ks[4], (Di, N)) * 0.2)
    h0 = jax.random.normal(ks[5], (B, Di, N)) * 0.1
    y, h = selective_scan(x, dt, bm, cm, a, h0, block_c=32, chunk=32,
                          interpret=True)
    yr, hr = selective_scan_ref(x, dt, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("shape", [(255,), (256,), (1000,), (64, 256),
                                   (7, 13, 5),
                                   # block counts that are NOT a multiple of
                                   # the kernel's ROWS=64 tile (pad path)
                                   (100, 256), (65, 256), (300, 100),
                                   (16651,)])
@pytest.mark.parametrize("scale", [0.01, 1.0, 100.0])
def test_ckpt_codec_sweep(shape, scale):
    from repro.kernels.ckpt_codec.ops import dequantize, quantize
    from repro.kernels.ckpt_codec.ref import dequantize_ref, quantize_ref

    x = jax.random.normal(KEY, shape) * scale
    q, s = quantize(x, interpret=True)
    qr, sr = quantize_ref(x)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = dequantize(q, s, shape, interpret=True)
    yr = dequantize_ref(qr, sr, shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)
    # quantization error bounded by half a quantization step per block
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 * 0.5 + 1e-6


@pytest.mark.parametrize("nb", [1, 63, 64, 65, 100, 128, 130])
def test_ckpt_codec_blocks_any_row_count(nb):
    """Kernel-level check: quantize_blocks/dequantize_blocks handle any NB
    (ROWS-padding path) and match the block-level oracle exactly."""
    from repro.kernels.ckpt_codec.kernel import (dequantize_blocks,
                                                 quantize_blocks)
    from repro.kernels.ckpt_codec.ref import quantize_blocks_ref

    x = jax.random.normal(KEY, (nb, 256)) * 3.0
    q, s = quantize_blocks(x, interpret=True)
    assert q.shape == (nb, 256) and s.shape == (nb, 128)
    qr, sr = quantize_blocks_ref(x)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s[:, 0]), np.asarray(sr),
                               rtol=1e-6)
    y = dequantize_blocks(q, s, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(qr, np.float32) * np.asarray(sr)[:, None],
        rtol=1e-6)


@pytest.mark.parametrize("shape", [(255,), (300, 100), (65, 256), (7, 13, 5)])
def test_device_codec_kernel_matches_numpy_codec(shape):
    """Acceptance: the on-device codec (interpret-mode Pallas kernel) round-
    trips within quantization tolerance of the numpy Int8BlockCodec for
    arbitrary leaf shapes, including nb % 64 != 0 — and produces the exact
    same payload bytes."""
    from repro.core.codec import DeviceCodec, Int8BlockCodec

    x = jax.random.normal(KEY, shape) * 5.0
    dc = DeviceCodec(use_kernel=True, interpret=True)
    q, s = dc.encode(x)
    codec = Int8BlockCodec()
    ref_payload, meta = codec.encode(np.asarray(x))
    nb = meta["blocks"]
    q_host = ref_payload[:nb * 256].view(np.int8).reshape(nb, 256)
    s_host = ref_payload[nb * 256:].view(np.float32)
    assert np.array_equal(np.asarray(q), q_host)       # int8 payload exact
    np.testing.assert_allclose(np.asarray(s), s_host,  # scales: XLA may fold
                               rtol=1e-6)              # /127 -> *(1/127)
    # device decode == numpy decode == original (within quant tolerance)
    y_dev = np.asarray(dc.decode(q, s, shape))
    y_np = codec.decode(ref_payload, meta)
    np.testing.assert_allclose(y_dev, y_np, rtol=1e-6, atol=1e-7)
    err = np.abs(y_np - np.asarray(x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 * 0.5 + 1e-6


@pytest.mark.parametrize("M,K,N", [
    (8, 16, 8), (64, 96, 80), (128, 128, 128), (130, 200, 72),
])
def test_abft_matmul_matches_oracle(M, K, N):
    from repro.kernels.abft_matmul.ops import abft_matmul
    from repro.kernels.abft_matmul.ref import abft_matmul_ref

    a = jax.random.normal(KEY, (M, K))
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (K, N))
    c, rep = abft_matmul(a, b, interpret=True)
    ref = abft_matmul_ref(a, b)[:-1, :-1]
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
    # clean input: nothing detected, nothing "corrected"
    assert not bool(rep["detected"]) and not bool(rep["corrected"])


@pytest.mark.parametrize("i,j,delta", [(3, 7, 50.0), (0, 0, -200.0),
                                       (63, 79, 17.5)])
def test_abft_matmul_corrects_single_output_error(i, j, delta):
    """Acceptance: a single injected output-element error is located and
    corrected in place — the result matches the reference as if nothing
    happened (no rollback)."""
    from repro.kernels.abft_matmul.ops import abft_matmul
    from repro.kernels.abft_matmul.ref import abft_matmul_ref

    a = jax.random.normal(KEY, (64, 96))
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (96, 80))
    ref = abft_matmul_ref(a, b)[:-1, :-1]
    c, rep = abft_matmul(a, b, inject=(i, j, delta), interpret=True)
    assert bool(rep["detected"]) and bool(rep["corrected"])
    assert (int(rep["row"]), int(rep["col"])) == (i, j)
    np.testing.assert_allclose(float(rep["delta"]), delta, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref), rtol=1e-5,
                               atol=1e-4)


def test_abft_matmul_checksum_element_hit_leaves_data_intact():
    from repro.kernels.abft_matmul.ops import abft_matmul
    from repro.kernels.abft_matmul.ref import abft_matmul_ref

    a = jax.random.normal(KEY, (64, 96))
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (96, 80))
    ref = abft_matmul_ref(a, b)[:-1, :-1]
    for inject in ((64, 7, 50.0), (5, 80, 50.0)):  # checksum row / column
        c, rep = abft_matmul(a, b, inject=inject, interpret=True)
        assert bool(rep["detected"]) and bool(rep["corrected"])
        np.testing.assert_allclose(np.asarray(c), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_abft_matmul_double_error_detected_not_corrected():
    from repro.kernels.abft_matmul.ops import verify_and_correct
    from repro.kernels.abft_matmul.ref import abft_matmul_ref

    a = jax.random.normal(KEY, (64, 96))
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (96, 80))
    full = abft_matmul_ref(a, b)
    full = full.at[2, 3].add(40.0).at[5, 9].add(-30.0)
    _, rep = verify_and_correct(full)
    assert bool(rep["detected"]) and not bool(rep["corrected"])
    assert int(rep["bad_rows"]) == 2 and int(rep["bad_cols"]) == 2


def test_abft_dot_matches_plain_and_differentiates():
    from repro.kernels.abft_matmul.ops import abft_dot

    x = jax.random.normal(KEY, (2, 16, 96), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (96, 80), jnp.bfloat16)
    y = abft_dot(x, w)
    assert y.shape == (2, 16, 80) and y.dtype == x.dtype
    ref = (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), rtol=2e-2,
                               atol=2e-2)
    # the custom VJP (checksummed backward contractions) matches plain grads
    f_abft = lambda w_: jnp.sum(abft_dot(x.astype(jnp.float32), w_) ** 2)
    f_ref = lambda w_: jnp.sum((x.astype(jnp.float32) @ w_) ** 2)
    wf = w.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(jax.grad(f_abft)(wf)),
                               np.asarray(jax.grad(f_ref)(wf)), rtol=1e-4,
                               atol=1e-3)


def test_mlp_abft_impl_matches_plain():
    from repro.layers.mlp import mlp_apply, mlp_init

    p = mlp_init(KEY, 64, 128, "silu", jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 8, 64))
    y_plain = mlp_apply(p, x, "silu", jnp.float32)
    y_abft = mlp_apply(p, x, "silu", jnp.float32, impl="abft")
    np.testing.assert_allclose(np.asarray(y_abft), np.asarray(y_plain),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 64), (2, 16, 128), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    from repro.kernels.rmsnorm.ops import rms_norm
    from repro.kernels.rmsnorm.ref import rms_norm_ref

    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],),
                          jnp.float32)
    y = rms_norm(x, w, interpret=True)
    yr = rms_norm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=2e-2,
                               rtol=2e-2)


@pytest.mark.parametrize("shape", [
    (1000,), (40, 100), (33, 17, 29), (2048,), (65536,), (70000,),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32,
                                   jnp.int8])
@pytest.mark.parametrize("block_elems", [256, 1024])
def test_block_hash_sweep(shape, dtype, block_elems):
    """Pallas kernel AND jnp twin vs the numpy oracle — bit-exact uint32
    block hashes across dtypes, odd sizes, and tail blocks."""
    from repro.kernels.block_hash.ops import block_hashes
    from repro.kernels.block_hash.ref import block_hashes_np

    if jnp.issubdtype(dtype, jnp.floating):
        x = jax.random.normal(KEY, shape).astype(dtype)
    else:
        n = int(np.prod(shape))
        x = (jnp.arange(n, dtype=jnp.int32) % 251 - 125).astype(
            dtype).reshape(shape)
    ref = block_hashes_np(np.asarray(x), block_elems)
    ker = np.asarray(block_hashes(x, block_elems, use_kernel=True,
                                  interpret=True))
    twin = np.asarray(block_hashes(x, block_elems, use_kernel=False))
    assert ref.dtype == np.uint32 and ker.dtype == np.uint32
    assert ref.shape == (-(-int(np.prod(shape)) // block_elems),)
    np.testing.assert_array_equal(ker, ref)
    np.testing.assert_array_equal(twin, ref)


def test_block_hash_single_bit_flip_changes_exactly_one_hash():
    from repro.kernels.block_hash.ref import block_hashes_np

    x = np.asarray(jax.random.normal(KEY, (4096,)))
    base = block_hashes_np(x, 256)
    # k=31 at an odd word index is the adversarial case for a plain sum's
    # weighted variant: delta = 2^31 * weight — only an ODD weight keeps
    # it nonzero mod 2^32
    for (i, bit) in ((0, 0), (300, 13), (4095, 31), (1, 31)):
        y = x.copy()
        w = y.view(np.uint32)
        w[i] ^= np.uint32(1 << bit)
        h = block_hashes_np(y, 256)
        assert (h != base).sum() == 1
        assert np.nonzero(h != base)[0][0] == i // 256


def test_block_hash_detects_permutations_and_compensating_changes():
    """A plain word sum is permutation-invariant and blind to +d/-d pairs
    — real state updates a delta save must NOT treat as clean.  The odd
    position weights break both symmetries."""
    from repro.kernels.block_hash.ref import block_hashes_np

    x = np.arange(4096, dtype=np.float32)
    base = block_hashes_np(x, 256)
    # swap two unequal values inside one block
    y = x.copy()
    y[10], y[20] = x[20], x[10]
    assert not np.array_equal(block_hashes_np(y, 256), base)
    # compensating integer +d/-d inside one block (sum-preserving)
    z = np.arange(4096, dtype=np.int32)
    bz = block_hashes_np(z, 256)
    z2 = z.copy()
    z2[100] += 7
    z2[101] -= 7
    assert not np.array_equal(block_hashes_np(z2, 256), bz)


def test_block_hash_checksum_is_sum_of_block_hashes():
    """The scrubber's leaf checksum == uint32 sum of the delta-mode block
    hashes (at the same block size — position weights restart per block)
    — scrub and delta genuinely share one reduction."""
    from repro.kernels.block_hash.ops import (BLOCK_ELEMS, block_hashes,
                                              checksum_words)
    from repro.kernels.block_hash.ref import checksum_np
    from repro.sdc.checksum import leaf_checksum

    x = jax.random.normal(KEY, (333, 77))
    hashes = np.asarray(block_hashes(x, BLOCK_ELEMS))
    total = int(hashes.sum(dtype=np.uint32))
    assert total == int(jax.device_get(checksum_words(x)))
    assert total == checksum_np(np.asarray(x))
    assert total == leaf_checksum(x)
    # the identity holds at every (matching) block size
    h256 = np.asarray(block_hashes(x, 256))
    assert int(h256.sum(dtype=np.uint32)) == checksum_np(np.asarray(x), 256)


# ---------------------------------------------------------------------------
# paged decode attention (serve memory stack, docs/serving.md)
# ---------------------------------------------------------------------------

def _paged_case(key, R, H, K, hd, ps, mpr, dtype, num_pages):
    """Random pool + tables: each row maps ``mpr`` distinct live pages
    (none the null page 0); lengths land in every page, including the
    last page's final slot (the fully-dead-trailing-page path falls out
    of short lengths)."""
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (R, 1, H, hd), dtype)
    k_pages = jax.random.normal(ks[1], (num_pages, ps, K, hd), dtype)
    v_pages = jax.random.normal(ks[2], (num_pages, ps, K, hd), dtype)
    perm = jax.random.permutation(ks[3], jnp.arange(1, num_pages))
    page_tables = perm[: R * mpr].reshape(R, mpr).astype(jnp.int32)
    lengths = (jnp.arange(R, dtype=jnp.int32) * 7) % (mpr * ps)
    lengths = lengths.at[-1].set(mpr * ps - 1)     # full table in play
    lengths = lengths.at[0].set(0)                 # single-position row
    return q, k_pages, v_pages, page_tables, lengths


@pytest.mark.parametrize("R,H,K,hd,ps,mpr", [
    (4, 4, 4, 32, 16, 4),    # MHA
    (3, 8, 2, 64, 16, 2),    # GQA 4:1
    (5, 4, 1, 64, 8, 3),     # MQA, small pages
])
@pytest.mark.parametrize("window,softcap", [
    (0, 0.0), (24, 0.0), (0, 30.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_sweep(R, H, K, hd, ps, mpr, window,
                                      softcap, dtype):
    """Pallas page-table-chasing kernel vs the gather oracle, across
    head groupings, page sizes, windows, and softcap."""
    from repro.kernels.paged_attention.ops import paged_decode_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref

    num_pages = R * mpr + 3
    q, kp, vp, pt, ln = _paged_case(KEY, R, H, K, hd, ps, mpr, dtype,
                                    num_pages)
    out = paged_decode_attention(q, kp, vp, pt, ln, window=window,
                                 softcap=softcap, impl="pallas",
                                 interpret=True)
    ref = paged_attention_ref(q[:, 0], kp, vp, pt, ln, window=window,
                              softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [0, 12])
def test_paged_attention_ref_impl_matches_oracle(window):
    """The production ``impl="ref"`` path (gather + the slot pool's exact
    decode_mha graph) agrees with the standalone oracle — the bridge that
    ties kernel sweeps to the engine's bit-identity contract."""
    from repro.kernels.paged_attention.ops import paged_decode_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref

    q, kp, vp, pt, ln = _paged_case(KEY, 4, 4, 2, 32, 8, 3, jnp.float32,
                                    4 * 3 + 2)
    out = paged_decode_attention(q, kp, vp, pt, ln, window=window,
                                 impl="ref")
    ref = paged_attention_ref(q[:, 0], kp, vp, pt, ln, window=window)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)
