"""Chaos scenario engine: schema, injector event API, drivers, the
control-plane simulator, and the compound E2E acceptance scenario
(docs/chaos.md).

The E2E bar: ONE JSON trace (scenarios/compound.json — kill two
hosts/replicas during an SDC storm under a traffic spike, one host
rejoining after) must run end-to-end through both the elastic training
loop and the serving engine with every invariant green, and the simulator
must validate the same control-plane protocol at 1000 virtual hosts in
under a minute."""
import os
import signal as signal_module
import subprocess
import sys
import textwrap
import time

import pytest

from repro.chaos import (ControlPlaneSim, Scenario, ScenarioError,
                         ServeScenarioDriver, TrainScenarioDriver,
                         check_conservation, check_monotonic_drain,
                         check_no_dead_growth, check_no_lost_steps,
                         check_token_identical, check_trajectory_match,
                         check_zero_drop, verify)
from repro.chaos.driver import _storm_flips
from repro.chaos.invariants import InvariantViolation
from repro.core import CorruptionDetected, FaultInjector, SimulatedFailure
from repro.core.failures import StragglerWatchdog

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SCENARIOS = os.path.join(ROOT, "scenarios")


# ---------------------------------------------------------------------------
# Scenario schema
# ---------------------------------------------------------------------------

def _compound():
    return (Scenario("compound", clock="step", seed=42)
            .kill_hosts([2, 3], at=6)
            .sdc_storm(rate=0.3, window=(4, 10))
            .traffic_spike(mult=4, window=(3, 12))
            .rejoin(2, at=16)
            .rejoin(3, at=16))


def test_scenario_builders_validate_and_chain():
    sc = _compound().validate()
    assert [e.kind for e in sc.sorted_events()] == [
        "traffic_spike", "sdc_storm", "kill_hosts", "rejoin", "rejoin"]
    assert sc.horizon == 16
    assert sc.at(6, "kill_hosts")[0].args["hosts"] == [2, 3]
    assert [e.args["mult"] for e in sc.active(5, "traffic_spike")] == [4.0]
    assert sc.active(12, "traffic_spike") == []      # window is [at, until)


def test_scenario_rejects_bad_events():
    with pytest.raises(ScenarioError):
        Scenario().kill_hosts([], at=3)              # empty
    with pytest.raises(ScenarioError):
        Scenario().kill_hosts([1, 1], at=3)          # duplicate ids
    with pytest.raises(ScenarioError):
        Scenario().partition([[0, 1]], at=2, heal_at=5)      # one group
    with pytest.raises(ScenarioError):
        Scenario().partition([[0, 1], [1, 2]], at=2, heal_at=5)  # overlap
    with pytest.raises(ScenarioError):
        Scenario().partition([[0], [1]], at=5, heal_at=5)    # heal <= at
    with pytest.raises(ScenarioError):
        Scenario().sdc_storm(rate=0.0, window=(1, 4))        # rate == 0
    with pytest.raises(ScenarioError):
        Scenario().sdc_storm(rate=0.5, window=(4, 2))        # inverted
    with pytest.raises(ScenarioError):
        Scenario().straggle(1, factor=1.0, window=(1, 4))    # not slower
    with pytest.raises(ScenarioError):
        Scenario().traffic_spike(mult=0.5, window=(1, 4))
    with pytest.raises(ScenarioError):
        Scenario().preempt(at=3, sig="USR1")                 # not SIG*
    with pytest.raises(ScenarioError):
        Scenario(clock="wallclock")


def test_scenario_timeline_validation():
    with pytest.raises(ScenarioError, match="already dead"):
        (Scenario().kill_hosts([1], at=2).kill_hosts([1], at=5)).validate()
    with pytest.raises(ScenarioError, match="never killed"):
        Scenario().rejoin(1, at=5).validate()
    # kill -> rejoin -> kill again is a legal flapping host
    (Scenario().kill_hosts([1], at=2).rejoin(1, at=5)
     .kill_hosts([1], at=8)).validate()


def test_scenario_round_trips_through_json(tmp_path):
    sc = _compound()
    path = str(tmp_path / "sc.json")
    sc.to_json(path)
    back = Scenario.from_json(path)
    assert back.to_dict() == sc.to_dict()
    assert back.seed == 42 and back.clock == "step"
    # and through a raw JSON string
    assert Scenario.from_json(sc.to_json()).to_dict() == sc.to_dict()


def test_scenario_from_dict_rejects_unknown_fields():
    with pytest.raises(ScenarioError, match="unknown fields"):
        Scenario.from_dict({"events": [
            {"kind": "kill_hosts", "hosts": [1], "at": 3, "color": "red"}]})
    with pytest.raises(ScenarioError, match="missing"):
        Scenario.from_dict({"events": [{"kind": "kill_hosts", "at": 3}]})
    with pytest.raises(ScenarioError, match="kind"):
        Scenario.from_dict({"events": [{"kind": "meteor", "at": 3}]})


def test_scenario_library_loads_and_validates():
    import glob
    paths = sorted(glob.glob(os.path.join(SCENARIOS, "*.json")))
    assert len(paths) >= 6, paths
    names = {Scenario.from_json(p).name for p in paths}
    assert "compound" in names


# ---------------------------------------------------------------------------
# FaultInjector event API (satellite: ids, pending, cancel, reset)
# ---------------------------------------------------------------------------

def test_injector_schedule_returns_ids_pending_ordered():
    inj = FaultInjector()
    e_late = inj.schedule_failstop(9)
    e_early = inj.schedule_bitflip(2, "a.b", 5)
    assert isinstance(e_late, int) and e_late != e_early
    steps = [(e["step"], e["id"]) for e in inj.pending()]
    assert steps == sorted(steps)            # (step, id) order
    assert [e["kind"] for e in inj.pending()] == ["bitflip", "failstop"]


def test_injector_cancel_prevents_firing():
    inj = FaultInjector()
    eid = inj.schedule_failstop(3)
    assert inj.cancel(eid) is True
    assert inj.cancel(eid) is False          # already gone
    inj.check(3)                             # nothing fires
    assert inj.pending() == []


def test_injector_reset_clears_pending_keeps_fired_logs():
    inj = FaultInjector()
    inj.schedule_failstop(1)
    with pytest.raises(SimulatedFailure):
        inj.check(1)
    inj.schedule_failstop(5)
    inj.schedule_bitflip(6, "x", 1)
    inj.reset()
    assert inj.pending() == []
    assert inj.triggered == [1]              # fired log survives reset
    inj.check(5)                             # cleared: nothing fires


def test_injector_duplicate_events_at_one_step_both_fire():
    """Two replica kills at one engine step = a correlated rack loss; the
    old set-based bookkeeping silently collapsed them."""
    inj = FaultInjector()
    inj.schedule_replica_kill(3, replica_id=1)
    inj.schedule_replica_kill(3, replica_id=2)
    with pytest.raises(SimulatedFailure):
        inj.check_replica(3, 1)
    with pytest.raises(SimulatedFailure):
        inj.check_replica(3, 2)
    assert inj.replica_kills == [(3, 1), (3, 2)]


def test_injector_replica_sdc_raises_corruption_once():
    inj = FaultInjector()
    inj.schedule_replica_sdc(4, replica_id=1, detail="storm")
    inj.check_replica(3, 1)                  # before the step: nothing
    inj.check_replica(5, 0)                  # other replica: nothing
    with pytest.raises(CorruptionDetected) as e:
        inj.check_replica(5, 1)              # >= step semantics
    assert e.value.kind == "injected-sdc" and e.value.detail == "storm"
    inj.check_replica(6, 1)                  # fires exactly once


# ---------------------------------------------------------------------------
# StragglerWatchdog bounded window (satellite)
# ---------------------------------------------------------------------------

def test_watchdog_window_is_bounded():
    wd = StragglerWatchdog(factor=3.0, window=16, min_samples=5)
    for step in range(10_000):
        wd.observe(step, 1.0 if step % 100 else 50.0)  # periodic straggler
    assert len(wd.durations) == 16           # a week-long run stays bounded
    assert len(wd.flagged_steps) <= 4 * 16
    assert wd.flagged_steps[-1] == 9_900     # newest flags retained
    assert wd.median == 1.0                  # median over the live window


def test_watchdog_still_detects_after_bounding():
    wd = StragglerWatchdog(factor=3.0, window=8, min_samples=3)
    for step in range(50):
        assert wd.observe(step, 1.0) is False
    assert wd.observe(50, 10.0) is True


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

def test_invariant_checks_pass_and_fail():
    ok = check_trajectory_match([1.0, 0.9], [1.0, 0.9], tol=0)
    assert ok and ok.name == "trajectory-match"
    assert not check_trajectory_match([1.0, 0.9], [1.0, 0.5], tol=0.1)
    hist = [{"step": 1, "loss": 1.0}, {"step": 2, "loss": 0.9},
            {"step": 2, "event": "shrink"}]
    assert check_no_lost_steps(hist, 2)
    assert not check_no_lost_steps(hist, 3)          # step 3 missing
    assert check_no_dead_growth([(16.0, [2])], {2: [(6.0, 16.0)]})
    assert not check_no_dead_growth([(10.0, [2])], {2: [(6.0, 16.0)]})
    assert not check_no_dead_growth([(10.0, [3])], {3: [(6.0, float("inf"))]})
    assert check_monotonic_drain([0, 0, 2, 2, 5])
    assert not check_monotonic_drain([0, 3, 1])
    assert check_conservation([{"submitted": 5, "completed": 2,
                                "queued": 2, "in_flight": 1}])
    assert not check_conservation([{"submitted": 5, "completed": 2,
                                    "queued": 2, "in_flight": 0}])
    with pytest.raises(InvariantViolation, match="monotonic-drain"):
        verify([check_monotonic_drain([1, 0])])


def test_zero_drop_and_token_identical_against_scheduler():
    from repro.serve import Scheduler
    s = Scheduler()
    r = s.submit([1, 2], 2)
    s.start_prefill(r, slot=0, replica=0)
    s.start_decode(r, 7)
    s.append_token(r, 8)
    s.finish(r)
    assert check_zero_drop(s, [r.rid])
    s2 = Scheduler(max_retries=0)
    r2 = s2.submit([1], 2)
    s2.start_prefill(r2, slot=0, replica=0)
    s2.requeue(r2)                           # past budget -> FAILED
    assert not check_zero_drop(s2)
    assert check_token_identical({0: [7, 8]}, {0: [7, 8]})
    assert not check_token_identical({0: [7, 9]}, {0: [7, 8]})
    assert not check_token_identical({}, {0: [7]})


# ---------------------------------------------------------------------------
# training driver: compilation units
# ---------------------------------------------------------------------------

class _FakeEmitter:
    def __init__(self):
        self.paused = 0
        self.resumed = 0
        self.send_filter = None

    def pause(self):
        self.paused += 1

    def resume(self):
        self.resumed += 1


def _fake_emitters(n=4):
    return {h: _FakeEmitter() for h in range(n)}


def test_train_driver_compiles_storm_and_straggle_onto_injector():
    sc = (Scenario("s", seed=7).sdc_storm(rate=0.5, window=(2, 8))
          .straggle(host=1, factor=3.0, window=(4, 6)))
    d = TrainScenarioDriver(sc, leaf_names=["params.w"], step_seconds=0.1,
                            settle_seconds=0)
    kinds = [e["kind"] for e in d.injector.pending()]
    assert kinds.count("straggle") == 2      # one per window step
    assert kinds.count("bitflip") >= 1
    flips = [e for e in d.injector.pending() if e["kind"] == "bitflip"]
    assert all(e["leaf"] == "params.w" for e in flips)
    assert all(2 <= e["step"] < 8 for e in flips)
    straggles = [e for e in d.injector.pending() if e["kind"] == "straggle"]
    assert all(abs(e["extra"] - 0.2) < 1e-9 for e in straggles)
    # seeded determinism: same scenario -> identical schedule
    d2 = TrainScenarioDriver(sc, leaf_names=["params.w"], step_seconds=0.1,
                             settle_seconds=0)
    assert d2.injector.pending() == d.injector.pending()
    # different seed -> (almost surely) different schedule object ids ok,
    # but _storm_flips must differ deterministically by seed
    ev = sc.window_events("sdc_storm")[0]
    sc2 = Scenario("s", seed=8)
    assert (_storm_flips(sc, ev, ["params.w"])
            != _storm_flips(sc2, ev, ["params.w"]))


def test_train_driver_fires_actions_once_across_rollback_replay():
    sc = (Scenario("s").kill_hosts([1, 2], at=3)
          .partition([[0], [3]], at=5, heal_at=7).rejoin(1, at=8))
    ems = _fake_emitters()
    d = TrainScenarioDriver(sc, emitters=ems, settle_seconds=0)
    for step in [1, 2, 3, 4]:
        d.on_metrics(step, {"step": step, "loss": 1.0})
    assert ems[1].paused == 1 and ems[2].paused == 1
    # rollback replays steps 2..4: the kill must NOT re-fire
    for step in [2, 3, 4]:
        d.on_metrics(step, {"step": step, "loss": 0.9})
    assert ems[1].paused == 1 and ems[2].paused == 1
    d.on_metrics(5, {"step": 5, "loss": 0.8})
    assert ems[3].send_filter is not None    # partition gate on
    assert ems[0].send_filter is None        # monitor side keeps delivering
    d.on_metrics(7, {"step": 7, "loss": 0.7})
    assert ems[3].send_filter is None        # healed
    d.on_metrics(8, {"step": 8, "loss": 0.6})
    assert ems[1].resumed == 1
    # merged history: last-written record per step wins
    hist = d.history()
    assert [h["step"] for h in hist] == [1, 2, 3, 4, 5, 7, 8]
    assert hist[1]["loss"] == 0.9            # replayed record replaced
    assert d.dead_intervals() == {1: [(3.0, 8.0)], 2: [(3.0, float("inf"))]}
    phases = [a["phase"] for a in d.applied]
    assert phases == ["kill", "partition", "heal", "rejoin"]


def test_train_driver_requires_emitters_for_touched_hosts():
    sc = Scenario("s").kill_hosts([5], at=3)
    with pytest.raises(ScenarioError, match="host 5"):
        TrainScenarioDriver(sc, emitters=_fake_emitters(2))


def test_train_driver_reports_skipped_foreign_kinds():
    sc = Scenario("s").traffic_spike(mult=4, window=(1, 5))
    d = TrainScenarioDriver(sc, settle_seconds=0)
    assert d.report()["skipped"] == ["traffic_spike"]


def test_train_driver_preempt_fires_signal():
    got = []
    prev = signal_module.signal(signal_module.SIGUSR1,
                                lambda s, f: got.append(s))
    try:
        sc = Scenario("s").preempt(at=2)
        d = TrainScenarioDriver(sc, settle_seconds=0)
        d.on_metrics(1, {"step": 1})
        assert got == []
        d.on_metrics(2, {"step": 2})
        time.sleep(0.05)
        assert got == [signal_module.SIGUSR1]
    finally:
        signal_module.signal(signal_module.SIGUSR1, prev)


def test_train_driver_rejects_time_clock():
    with pytest.raises(ScenarioError, match="clock"):
        TrainScenarioDriver(Scenario("s", clock="time"))


# ---------------------------------------------------------------------------
# control-plane simulator
# ---------------------------------------------------------------------------

def test_sim_thousand_hosts_under_a_minute():
    """The acceptance bar: 1000 virtual hosts through the compound trace,
    all invariants green, well under a minute."""
    sc = Scenario.from_json(os.path.join(SCENARIOS, "compound.json"))
    t0 = time.perf_counter()
    rep = ControlPlaneSim(1000, base_rate=20).run(sc)
    wall = time.perf_counter() - t0
    assert wall < 60.0, wall
    assert rep.num_hosts == 1000
    assert len(rep.detections) == 2          # hosts 2 and 3
    assert {d["host"] for d in rep.detections} == {2, 3}
    assert all(lat >= 0 for lat in rep.detection_latencies)
    assert rep.stale_delivered > 0
    assert rep.stale_rejected == rep.stale_delivered   # every one rejected
    assert sorted(h for t, hs in rep.grow_events for h in hs) == [2, 3]
    assert rep.cadence_ok                    # Young/Daly tracks closed form
    verify(rep.invariants)
    d = rep.to_dict()
    assert d["invariant_pass_rate"] == 1.0


def test_sim_mesh_shrinks_and_grows_with_membership():
    sc = (Scenario("m").kill_hosts([1, 2], at=3).rejoin(1, at=10))
    rep = ControlPlaneSim(8, devices_per_host=2, model_axis=2).run(sc)
    dps = [m["dp"] for m in rep.mesh_history]
    assert dps[0] == 8                       # 8 hosts x 2 dev / tp2
    assert 6 in dps                          # after losing 2 hosts
    assert dps[-1] == 7                      # host 1 grew back
    # Young/Daly re-sized at every membership change
    nodes = {c["nodes"] for c in rep.cadence}
    assert {8, 6, 7} <= nodes


def test_sim_partition_is_asymmetric_and_heals():
    """The cut side keeps beating (its seq advances) but the monitor times
    it out; healing rejoins through ordinary (inc, seq) delivery."""
    sc = Scenario("p").partition([[0, 1], [2, 3]], at=2, heal_at=20)
    rep = ControlPlaneSim(4).run(sc)
    assert {d["host"] for d in rep.detections} == {2, 3}
    rejoined = sorted(h for t, hs in rep.grow_events for h in hs)
    assert rejoined == [2, 3]                # healed via ordinary delivery
    verify(rep.invariants)


def test_sim_all_hosts_dead_raises():
    from repro.core import NoSurvivorsError
    sc = Scenario("dead").kill_hosts([0, 1], at=2)
    with pytest.raises(NoSurvivorsError):
        ControlPlaneSim(2).run(sc)


def test_sim_time_clock_scenarios():
    sc = Scenario("t", clock="time").kill_hosts([1], at=0.5)
    rep = ControlPlaneSim(4, period=0.1).run(sc)
    assert len(rep.detections) == 1
    assert rep.detections[0]["t_lost"] == pytest.approx(0.5)


def test_sim_traffic_spike_drains_and_conserves():
    sc = (Scenario("q").traffic_spike(mult=10, window=(2, 6))
          .kill_hosts([1], at=4))
    rep = ControlPlaneSim(4, base_rate=3, slots_per_host=2,
                          service_ticks=2).run(sc)
    assert rep.drained_total > 0             # the kill drained in-flight work
    assert rep.completed_total > 0
    verify(rep.invariants)


# ---------------------------------------------------------------------------
# serving driver (fast: flash crowd + admission control)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup():
    import jax
    from repro.models import get_config, init_params
    cfg = get_config("granite-3-8b", tiny=True)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def test_serve_driver_flash_crowd_rejects_but_conserves(serve_setup):
    """Overload through admission control: the spike overflows max_pending,
    rejections are counted (never raised), and every ADMITTED request
    finishes — conservation holds at every engine step."""
    from repro.serve import ServeEngine
    cfg, params = serve_setup
    sc = Scenario.from_json(os.path.join(SCENARIOS, "flash_crowd.json"))
    eng = ServeEngine(cfg, params, num_replicas=1, slots_per_replica=2,
                      max_len=16, fault_tolerant=False, max_pending=6)
    drv = ServeScenarioDriver(eng, sc, base_rate=2, prompt_len=4,
                              max_new_tokens=4)
    results = drv.run()
    eng.shutdown()
    assert drv.rejected > 0, "an 8x spike into max_pending=6 must reject"
    assert len(results) == len(drv.submitted_rids)
    verify([check_zero_drop(eng.scheduler, drv.submitted_rids),
            check_conservation(drv.samples),
            check_monotonic_drain(drv.drained_series)])
    rep = drv.report()
    assert rep["rejected"] == drv.rejected
    assert rep["skipped"] == []              # every kind applies here


def test_serve_driver_rejects_time_clock(serve_setup):
    from repro.serve import ServeEngine
    cfg, params = serve_setup
    eng = ServeEngine(cfg, params, num_replicas=1, slots_per_replica=2,
                      max_len=16, fault_tolerant=False)
    with pytest.raises(ScenarioError, match="clock"):
        ServeScenarioDriver(eng, Scenario("t", clock="time"))
    eng.shutdown()


# ---------------------------------------------------------------------------
# E2E: the compound scenario through the serving engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_e2e_serve_compound_scenario(serve_setup):
    """ONE JSON trace: 2 replicas killed + SDC storm striking replicas +
    4x traffic spike.  Standbys absorb the losses; zero admitted requests
    drop; every retried stream is token-identical to the B=1 oracle."""
    import jax
    import jax.numpy as jnp
    from repro.models import init_cache
    from repro.serve import ServeEngine
    from repro.train import make_decode_step, make_prefill_step
    cfg, params = serve_setup
    sc = Scenario.from_json(os.path.join(SCENARIOS, "compound.json"))
    eng = ServeEngine(cfg, params, num_replicas=4, slots_per_replica=2,
                      max_len=32, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      max_pending=256, max_retries=8)
    for _ in range(4):                       # one per possible casualty
        eng.add_standby(lambda: params)
    drv = ServeScenarioDriver(eng, sc, base_rate=1, prompt_len=6,
                              max_new_tokens=6)
    results = drv.run()
    rep = drv.report()
    failures = [e for e in eng.events if e["event"] == "replica_failed"]
    sched = eng.scheduler

    # the scenario actually struck: injected kills and SDC both landed
    reasons = {e["reason"] for e in failures}
    assert any(r.startswith("injected:replica-kill") for r in reasons)
    assert any(r.startswith("sentinel:") for r in reasons), reasons
    assert rep["retried"] > 0                # in-flight work drained
    assert rep["skipped"] == ["rejoin"]      # serve plane has no rejoin

    # invariants: nothing dropped, accounting balanced, streams bit-exact
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    ref = {}
    for rid in drv.submitted_rids:
        toks = jnp.asarray(drv.prompts[rid], jnp.int32)[None]
        tok, row = prefill(params, {"tokens": toks}, init_cache(cfg, 1, 32))
        s = [int(tok[0])]
        for _ in range(drv.max_new_tokens - 1):
            tok, row = decode(params, {"tokens": tok[:, None]}, row)
            s.append(int(tok[0]))
        ref[rid] = s
    verify([check_zero_drop(sched, drv.submitted_rids),
            check_token_identical(results, ref),
            check_conservation(drv.samples),
            check_monotonic_drain(drv.drained_series)])
    eng.shutdown()


# ---------------------------------------------------------------------------
# E2E: the compound scenario through the elastic training loop
# (multi-device -> subprocess, same pattern as tests/test_elastic_loop.py)
# ---------------------------------------------------------------------------

_PRELUDE = """
import time
import jax
from repro.chaos import (Scenario, run_scenario_elastic, verify,
                         check_no_dead_growth, check_no_lost_steps,
                         check_trajectory_match)
from repro.core import (Dependability, DependabilityConfig, HeartbeatEmitter)
from repro.data import ShardedPipeline
from repro.launch.mesh import host_device_map
from repro.models import get_config
from repro.obs import Observability, Timeline, load_jsonl, to_scenario
from repro.sdc.checksum import named_leaves
from repro.sharding.api import resolve
from repro.sharding.rules import state_specs
from repro.train import init_state, make_train_step

cfg = get_config("granite-3-8b", tiny=True)
KEY = jax.random.PRNGKey(0)
PERIOD = 0.05

def shardings_for(mesh):
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    specs = state_specs(cfg, tp)
    return jax.tree.map(lambda s: resolve(s, mesh), specs,
                        is_leaf=lambda x: x.__class__.__name__ ==
                        "PartitionSpec")

def make_step_for(steps):
    def make_step(mesh):
        return jax.jit(make_train_step(cfg, total_steps=steps),
                       out_shardings=(shardings_for(mesh), None))
    return make_step
"""


def _run(script: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["CHAOS_SCENARIOS"] = SCENARIOS
    p = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_e2e_elastic_compound_scenario(tmp_path):
    """The same compound JSON against run_elastic: two hosts die together
    at step 6, seeded SDC flips land (scrub-detected, rolled back via
    run_scenario_elastic's re-entry on the survivor set), the rack heals
    at step 16 — and the merged trajectory still matches an uninterrupted
    single-device run step for step.

    The run records its telemetry to JSONL; afterwards the log must (a)
    convert back into the *same* Scenario via ``to_scenario`` (the
    record-and-replay acceptance criterion, replayed here through the
    control-plane simulator with invariants green) and (b) yield a
    failure timeline whose incidents closed."""
    out = _run(f"""
    import os
    STEPS = 20

    # reference: uninterrupted slice-mode run on one device
    ref_data = ShardedPipeline(cfg, 16, 4, dp_width=1)
    ref_step = jax.jit(make_train_step(cfg, total_steps=STEPS))
    ref = init_state(cfg, KEY)
    ref_losses = []
    for _ in range(STEPS):
        ref, m = ref_step(ref, ref_data.next_batch())
        ref_losses.append(float(m["loss"]))

    sc = Scenario.from_json(
        os.path.join(os.environ["CHAOS_SCENARIOS"], "compound.json"))
    hosts = host_device_map(4)               # 4 hosts x 2 devices
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=r"{tmp_path}", policy_mode="every_n", every_n=2,
        heartbeat=True, heartbeat_period=PERIOD,
        heartbeat_timeout_factor=5.0, signal_detection=False,
        scrub=True, scrub_fraction=1.0,
        monitor_hosts=4), host_id=0, num_hosts=1).start()
    jsonl = os.path.join(r"{tmp_path}", "events.jsonl")
    dep.attach_obs(Observability(jsonl_path=jsonl))
    ems = {{h: HeartbeatEmitter(h, dep.monitor.addr, PERIOD).start()
           for h in (1, 2, 3)}}
    ems[0] = dep.emitter                     # host 0 beats from dep itself

    data = ShardedPipeline(cfg, 16, 4, dp_width=4)
    state = init_state(cfg, KEY)
    template = jax.eval_shape(lambda: init_state(cfg, KEY))
    leaf_names = [n for n, v in named_leaves(state)
                  if n.startswith("params.") and "attn.wk" in n]
    assert leaf_names

    state, info = run_scenario_elastic(
        dep, make_step_for(STEPS), state, data, STEPS, scenario=sc,
        emitters=ems, host_devices=hosts, model_axis=2, like=template,
        shardings_fn=shardings_for, leaf_names=leaf_names)

    assert info["status"] == "done"
    assert info["rollbacks"] >= 1, "the storm must have forced a rollback"
    kinds = [e.kind for e in info["events"]]
    assert "shrink" in kinds and "grow" in kinds, kinds
    shrunk = [h for e in info["events"] if e.kind == "shrink"
              for h in e.hosts]
    assert sorted(shrunk) == [2, 3], shrunk
    grown = [(e.step, list(e.hosts)) for e in info["events"]
             if e.kind == "grow"]
    assert sorted(h for _, hs in grown for h in hs) == [2, 3]
    assert info["dp"] == 4                   # the full rack healed
    assert info["report"]["sdc_injected"], "flips must actually have landed"
    assert info["report"]["skipped"] == ["traffic_spike"]

    losses = [h["loss"] for h in info["history"] if "loss" in h]
    verify([check_no_lost_steps(info["history"], STEPS),
            check_trajectory_match(losses, ref_losses, tol=0.15),
            check_no_dead_growth(
                [(s, hs) for s, hs in grown],
                {{2: [(6.0, 16.0)], 3: [(6.0, 16.0)]}})])

    # record-and-replay: freeze the JSONL log, reconstruct the scenario
    # from it (declarative chaos events -> lossless), and replay the
    # reconstruction through the control-plane simulator
    dep.obs.close()
    rec = load_jsonl(jsonl)
    back = to_scenario(rec)
    assert back.to_dict() == sc.to_dict(), "round-trip scenario drifted"
    assert back.seed == sc.seed and back.clock == "step"
    from repro.chaos import ControlPlaneSim
    simrep = ControlPlaneSim(4, devices_per_host=2, model_axis=2).run(back)
    verify(simrep.invariants)
    assert {{d["host"] for d in simrep.detections}} == {{2, 3}}

    # failure timeline: the rack loss + storm incidents all closed, so
    # MTTR and availability are well-defined measured numbers
    tl = Timeline.from_events(rec)
    s = tl.summary()
    assert s["incidents"] >= 1 and s["closed"] == s["incidents"], s
    assert s["mttr_s"] > 0 and s["availability"] < 1.0, s
    assert "heartbeat.failure" in s["causes"], s

    for em in ems.values():
        em.stop()
    dep.stop()
    print("compound elastic OK: rollbacks=", info["rollbacks"],
          "events=", kinds, "mttr=%.2fs" % s["mttr_s"],
          "availability=%.3f" % s["availability"])
    """, devices=8)
    assert "compound elastic OK" in out
