"""End-to-end fail-stop recovery: crash-and-restore training must be
bit-exact with the uninterrupted run (global + local state preserved)."""
import jax
import numpy as np
import pytest

from repro.core import (Dependability, DependabilityConfig, FaultInjector,
                        SimulatedFailure, run_bsp, run_with_recovery)
from repro.data import make_pipeline
from repro.models import get_config
from repro.train import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _dep(tmp_path, **kw):
    base = dict(policy_mode="every_n", every_n=2, heartbeat=False,
                signal_detection=False)
    base.update(kw)
    return Dependability(DependabilityConfig(checkpoint_dir=str(tmp_path),
                                             **base)).start()


def _run_reference(cfg, steps):
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    state = init_state(cfg, KEY)
    data = make_pipeline(cfg, 16, 4)
    for _ in range(steps):
        state, m = step_fn(state, data.next_batch())
    return state, float(m["loss"])


@pytest.mark.parametrize("async_save", [False, True])
def test_crash_recovery_bit_exact(tmp_path, async_save):
    cfg = get_config("granite-3-8b", tiny=True)
    steps = 9
    ref_state, ref_loss = _run_reference(cfg, steps)

    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    state = init_state(cfg, KEY)
    data = make_pipeline(cfg, 16, 4)
    dep = _dep(tmp_path, async_save=async_save)
    dep.register_local_state(data)
    injector = FaultInjector()
    injector.schedule_failstop(5)
    injector.schedule_failstop(7)
    state, info = run_with_recovery(dep, step_fn, state, data, steps,
                                    fault_injector=injector, like=state,
                                    max_restarts=3)
    assert info["status"] == "done"
    assert info["restarts"] == 2
    last_loss = [h["loss"] for h in info["history"] if "loss" in h][-1]
    assert last_loss == ref_loss
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    dep.stop()


def test_recovery_gives_up_after_max_restarts(tmp_path):
    cfg = get_config("gemma-7b", tiny=True)
    step_fn = jax.jit(make_train_step(cfg))
    state = init_state(cfg, KEY)
    data = make_pipeline(cfg, 16, 2)
    dep = _dep(tmp_path)
    dep.register_local_state(data)
    injector = FaultInjector()
    for s in (2, 3, 4, 5, 6):
        injector.schedule_failstop(s)
    with pytest.raises(SimulatedFailure):
        run_with_recovery(dep, step_fn, state, data, 10,
                          fault_injector=injector, like=state,
                          max_restarts=2)
    dep.stop()


def test_straggler_watchdog_flags_slow_step(tmp_path):
    cfg = get_config("gemma-7b", tiny=True)
    step_fn = jax.jit(make_train_step(cfg))
    state = init_state(cfg, KEY)
    data = make_pipeline(cfg, 16, 2)
    dep = _dep(tmp_path, straggler_factor=2.5)
    dep.register_local_state(data)
    injector = FaultInjector()
    injector.schedule_straggle(8, extra_seconds=1.0)
    state, status, hist = run_bsp(dep, step_fn, state, data, 10,
                                  fault_injector=injector)
    # straggle(8) sleeps inside step 8's superstep window
    flagged = dep.stragglers.flagged_steps
    assert 8 in flagged, hist
    dep.stop()


def test_young_daly_policy_in_loop(tmp_path):
    cfg = get_config("gemma-7b", tiny=True)
    step_fn = jax.jit(make_train_step(cfg))
    state = init_state(cfg, KEY)
    data = make_pipeline(cfg, 16, 2)
    dep = _dep(tmp_path, policy_mode="young_daly")
    dep.register_local_state(data)
    state, status, _ = run_bsp(dep, step_fn, state, data, 6)
    assert status == "done"
    assert dep.manager.latest_step() is not None   # bootstrap save happened
    assert dep.policy.ckpt_cost_s is not None      # C measured online
    dep.stop()
