"""E2E elastic failover: heartbeat-driven mesh shrink/grow
(core/elastic_loop.run_elastic) with local-scope shard checkpointing.

Multi-device, so each test runs a subprocess with
--xla_force_host_platform_device_count set (the main test process must keep
the default single CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# shared by every scenario: 2 simulated hosts x 4 devices, tp=2; slice-mode
# pipeline so the merged global batch is identical at any DP width
_PRELUDE = """
import time, tempfile
import jax
import numpy as np
from repro.core import (Dependability, DependabilityConfig, HeartbeatEmitter,
                        run_elastic)
from repro.data import ShardedPipeline
from repro.launch.mesh import host_device_map
from repro.models import get_config
from repro.sharding.api import resolve
from repro.sharding.rules import state_specs
from repro.train import init_state, make_train_step

cfg = get_config("granite-3-8b", tiny=True)
KEY = jax.random.PRNGKey(0)
PERIOD = 0.05

def shardings_for(mesh):
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    specs = state_specs(cfg, tp)
    return jax.tree.map(lambda s: resolve(s, mesh), specs,
                        is_leaf=lambda x: x.__class__.__name__ ==
                        "PartitionSpec")

def make_step_for(steps):
    def make_step(mesh):
        return jax.jit(make_train_step(cfg, total_steps=steps),
                       out_shardings=(shardings_for(mesh), None))
    return make_step

def make_dep(d, monitor_hosts=2):
    return Dependability(DependabilityConfig(
        checkpoint_dir=d, policy_mode="every_n", every_n=2,
        heartbeat=True, heartbeat_period=PERIOD,
        heartbeat_timeout_factor=5.0, signal_detection=False,
        monitor_hosts=monitor_hosts), host_id=0, num_hosts=1).start()
"""


def _run(script: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(script)],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_failover_shrink_matches_uninterrupted_run(tmp_path):
    """Emitter pause -> monitor detection -> on_failure exactly once ->
    survivor mesh rebuild -> resharded restore of global AND per-shard
    local state -> loss history matches the uninterrupted run."""
    _run(f"""
    STEPS = 10

    # reference: uninterrupted slice-mode run on a single device
    ref_data = ShardedPipeline(cfg, 16, 4, dp_width=1)
    ref_step = jax.jit(make_train_step(cfg, total_steps=STEPS))
    ref = init_state(cfg, KEY)
    ref_losses = []
    for _ in range(STEPS):
        ref, m = ref_step(ref, ref_data.next_batch())
        ref_losses.append(float(m["loss"]))

    hosts = host_device_map(2)
    dep = make_dep(r"{tmp_path}")
    failures = []
    dep.on_host_failure = failures.append
    em1 = HeartbeatEmitter(1, dep.monitor.addr, PERIOD).start()

    data = ShardedPipeline(cfg, 16, 4, dp_width=4)
    state = init_state(cfg, KEY)
    template = jax.eval_shape(lambda: init_state(cfg, KEY))

    paused = {{"done": False}}
    def on_metrics(s, rec):
        if s == 3 and not paused["done"]:
            paused["done"] = True
            em1.pause()                   # fail-stop: beats just stop
            time.sleep(6 * PERIOD)        # monitor notices by next boundary

    state, info = run_elastic(dep, make_step_for(STEPS), state, data, STEPS,
                              host_devices=hosts, model_axis=2,
                              like=template, shardings_fn=shardings_for,
                              on_metrics=on_metrics)
    assert info["status"] == "done"
    assert failures == [1], failures      # fired exactly once
    assert [e.kind for e in info["events"]] == ["shrink"]
    assert info["events"][0].hosts == (1,)
    assert info["dp"] == 2                # (2,2) survivor mesh
    # per-shard local scope: 4 shard files remapped onto 2 shards
    assert data.dp_width == 2 and data.remapped_from == 4
    losses = [h["loss"] for h in info["history"] if "loss" in h]
    assert len(losses) == STEPS, losses   # no lost or repeated steps
    # same data stream either side of the failure -> same trajectory up to
    # bf16 cross-mesh reduction-order noise (see test_elastic_mesh notes)
    for i, (a, b) in enumerate(zip(losses, ref_losses)):
        assert abs(a - b) < 0.15, (i, a, b)
    em1.stop(); dep.stop()
    print("shrink failover OK", losses[-1], ref_losses[-1])
    """, devices=8)


@pytest.mark.slow
def test_failover_grow_on_rejoin(tmp_path):
    """Shrink on pause, then the emitter resumes: the monitor reports the
    rejoin, the loop pauses at a step boundary and grows the mesh back."""
    _run(f"""
    STEPS = 14
    hosts = host_device_map(2)
    dep = make_dep(r"{tmp_path}")
    em1 = HeartbeatEmitter(1, dep.monitor.addr, PERIOD).start()

    data = ShardedPipeline(cfg, 16, 4, dp_width=4)
    state = init_state(cfg, KEY)
    template = jax.eval_shape(lambda: init_state(cfg, KEY))

    phase = {{"n": 0}}
    def on_metrics(s, rec):
        if s == 3 and phase["n"] == 0:
            phase["n"] = 1
            em1.pause()
            time.sleep(6 * PERIOD)
        elif s == 7 and phase["n"] == 1:
            phase["n"] = 2
            em1.resume()                  # failover: the host comes back
            time.sleep(4 * PERIOD)

    state, info = run_elastic(dep, make_step_for(STEPS), state, data, STEPS,
                              host_devices=hosts, model_axis=2,
                              like=template, shardings_fn=shardings_for,
                              on_metrics=on_metrics)
    assert info["status"] == "done"
    kinds = [e.kind for e in info["events"]]
    assert kinds == ["shrink", "grow"], kinds
    assert info["dp"] == 4 and data.dp_width == 4
    losses = [h["loss"] for h in info["history"] if "loss" in h]
    assert len(losses) == STEPS
    em1.stop(); dep.stop()
    print("grow on rejoin OK")
    """, devices=8)


@pytest.mark.slow
def test_all_hosts_failed_raises_no_survivors(tmp_path):
    """Every emitter pausing must surface NoSurvivorsError, not a
    ZeroDivisionError from the grid math."""
    _run(f"""
    from repro.core import NoSurvivorsError
    STEPS = 10
    hosts = host_device_map(2)
    dep = make_dep(r"{tmp_path}", monitor_hosts=2)
    em1 = HeartbeatEmitter(1, dep.monitor.addr, PERIOD).start()

    data = ShardedPipeline(cfg, 16, 4, dp_width=4)
    state = init_state(cfg, KEY)
    template = jax.eval_shape(lambda: init_state(cfg, KEY))

    fired = {{"done": False}}
    def on_metrics(s, rec):
        if s == 2 and not fired["done"]:
            fired["done"] = True
            em1.pause()
            dep.emitter.pause()           # host 0's own beats stop too
            time.sleep(8 * PERIOD)

    try:
        run_elastic(dep, make_step_for(STEPS), state, data, STEPS,
                    host_devices=hosts, model_axis=2, like=template,
                    shardings_fn=shardings_for, on_metrics=on_metrics)
        raise SystemExit("expected NoSurvivorsError")
    except NoSurvivorsError as e:
        print("no survivors OK:", e)
    em1.stop(); dep.stop()
    """, devices=8)
